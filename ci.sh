#!/usr/bin/env bash
# CI check: tier-1 (build + tests) plus the smoke-scale suite through the
# scheduling service's worker pool, including the byte-determinism check
# the batch API guarantees.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

BIN=target/release/memsched
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

echo "== service: smoke suite ×2 through the pool (jobs=1 vs jobs=4) =="
"$BIN" batch --suite smoke --repeat 2 --jobs 1 --out "$TMP/j1.jsonl"
"$BIN" batch --suite smoke --repeat 2 --jobs 4 --out "$TMP/j4.jsonl"
cmp "$TMP/j1.jsonl" "$TMP/j4.jsonl"
echo "batch output byte-identical across worker counts"

echo "== engine: parallel-scoring parity (score-threads=1 vs 4) =="
"$BIN" batch --suite smoke --jobs 2 --score-threads 1 --out "$TMP/s1.jsonl"
"$BIN" batch --suite smoke --jobs 2 --score-threads 4 --out "$TMP/s4.jsonl"
cmp "$TMP/s1.jsonl" "$TMP/s4.jsonl"
echo "batch output byte-identical across score-thread counts"

echo "== experiments: fig1 smoke through the pool =="
"$BIN" experiment --figure fig1 --scale smoke --jobs 4 > /dev/null

echo "ci: OK"
