#!/usr/bin/env bash
# Tiered CI harness.
#
#   ./ci.sh             all tiers (tier1, lint, smoke, bench)
#   ./ci.sh --tier1     build + cargo test -q
#   ./ci.sh --lint      cargo fmt --check + cargo clippy -- -D warnings
#                       (root package only — the rust/vendor shims are
#                       path dependencies, not workspace members, so
#                       they are excluded automatically; skipped with a
#                       notice when the components are not installed)
#   ./ci.sh --smoke     service/parity smokes + the replay-parity smoke
#                       (multi-sigma vs per-sigma, sweep vs flat, scaffold
#                       sweep vs per-point `memsched simulate`, warm/cold
#                       --cache-dir with schedules_computed=0, Recompute
#                       sweep bytes across --score-threads) + the serve
#                       round-trip smoke (daemon responses byte-identical
#                       to `memsched batch`, warm second client computes
#                       0 schedules, SIGTERM drains and exits 0)
#   ./ci.sh --bench     bench_engine + bench_service + bench_replay +
#                       bench_recompute at tiny scale, emit BENCH_ci.json,
#                       and gate >2x regressions against
#                       rust/benches/BENCH_baseline.json
#                       when that baseline exists
#   ./ci.sh --bench --seed-baseline
#                       additionally copy the fresh BENCH_ci.json to
#                       rust/benches/BENCH_baseline.json (after the gate
#                       runs against the old baseline, if any); run on a
#                       representative toolchain box and commit the file
#                       so `memsched bench-check` actually gates
#   ./ci.sh --crossover full-scale serial-vs-pooled scoring sweep over the
#                       cluster × fan-in work axis; prints the measured
#                       suggestion for scheduler::SCORE_PARALLEL_CROSSOVER
#                       (update the constant + its boundary test if moved)
#
# .github/workflows/ci.yml runs the tiers as separate jobs.
set -euo pipefail
cd "$(dirname "$0")"

BIN=target/release/memsched

usage() {
  sed -n '2,32p' "$0" | sed 's/^# \{0,1\}//'
}

TIERS=()
SEED_BASELINE=0
for arg in "$@"; do
  case "$arg" in
    --tier1) TIERS+=(tier1) ;;
    --lint) TIERS+=(lint) ;;
    --smoke) TIERS+=(smoke) ;;
    --bench) TIERS+=(bench) ;;
    --crossover) TIERS+=(crossover) ;;
    --seed-baseline) SEED_BASELINE=1 ;;
    -h|--help) usage; exit 0 ;;
    *) echo "unknown option: $arg" >&2; usage >&2; exit 2 ;;
  esac
done
if [ ${#TIERS[@]} -eq 0 ]; then
  TIERS=(tier1 lint smoke bench)
fi
if [ "$SEED_BASELINE" = 1 ] && [[ " ${TIERS[*]} " != *" bench "* ]]; then
  TIERS+=(bench)
fi

ensure_bin() {
  # Always build: a stale target/release/memsched (e.g. restored from a
  # CI cache) must never be what the smokes and bench gates validate.
  # Incremental compilation makes the no-change case cheap.
  cargo build --release
}

tier_tier1() {
  echo "== tier-1: cargo build --release && cargo test -q =="
  cargo build --release
  cargo test -q
}

tier_lint() {
  echo "== lint: cargo fmt --check + cargo clippy -- -D warnings =="
  if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
  else
    echo "lint: rustfmt not installed; skipping fmt check"
  fi
  if cargo clippy --version >/dev/null 2>&1; then
    # Vendor shims are path dependencies (not workspace members), so
    # clippy only lints the memsched package itself.
    cargo clippy --release --all-targets -- -D warnings
  else
    echo "lint: clippy not installed; skipping clippy"
  fi
}

tier_smoke() {
  ensure_bin
  TMP="$(mktemp -d)"
  trap 'rm -rf "$TMP"' EXIT

  echo "== service: smoke suite ×2 through the pool (jobs=1 vs jobs=4) =="
  "$BIN" batch --suite smoke --repeat 2 --jobs 1 --out "$TMP/j1.jsonl" 2>/dev/null
  "$BIN" batch --suite smoke --repeat 2 --jobs 4 --out "$TMP/j4.jsonl" 2>/dev/null
  cmp "$TMP/j1.jsonl" "$TMP/j4.jsonl"
  echo "batch output byte-identical across worker counts"

  echo "== engine: parallel-scoring parity (score-threads=1 vs 4 vs auto) =="
  "$BIN" batch --suite smoke --jobs 2 --score-threads 1 --out "$TMP/s1.jsonl" 2>/dev/null
  "$BIN" batch --suite smoke --jobs 2 --score-threads 4 --out "$TMP/s4.jsonl" 2>/dev/null
  "$BIN" batch --suite smoke --jobs 2 --score-threads auto --out "$TMP/sa.jsonl" 2>/dev/null
  cmp "$TMP/s1.jsonl" "$TMP/s4.jsonl"
  cmp "$TMP/s1.jsonl" "$TMP/sa.jsonl"
  echo "batch output byte-identical across score-thread counts (incl. auto)"

  echo "== simulator: Recompute sweep parity (score-threads=1 vs 4) =="
  # Recompute-mode points reschedule mid-run through Engine::resume;
  # with score-threads > 1 those passes score on the worker's pool, and
  # the deterministic reduction must keep every outcome byte identical.
  "$BIN" batch --suite smoke --sigmas 0.3 --jobs 2 --score-threads 1 \
    --out "$TMP/rc1.jsonl" 2>/dev/null
  "$BIN" batch --suite smoke --sigmas 0.3 --jobs 2 --score-threads 4 \
    --out "$TMP/rc4.jsonl" 2>/dev/null
  cmp "$TMP/rc1.jsonl" "$TMP/rc4.jsonl"
  grep -q '"mode":"recompute"' "$TMP/rc1.jsonl" \
    || { echo "Recompute sweep emitted no recompute rows:"; head "$TMP/rc1.jsonl"; exit 1; }
  echo "Recompute-mode sweep byte-identical across score-thread counts"

  echo "== experiments: fig1 smoke through the pool =="
  "$BIN" experiment --figure fig1 --scale smoke --jobs 4 > /dev/null 2>"$TMP/fig1.err"

  echo "== replay: multi-sigma experiment == concatenated single-sigma runs =="
  "$BIN" experiment --figure fig8 --scale smoke --sigmas 0.1,0.3 --jobs 4 \
    > "$TMP/multi.csv" 2>/dev/null
  "$BIN" experiment --figure fig8 --scale smoke --sigmas 0.1 --jobs 1 \
    > "$TMP/s01.csv" 2>/dev/null
  "$BIN" experiment --figure fig8 --scale smoke --sigmas 0.3 --jobs 1 \
    > "$TMP/s03.csv" 2>/dev/null
  cat "$TMP/s01.csv" "$TMP/s03.csv" | cmp - "$TMP/multi.csv"
  echo "multi-sigma fig8 output identical to per-sigma concatenation"

  echo "== replay: sweep JSONL == flattened per-point JSONL =="
  cat > "$TMP/sweep_jobs.jsonl" <<'EOF'
{"model":"chipseq","input":1,"sweep":[{"mode":"recompute","sigma":0.1},{"mode":"recompute","sigma":0.3},{"mode":"static","sigma":0.3}]}
{"model":"bacass","input":0,"algo":"heftm-mm","sweep":[{"mode":"static","sigma":0.2,"seed":9}]}
{"model":"eager","input":0}
EOF
  cat > "$TMP/flat_jobs.jsonl" <<'EOF'
{"model":"chipseq","input":1,"sim":{"mode":"recompute","sigma":0.1}}
{"model":"chipseq","input":1,"sim":{"mode":"recompute","sigma":0.3}}
{"model":"chipseq","input":1,"sim":{"mode":"static","sigma":0.3}}
{"model":"bacass","input":0,"algo":"heftm-mm","sim":{"mode":"static","sigma":0.2,"seed":9}}
{"model":"eager","input":0}
EOF
  "$BIN" batch --input "$TMP/sweep_jobs.jsonl" --jobs 4 --out "$TMP/sweep.jsonl" 2>/dev/null
  "$BIN" batch --input "$TMP/flat_jobs.jsonl" --jobs 1 --out "$TMP/flat.jsonl" 2>/dev/null
  cmp "$TMP/sweep.jsonl" "$TMP/flat.jsonl"
  echo "replay-sweep batch byte-identical to flattened per-point batch"

  echo "== replay: scaffold sweep matches per-point memsched simulate =="
  # The sweep runs through the shared-scaffold replay core; each point is
  # then re-run standalone (`memsched simulate --json`, which prints the
  # same full-precision `sim` object a batch line carries) and the bytes
  # must agree exactly.
  "$BIN" generate --model chipseq --seed 7 --input 1 --out "$TMP/wf.json" >/dev/null
  printf '%s\n' \
    "{\"workflow\":\"$TMP/wf.json\",\"sweep\":[{\"mode\":\"recompute\",\"sigma\":0.1,\"seed\":7},{\"mode\":\"recompute\",\"sigma\":0.3,\"seed\":7},{\"mode\":\"static\",\"sigma\":0.3,\"seed\":7}]}" \
    > "$TMP/scaffold_sweep.jsonl"
  "$BIN" batch --input "$TMP/scaffold_sweep.jsonl" --jobs 4 \
    --out "$TMP/scaffold_out.jsonl" 2>/dev/null
  # The comparison below assumes the static schedule is valid (both
  # paths then emit the same sim-object shape); fail legibly otherwise.
  sed -n '1p' "$TMP/scaffold_out.jsonl" | grep -q '"valid":true' \
    || { echo "scaffold smoke workload schedules invalid; pick another instance:"; \
         cat "$TMP/scaffold_out.jsonl"; exit 1; }
  i=1
  for point in "--sigma 0.1 --seed 7" "--sigma 0.3 --seed 7" "--sigma 0.3 --seed 7 --no-recompute"; do
    want=$(sed -n "${i}p" "$TMP/scaffold_out.jsonl" | sed -E 's/.*"sim":(\{[^}]*\})\}$/\1/')
    # shellcheck disable=SC2086  # $point is a flag list by construction
    got=$("$BIN" simulate --workflow "$TMP/wf.json" $point --json)
    if [ "$want" != "$got" ]; then
      echo "replay point $i mismatch:"; echo "  sweep:    $want"; echo "  simulate: $got"
      exit 1
    fi
    i=$((i+1))
  done
  echo "scaffold-path sweep sim fields byte-identical to per-point memsched simulate"

  echo "== obs: memsched trace renders a valid Chrome trace =="
  # --check re-parses the rendered bytes and validates them in-process
  # (every named processor track has >=1 task slice, timestamps monotone
  # non-decreasing), so the smoke needs no external JSON tooling.
  "$BIN" trace --workflow "$TMP/wf.json" --check --out "$TMP/trace.json" 2>"$TMP/trace.err"
  grep -q '"traceEvents"' "$TMP/trace.json" \
    || { echo "trace output missing traceEvents:"; cat "$TMP/trace.json"; exit 1; }
  grep -q '"ph":"X"' "$TMP/trace.json" \
    || { echo "trace output has no task slices:"; cat "$TMP/trace.json"; exit 1; }
  grep -q '"ph":"C"' "$TMP/trace.json" \
    || { echo "trace output has no memory counter track:"; cat "$TMP/trace.json"; exit 1; }
  grep -q 'check passed' "$TMP/trace.err" \
    || { echo "trace --check did not pass:"; cat "$TMP/trace.err"; exit 1; }
  echo "trace self-validates: per-processor slices, memory counter track, monotone timestamps"

  echo "== replay: warm/cold --cache-dir byte-identity + schedules_computed==0 =="
  "$BIN" batch --suite smoke --sigmas 0.1,0.3 --jobs 1 --out "$TMP/nocache.jsonl" 2>/dev/null
  "$BIN" batch --suite smoke --sigmas 0.1,0.3 --jobs 4 --cache-dir "$TMP/cache" \
    --out "$TMP/cold.jsonl" 2>"$TMP/cold.err"
  "$BIN" batch --suite smoke --sigmas 0.1,0.3 --jobs 4 --cache-dir "$TMP/cache" \
    --metrics-json "$TMP/metrics.jsonl" --out "$TMP/warm.jsonl" 2>"$TMP/warm.err"
  cmp "$TMP/nocache.jsonl" "$TMP/cold.jsonl"
  cmp "$TMP/nocache.jsonl" "$TMP/warm.jsonl"
  grep -Eq '"schedules_computed":0[,}]' "$TMP/warm.err" \
    || { echo "warm run did not report schedules_computed=0:"; cat "$TMP/warm.err"; exit 1; }
  # --metrics-json enables tracing for the run (the byte-compare above
  # therefore also exercises the traced==untraced invariant) and writes
  # versioned counter + span-histogram records.
  grep -Eq '"schema":3[,}]' "$TMP/metrics.jsonl" \
    || { echo "metrics JSONL missing schema-3 field:"; cat "$TMP/metrics.jsonl"; exit 1; }
  grep -q '"span"' "$TMP/metrics.jsonl" \
    || { echo "metrics JSONL has no span histograms:"; cat "$TMP/metrics.jsonl"; exit 1; }
  echo "multi-sigma batch byte-identical across jobs and warm/cold cache-dir (warm run traced); warm run computed 0 schedules; metrics JSONL well-formed"

  echo "== replay: warm --cache-dir experiment reuses every schedule =="
  "$BIN" experiment --figure fig8 --scale smoke --sigmas 0.1,0.3 --jobs 4 \
    --cache-dir "$TMP/ecache" > "$TMP/e_cold.csv" 2>/dev/null
  "$BIN" experiment --figure fig8 --scale smoke --sigmas 0.1,0.3 --jobs 4 \
    --cache-dir "$TMP/ecache" > "$TMP/e_warm.csv" 2>"$TMP/e_warm.err"
  cmp "$TMP/multi.csv" "$TMP/e_cold.csv"
  cmp "$TMP/multi.csv" "$TMP/e_warm.csv"
  grep -Eq '"schedules_computed":0[,}]' "$TMP/e_warm.err" \
    || { echo "warm experiment did not report schedules_computed=0:"; cat "$TMP/e_warm.err"; exit 1; }
  echo "experiment tables cache-independent; warm experiment computed 0 schedules"

  echo "== portfolio: batch commits the min-sim candidate and reports the gap =="
  cat > "$TMP/portfolio_jobs.jsonl" <<'EOF'
{"model":"chipseq","input":1,"algo":"portfolio"}
{"model":"eager","input":0,"algo":"portfolio"}
{"model":"chipseq","input":1,"algo":"peft"}
{"model":"bacass","input":0,"algo":"lookahead"}
{"model":"bacass","input":0,"algo":"dls"}
EOF
  "$BIN" batch --input "$TMP/portfolio_jobs.jsonl" --jobs 1 --out "$TMP/pf1.jsonl" 2>/dev/null
  "$BIN" batch --input "$TMP/portfolio_jobs.jsonl" --jobs 4 --out "$TMP/pf4.jsonl" 2>/dev/null
  cmp "$TMP/pf1.jsonl" "$TMP/pf4.jsonl"
  grep -q '"portfolio":{"chosen":' "$TMP/pf1.jsonl" \
    || { echo "portfolio rows missing the decision record:"; cat "$TMP/pf1.jsonl"; exit 1; }
  grep -Eq '"optimality_gap":[0-9]' "$TMP/pf1.jsonl" \
    || { echo "rows missing a numeric optimality_gap:"; cat "$TMP/pf1.jsonl"; exit 1; }
  if grep -q '"optimality_gap":-' "$TMP/pf1.jsonl"; then
    echo "negative optimality_gap in:"; cat "$TMP/pf1.jsonl"; exit 1
  fi
  # The committed algorithm must be the (first-wins) argmin over the
  # candidates' finite simulated makespans — re-derived here from the
  # emitted decision record, independent of the Rust argmin.
  awk '
    /"portfolio":\{"chosen":/ {
      line = $0
      match(line, /"chosen":"[^"]*"/)
      chosen = substr(line, RSTART + 10, RLENGTH - 11)
      n = split(line, parts, /\{"algorithm":"/)
      best = ""; bestv = 0
      for (i = 2; i <= n; i++) {
        alg = substr(parts[i], 1, index(parts[i], "\"") - 1)
        if (match(parts[i], /"sim_makespan":[0-9.eE+-]+/)) {
          v = substr(parts[i], RSTART + 15, RLENGTH - 15) + 0
          if (best == "" || v < bestv) { best = alg; bestv = v }
        }
      }
      if (best != chosen) {
        printf "portfolio commit mismatch: chosen %s but min candidate %s\n", chosen, best
        exit 1
      }
    }
  ' "$TMP/pf1.jsonl"
  echo "portfolio rows byte-identical across workers; committed algo is the min simulated candidate; optimality_gap present and non-negative"

  echo "== serve: daemon round-trip byte-identical to batch; SIGTERM drains and exits 0 =="
  SOCK="$TMP/serve.sock"
  "$BIN" serve --socket "$SOCK" --jobs 2 2>"$TMP/serve.err" &
  SERVE_PID=$!
  for _ in $(seq 1 100); do [ -S "$SOCK" ] && break; sleep 0.05; done
  [ -S "$SOCK" ] || { echo "serve socket never appeared:"; cat "$TMP/serve.err"; exit 1; }
  # Two clients submit the sweep job file used above; each response
  # stream must be byte-identical to the `memsched batch` output for the
  # same file ($TMP/sweep.jsonl), however warm the daemon's caches are.
  "$BIN" client --socket "$SOCK" --input "$TMP/sweep_jobs.jsonl" \
    > "$TMP/serve_c0.jsonl" 2>/dev/null
  "$BIN" client --socket "$SOCK" --input "$TMP/sweep_jobs.jsonl" \
    > "$TMP/serve_c1.jsonl" 2>/dev/null
  cmp "$TMP/sweep.jsonl" "$TMP/serve_c0.jsonl"
  cmp "$TMP/sweep.jsonl" "$TMP/serve_c1.jsonl"
  # A live stats probe: the daemon answers {"ctl":"stats"} with its
  # global counters and per-session summaries, without disturbing it.
  "$BIN" client --socket "$SOCK" --stats > "$TMP/stats.json" 2>/dev/null
  grep -q '"stats"' "$TMP/stats.json" \
    || { echo "stats probe got no stats reply:"; cat "$TMP/stats.json"; exit 1; }
  grep -q '"counters"' "$TMP/stats.json" \
    || { echo "stats reply missing counters:"; cat "$TMP/stats.json"; exit 1; }
  kill -TERM "$SERVE_PID"
  wait "$SERVE_PID"  # set -e: a non-zero daemon exit fails the smoke
  grep -Eq '"name":"c1"[^}]*"schedules_computed":0' "$TMP/serve.err" \
    || { echo "warm client did not report schedules_computed=0:"; cat "$TMP/serve.err"; exit 1; }
  echo "serve responses byte-identical to batch; warm client computed 0 schedules; live stats answered; clean SIGTERM exit"
}

tier_bench() {
  ensure_bin
  echo "== bench: tiny-scale bench_engine + bench_service + bench_replay + bench_recompute -> BENCH_ci.json =="
  rm -f BENCH_ci.json
  # Pinned knobs so entry ids are stable across machines/runs.
  MEMSCHED_BENCH_FAST=1 MEMSCHED_SCORE_THREADS=4 \
    MEMSCHED_BENCH_JSON="$PWD/BENCH_ci.json" \
    cargo bench --bench bench_engine
  MEMSCHED_SUITE_SCALE=smoke MEMSCHED_JOBS=4 \
    MEMSCHED_BENCH_JSON="$PWD/BENCH_ci.json" \
    cargo bench --bench bench_service
  MEMSCHED_BENCH_FAST=1 \
    MEMSCHED_BENCH_JSON="$PWD/BENCH_ci.json" \
    cargo bench --bench bench_replay
  MEMSCHED_BENCH_FAST=1 \
    MEMSCHED_BENCH_JSON="$PWD/BENCH_ci.json" \
    cargo bench --bench bench_recompute
  echo "bench entries:"
  cat BENCH_ci.json
  BASELINE=rust/benches/BENCH_baseline.json
  if [ -f "$BASELINE" ]; then
    echo "== bench: regression gate (>2x vs $BASELINE fails) =="
    "$BIN" bench-check --current BENCH_ci.json --baseline "$BASELINE" --tolerance 2.0
  else
    echo "no checked-in baseline at $BASELINE; run ./ci.sh --bench --seed-baseline"
    echo "on a representative machine and commit the file to enable the gate"
  fi
  if [ "$SEED_BASELINE" = 1 ]; then
    cp BENCH_ci.json "$BASELINE"
    echo "seeded $BASELINE from this run -- commit it so bench-check gates regressions"
  fi
}

tier_crossover() {
  ensure_bin
  echo "== crossover: serial vs pooled scoring across the cluster x fan-in work axis =="
  MEMSCHED_BENCH_CROSSOVER=1 cargo bench --bench bench_engine
}

for tier in "${TIERS[@]}"; do
  "tier_$tier"
done
echo "ci: OK (${TIERS[*]})"
