//! Dynamic scenario walkthrough (paper §V): run one workflow under 10%
//! parameter deviations, once following the static schedule and once with
//! on-the-fly recomputation; then demonstrate the retrace primitive and
//! the AOT online predictor.
//!
//! Run with: `cargo run --release --example adaptive_rescheduling`

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::memory_constrained_cluster;
use memsched::scheduler::{compute_schedule, retrace, Algorithm, EvictionPolicy};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};

fn main() -> anyhow::Result<()> {
    let spec = WorkloadSpec { family: "methylseq".into(), size: Some(1000), input: 3, seed: 11 };
    let wf = spec.build()?;
    let cluster = memory_constrained_cluster();

    let schedule = compute_schedule(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
    println!(
        "static schedule (HEFTM-MM): valid={} makespan={:.1}s",
        schedule.valid, schedule.makespan
    );
    anyhow::ensure!(schedule.valid, "static schedule must be valid for this demo");

    // Retrace against the *actual* parameters (what §V's monitoring would
    // report in one shot).
    let dev = DeviationModel::new(0.1, 99);
    let actual_wf = dev.deviate_workflow(&wf);
    let r = retrace::retrace(&actual_wf, &cluster, &schedule, EvictionPolicy::LargestFirst, &[]);
    println!(
        "retrace under actual parameters: valid={} makespan={:.1}s{}",
        r.valid,
        r.makespan,
        r.failed_task.map(|t| format!(" (first violation at task {t})")).unwrap_or_default()
    );

    // Execute both runtime modes with identical per-task deviations.
    for (label, mode) in
        [("without recomputation", SimMode::FollowStatic), ("with recomputation", SimMode::Recompute)]
    {
        let out = simulate(&wf, &cluster, &schedule, &SimConfig::new(mode, dev));
        match (out.completed, &out.failure) {
            (true, _) => println!(
                "{label:<24}: completed, makespan {:.1}s, {} recomputations",
                out.makespan, out.recomputations
            ),
            (false, f) => println!(
                "{label:<24}: FAILED after {} tasks ({f:?})",
                out.started
            ),
        }
    }

    // Online predictor (§V): refine estimates from observed deviations.
    match memsched::runtime::predictor::Predictor::load_default() {
        Ok(pred) => {
            let mut stats = memsched::runtime::predictor::DeviationStats::default();
            // Pretend the first 50 tasks finished and were observed.
            for v in 0..50.min(wf.num_tasks()) {
                let est = wf.task(v);
                let (aw, am) = dev.actual(v, est.work, est.memory);
                stats.observe(&est.task_type, aw / est.work, am / est.memory);
            }
            println!("\nonline predictor corrections (type: observed -> corrected):");
            for ty in ["bismark_align", "methylation_extract", "fastqc"] {
                if let Some((ow, om)) = stats.mean(ty) {
                    let (cw, cm) = pred.correct(ow, om, 100.0)?;
                    println!("  {ty:<22} work {ow:.3} -> {cw:.3}   mem {om:.3} -> {cm:.3}");
                }
            }
        }
        Err(e) => println!("\npredictor artifact unavailable ({e}); run `make artifacts`"),
    }
    Ok(())
}
