//! Walkthrough of the parallel scheduling service: build a mixed batch of
//! jobs (several workloads × algorithms, a simulation job, and deliberate
//! duplicates), execute it on a multi-threaded service, and inspect the
//! JSONL stream plus the schedule-cache counters.
//!
//! Run with: `cargo run --release --example batch_service`

use std::sync::Arc;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::small_cluster;
use memsched::scheduler::Algorithm;
use memsched::service::{
    self, ClusterSpec, Job, JobSource, SchedulingService, SimJob,
};
use memsched::simulator::SimMode;

fn main() -> anyhow::Result<()> {
    // One shared platform for the whole batch (a job may also name a
    // preset or a cluster JSON file via `ClusterSpec::Named`).
    let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));

    let spec = |family: &str, size: Option<usize>, input: usize| {
        JobSource::Generated(WorkloadSpec { family: family.into(), size, input, seed: 42 })
    };

    let mut jobs = Vec::new();
    // All four algorithms on one 200-task chipseq instance. The four jobs
    // share a single workflow materialization inside the service.
    for algo in Algorithm::all() {
        jobs.push(Job::new(spec("chipseq", Some(200), 2), cluster.clone()).with_algo(algo));
    }
    // A second workload family.
    jobs.push(Job::new(spec("eager", Some(200), 3), cluster.clone()).with_algo(Algorithm::HeftmMm));
    // A dynamic job: schedule + runtime simulation under 10% deviations.
    jobs.push(
        Job::new(spec("methylseq", None, 1), cluster.clone())
            .with_algo(Algorithm::HeftmBl)
            .with_sim(SimJob { mode: SimMode::Recompute, sigma: 0.1, seed: 7 }),
    );
    // Deliberate duplicates: identical requests dedupe to one computation
    // through the content-addressed schedule cache.
    let dup = jobs[1].clone();
    jobs.push(dup.clone());
    jobs.push(dup);

    let service = SchedulingService::new(4);
    println!("submitting {} jobs on {} workers...\n", jobs.len(), service.workers());
    let results = service.run_batch(jobs);

    println!("--- JSONL stream (deterministic: identical bytes for any worker count) ---");
    print!("{}", service::to_jsonl(&results));

    let stats = service.cache_stats();
    println!("\n--- summary ---");
    println!("jobs:               {}", results.len());
    println!("deduped (cache_hit): {}", results.iter().filter(|r| r.cache_hit).count());
    println!("schedules computed: {}", stats.computed);
    println!("cache lookups/hits: {}/{}", stats.lookups, stats.hits());

    anyhow::ensure!(
        results.iter().filter(|r| r.cache_hit).count() >= 2,
        "the duplicate jobs must be served from the cache"
    );
    anyhow::ensure!(results.iter().all(|r| r.error.is_none()), "all jobs must succeed");
    Ok(())
}
