//! End-to-end driver: exercises the full system — workload generation,
//! trace binding, all four schedulers on both clusters, and the dynamic
//! runtime with deviations + recomputation — and reports the paper's
//! headline metrics side by side with the expected values.
//!
//! This is the run recorded in EXPERIMENTS.md. Scale via
//! `MEMSCHED_SUITE_SCALE=smoke|quick|full` (default quick).
//!
//! Run with: `cargo run --release --example end_to_end`

use memsched::experiments::{self, figures, SuiteScale};
use memsched::platform::presets::{default_cluster, memory_constrained_cluster};
use memsched::scheduler::Algorithm;

fn main() -> anyhow::Result<()> {
    let scale: SuiteScale = std::env::var("MEMSCHED_SUITE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(SuiteScale::Quick);
    let seed = 42;
    let t0 = std::time::Instant::now();

    // ---------------------------------------------------------------- static
    println!("### Static evaluation (suite scale {scale:?})\n");
    let mut all_static = Vec::new();
    for cluster in [default_cluster(), memory_constrained_cluster()] {
        let specs = experiments::suite(scale, seed);
        let mut results = Vec::new();
        for (i, spec) in specs.iter().enumerate() {
            eprint!("\r{} [{}/{}] {}        ", cluster.name, i + 1, specs.len(), spec.id());
            results.extend(experiments::run_static(spec, &cluster)?);
        }
        eprintln!();
        println!("-- success rates (%), cluster `{}` --", cluster.name);
        print!("{}", figures::success_rates(&results).to_markdown());
        println!("-- relative makespans (vs HEFT), cluster `{}` --", cluster.name);
        print!("{}", figures::relative_makespans(&results).to_markdown());
        println!("-- memory usage (%), cluster `{}` --", cluster.name);
        print!("{}", figures::memory_usage(&results, false).to_markdown());
        println!();
        all_static.push((cluster.name.clone(), results));
    }

    // --------------------------------------------------------------- dynamic
    println!("### Dynamic evaluation (sigma = 10%, memory-constrained cluster)\n");
    let cluster = memory_constrained_cluster();
    let specs: Vec<_> = experiments::suite(scale, seed)
        .into_iter()
        .filter(|s| s.size.is_none_or(|n| n <= 2000))
        .collect();
    let mut dynamic = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        eprint!("\rdynamic [{}/{}] {}        ", i + 1, specs.len(), spec.id());
        for algo in Algorithm::all() {
            dynamic.push(experiments::run_dynamic(spec, &cluster, algo, 0.1)?);
        }
    }
    eprintln!();
    println!("-- validity counts (§VI-C) --");
    print!("{}", figures::dynamic_validity(&dynamic).to_markdown());
    println!("-- makespan improvement of recomputation (%) (Fig 8) --");
    print!("{}", figures::dynamic_improvement(&dynamic).to_markdown());

    // -------------------------------------------------------------- headline
    println!("\n### Headline checks vs paper\n");
    let (_, default_results) = &all_static[0];
    let (_, constrained_results) = &all_static[1];
    let rate = |rs: &[experiments::StaticResult], algo: Algorithm| {
        let xs: Vec<_> = rs.iter().filter(|r| r.algo == algo).collect();
        100.0 * xs.iter().filter(|r| r.valid).count() as f64 / xs.len().max(1) as f64
    };
    println!("| metric | paper | measured |");
    println!("|---|---|---|");
    println!(
        "| HEFT success, default cluster | 24.2% | {:.1}% |",
        rate(default_results, Algorithm::Heft)
    );
    for algo in [Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm] {
        println!(
            "| {} success, default cluster | 100% | {:.1}% |",
            algo.label(),
            rate(default_results, algo)
        );
    }
    println!(
        "| HEFT success, constrained | 4.8% | {:.1}% |",
        rate(constrained_results, Algorithm::Heft)
    );
    println!(
        "| HEFTM-BL success, constrained | 38% | {:.1}% |",
        rate(constrained_results, Algorithm::HeftmBl)
    );
    println!(
        "| HEFTM-BLC success, constrained | 49% | {:.1}% |",
        rate(constrained_results, Algorithm::HeftmBlc)
    );
    println!(
        "| HEFTM-MM success, constrained | 100% | {:.1}% |",
        rate(constrained_results, Algorithm::HeftmMm)
    );
    let surv = |ok: usize, total: usize| 100.0 * ok as f64 / total.max(1) as f64;
    let no_rec_ok = dynamic.iter().filter(|r| r.static_ok).count();
    let rec_ok = dynamic.iter().filter(|r| r.recompute_ok).count();
    let init_ok = dynamic.iter().filter(|r| r.initially_valid).count();
    println!(
        "| dynamic: survive w/o recompute | 11.6% (134/1160) | {:.1}% ({}/{}) |",
        surv(no_rec_ok, dynamic.len()),
        no_rec_ok,
        dynamic.len()
    );
    println!(
        "| dynamic: recompute keeps valid | ~100% of initial | {:.1}% ({}/{}) |",
        surv(rec_ok, init_ok),
        rec_ok,
        init_ok
    );
    println!("\ntotal wall time: {}", memsched::bench::fmt_duration(t0.elapsed()));
    Ok(())
}
