//! Domain scenario: scheduling an ancient-DNA analysis campaign
//! (nf-core/eager-like, 2 000 tasks) on the memory-constrained cluster —
//! the situation the paper's introduction motivates: a memory-oblivious
//! scheduler produces plans that die at runtime, while the memory-aware
//! heuristics trade a little makespan for guaranteed-fit schedules.
//!
//! Run with: `cargo run --release --example genomics_pipeline`

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::memory_constrained_cluster;
use memsched::scheduler::{compute_schedule, Algorithm, EvictionPolicy};

fn main() -> anyhow::Result<()> {
    let spec = WorkloadSpec { family: "eager".into(), size: Some(2000), input: 4, seed: 7 };
    let wf = spec.build()?;
    let cluster = memory_constrained_cluster();
    println!(
        "workflow `{}`: {} tasks, {} edges, depth {}",
        wf.name,
        wf.num_tasks(),
        wf.num_edges(),
        wf.stats().depth
    );
    println!("cluster `{}`: {} processors\n", cluster.name, cluster.len());

    println!(
        "{:<10} {:>6} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "algo", "valid", "makespan(s)", "mem(%)", "procs", "evicted", "time(ms)"
    );
    for algo in Algorithm::all() {
        let t0 = std::time::Instant::now();
        let s = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        let evicted: usize = s.tasks.iter().map(|t| t.evicted.len()).sum();
        println!(
            "{:<10} {:>6} {:>12.1} {:>10.1} {:>10} {:>10} {:>10.1}",
            s.algorithm.label(),
            s.valid,
            s.makespan,
            100.0 * s.mean_mem_usage(),
            s.procs_used(),
            evicted,
            dt
        );
    }

    // Both eviction policies (paper: "comparable results").
    println!("\neviction policy comparison (HEFTM-BL):");
    for policy in [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst] {
        let s = compute_schedule(&wf, &cluster, Algorithm::HeftmBl, policy);
        println!(
            "  {:?}: valid={} makespan={:.1}s evictions={}",
            policy,
            s.valid,
            s.makespan,
            s.tasks.iter().map(|t| t.evicted.len()).sum::<usize>()
        );
    }
    Ok(())
}
