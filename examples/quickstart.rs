//! Quickstart: build a small workflow by hand, schedule it with a
//! memory-aware heuristic, and inspect the placements.
//!
//! Run with: `cargo run --release --example quickstart`

use memsched::platform::presets::small_cluster;
use memsched::scheduler::{compute_schedule, Algorithm, EvictionPolicy};
use memsched::workflow::WorkflowBuilder;

fn main() -> anyhow::Result<()> {
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;

    // A toy variant-calling pipeline: QC fans out per sample, alignment is
    // heavy, a final joint step gathers everything.
    let mut b = WorkflowBuilder::new("toy_pipeline");
    let qc: Vec<_> =
        (0..4).map(|i| b.task(format!("qc_{i}"), "fastqc", 5.0, 0.2 * GB)).collect();
    let align: Vec<_> =
        (0..4).map(|i| b.task(format!("align_{i}"), "bwa", 120.0, 6.0 * GB)).collect();
    let joint = b.task("joint_call", "gatk", 200.0, 10.0 * GB);
    for i in 0..4 {
        b.edge(qc[i], align[i], 0.5 * GB);
        b.edge(align[i], joint, 1.0 * GB);
    }
    let wf = b.build()?;

    // Table II machines, one of each kind.
    let cluster = small_cluster();

    for algo in [Algorithm::Heft, Algorithm::HeftmBl, Algorithm::HeftmMm] {
        let s = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
        println!("=== {} ===", algo.label());
        println!("valid: {}   makespan: {:.1}s   peak mem: {:.0}%",
            s.valid, s.makespan, 100.0 * s.mean_mem_usage());
        println!("{:<12} {:>6} {:>10} {:>10}", "task", "proc", "start", "finish");
        for (v, t) in s.tasks.iter().enumerate() {
            println!(
                "{:<12} {:>6} {:>10.1} {:>10.1}",
                wf.task(v).name,
                cluster.proc(t.proc).name,
                t.start,
                t.finish
            );
        }
        println!();
    }
    Ok(())
}
