//! The three-layer stack in action: the Rust coordinator driving the
//! AOT-compiled XLA artifact (whose inner kernels are Pallas) through the
//! PJRT CPU client, as an alternative EFT-scoring backend for the
//! scheduler's inner loop.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example xla_scoring`

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::small_cluster;
use memsched::runtime::scorer::{NativeScorer, XlaScorer};
use memsched::scheduler::engine::EftScorer;
use memsched::scheduler::{Algorithm, Engine, EvictionPolicy};

fn main() -> anyhow::Result<()> {
    let xla = XlaScorer::load_default().map_err(|e| {
        anyhow::anyhow!("failed to load artifacts ({e}); run `make artifacts` first")
    })?;
    println!("loaded artifacts/eft_score.hlo.txt on PJRT CPU client");

    let spec = WorkloadSpec { family: "atacseq".into(), size: Some(200), input: 2, seed: 5 };
    let wf = spec.build()?;
    let cluster = small_cluster();
    let order = Algorithm::HeftmBl.rank_order(&wf, &cluster);

    // Schedule with each scoring backend and compare.
    let t0 = std::time::Instant::now();
    let native_schedule = Engine::new(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst)
        .run(&order);
    let t_native = t0.elapsed();
    let t0 = std::time::Instant::now();
    let xla_schedule = Engine::new(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst)
        .with_scorer(&xla)
        .run(&order);
    let t_xla = t0.elapsed();

    println!("\n{:<18} {:>10} {:>14} {:>12}", "backend", "valid", "makespan(s)", "time");
    println!(
        "{:<18} {:>10} {:>14.2} {:>12}",
        "native (rust)",
        native_schedule.valid,
        native_schedule.makespan,
        memsched::bench::fmt_duration(t_native)
    );
    println!(
        "{:<18} {:>10} {:>14.2} {:>12}",
        "xla (PJRT)",
        xla_schedule.valid,
        xla_schedule.makespan,
        memsched::bench::fmt_duration(t_xla)
    );
    let rel = (native_schedule.makespan - xla_schedule.makespan).abs()
        / native_schedule.makespan.max(1e-9);
    println!("makespan agreement: {:.4}% difference", 100.0 * rel);
    anyhow::ensure!(rel < 0.01, "backends diverged beyond f32 tie-breaking");

    // Per-call parity spot check (queries borrow a reusable arena).
    let bufs = memsched::scheduler::ScoreBuffers {
        proc_ready: vec![0.0, 5.0, 2.0],
        speeds: vec![1.0, 2.0, 4.0],
        avail_mem: vec![100.0, 50.0, 10.0],
        parents: vec![
            memsched::scheduler::engine::ParentInfo { finish: 3.0, data: 10.0, proc: 0 },
            memsched::scheduler::engine::ParentInfo { finish: 4.0, data: 20.0, proc: 1 },
        ],
        // Row-major parents × procs.
        comm: vec![0.0, 1.0, 0.0, 2.0, 0.0, 6.0],
        work: 8.0,
        memory: 30.0,
        out_total: 5.0,
        bandwidth: 10.0,
        ..Default::default()
    };
    let (mut nft, mut nres) = (vec![0.0; 3], vec![0.0; 3]);
    NativeScorer.score(&bufs.query(), &mut nft, &mut nres);
    let (mut xft, mut xres) = (vec![0.0; 3], vec![0.0; 3]);
    xla.score(&bufs.query(), &mut xft, &mut xres);
    println!("\nper-call parity (ft): native {nft:?} vs xla {xft:?}");
    Ok(())
}
