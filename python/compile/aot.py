"""AOT export: lower the L2 computations to HLO *text* artifacts that the
Rust coordinator loads via the PJRT C API.

HLO text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit
instruction ids which the xla crate's XLA (xla_extension 0.5.1) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and gen_hlo.py.

Artifacts:
  artifacts/eft_score.hlo.txt   fused Step 2+3 scoring (Pallas kernels)
  artifacts/predictor.hlo.txt   online resource predictor (§V)
  artifacts/meta.json           export shapes + provenance

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.eft import PAD_PARENTS, PAD_PROCS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_eft_score() -> str:
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.eft_score).lower(
        spec((PAD_PROCS,), f32),              # ready
        spec((PAD_PROCS,), f32),              # speed
        spec((PAD_PROCS,), f32),              # avail
        spec((PAD_PARENTS,), f32),            # pft
        spec((PAD_PARENTS,), f32),            # pc
        spec((PAD_PARENTS, PAD_PROCS), f32),  # comm
        spec((PAD_PARENTS, PAD_PROCS), f32),  # mask
        spec((4,), f32),                      # scalars
    )
    return to_hlo_text(lowered)


def export_predictor(seed: int) -> str:
    weights = model.fit_predictor(seed)
    fn = model.make_predictor_fn(weights)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((model.PREDICTOR_FEATURES,), jnp.float32)
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    # Back-compat: allow `--out <file>` to mean the eft artifact path.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    eft_text = export_eft_score()
    eft_path = os.path.join(out_dir, "eft_score.hlo.txt")
    with open(eft_path, "w") as f:
        f.write(eft_text)
    print(f"wrote {eft_path} ({len(eft_text)} chars)")

    pred_text = export_predictor(args.seed)
    pred_path = os.path.join(out_dir, "predictor.hlo.txt")
    with open(pred_path, "w") as f:
        f.write(pred_text)
    print(f"wrote {pred_path} ({len(pred_text)} chars)")

    meta = {
        "pad_procs": PAD_PROCS,
        "pad_parents": PAD_PARENTS,
        "predictor_features": model.PREDICTOR_FEATURES,
        "predictor_outputs": model.PREDICTOR_OUTPUTS,
        "jax_version": jax.__version__,
        "seed": args.seed,
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
