"""Layer-1 Pallas kernel: batched earliest-finish-time (Step 3 of §IV-B).

For one task `v` and all processors `j` at once:

    arrival[p, j] = mask[p, j] * (max(pft[p], comm[p, j]) + pc[p] * inv_beta)
    st[j]         = max(ready[j], max_p arrival[p, j])
    ft[j]         = st[j] + w / speed[j]

Shapes are fixed at export time: K processors (padded), P parents (padded).
`mask[p, j] = 1` iff parent `p` exists and is *remote* to processor `j`
(same-processor parents contribute no communication).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): this is a VPU-bound
masked max-reduction over a (P, K) tile. The whole tile fits VMEM
comfortably (32×128 f32 = 16 KiB), so a single grid step with the K axis
on lanes is the natural TPU mapping. `interpret=True` everywhere: the CPU
PJRT client cannot execute Mosaic custom-calls (see /opt/xla-example
README); the kernel still lowers into the same HLO module the Rust
runtime loads.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Export-time padded shapes (must match rust/src/runtime/scorer.rs).
PAD_PROCS = 128
PAD_PARENTS = 32


def _eft_kernel(ready_ref, speed_ref, pft_ref, pc_ref, comm_ref, mask_ref,
                scalars_ref, ft_ref):
    """Pallas kernel body: one (P, K) tile, K on the lane axis."""
    ready = ready_ref[...]            # [K]
    speed = speed_ref[...]            # [K]
    pft = pft_ref[...]                # [P]
    pc = pc_ref[...]                  # [P]
    comm = comm_ref[...]              # [P, K]
    mask = mask_ref[...]              # [P, K]
    w = scalars_ref[0]
    inv_beta = scalars_ref[3]

    # Channel availability: the transfer starts when both the producer has
    # finished and the channel is free.
    start = jnp.maximum(pft[:, None], comm)               # [P, K]
    arrival = start + pc[:, None] * inv_beta              # [P, K]
    # Masked max over parents: non-remote/padded entries contribute 0
    # (arrival times are nonnegative, ready >= 0, so 0 is neutral).
    arrival = jnp.where(mask > 0.0, arrival, 0.0)
    st = jnp.maximum(ready, jnp.max(arrival, axis=0))     # [K]
    ft_ref[...] = st + w / speed


def eft_times(ready, speed, pft, pc, comm, mask, scalars):
    """Invoke the Pallas EFT kernel (interpret mode)."""
    k = ready.shape[0]
    return pl.pallas_call(
        _eft_kernel,
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(ready, speed, pft, pc, comm, mask, scalars)


@partial(jax.jit, static_argnames=())
def eft_times_jit(ready, speed, pft, pc, comm, mask, scalars):
    return eft_times(ready, speed, pft, pc, comm, mask, scalars)
