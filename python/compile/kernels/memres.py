"""Layer-1 Pallas kernel: batched memory residual (Step 2 of §IV-B).

For one task `v` and all processors `j`:

    rem_in[j] = sum_p mask[p, j] * pc[p]        (remote input volume)
    res[j]    = avail[j] - m_v - rem_in[j] - out_total

`res[j] < 0` means placing `v` on `p_j` requires evicting pending files
into the communication buffer (handled exactly on the Rust side).

Like `eft.py`, a single-tile VPU reduction in interpret mode.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _memres_kernel(avail_ref, pc_ref, mask_ref, scalars_ref, res_ref):
    avail = avail_ref[...]            # [K]
    pc = pc_ref[...]                  # [P]
    mask = mask_ref[...]              # [P, K]
    m_v = scalars_ref[1]
    out_total = scalars_ref[2]
    rem_in = jnp.sum(mask * pc[:, None], axis=0)          # [K]
    res_ref[...] = avail - m_v - rem_in - out_total


def mem_residuals(avail, pc, mask, scalars):
    """Invoke the Pallas memory-residual kernel (interpret mode)."""
    k = avail.shape[0]
    return pl.pallas_call(
        _memres_kernel,
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(avail, pc, mask, scalars)
