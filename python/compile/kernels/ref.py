"""Pure-jnp oracle for the Pallas kernels (the CORE correctness signal).

Deliberately written in the most direct style possible — vectorized jnp
ops with no cleverness — so that a disagreement with the kernels indicates
a kernel bug, not an oracle bug.
"""

import jax.numpy as jnp


def eft_times_ref(ready, speed, pft, pc, comm, mask, scalars):
    """Reference Step-3 finish times. Same shapes as kernels.eft."""
    w = scalars[0]
    inv_beta = scalars[3]
    start = jnp.maximum(pft[:, None], comm)
    arrival = jnp.where(mask > 0.0, start + pc[:, None] * inv_beta, 0.0)
    st = jnp.maximum(ready, jnp.max(arrival, axis=0))
    return st + w / speed


def mem_residuals_ref(avail, pc, mask, scalars):
    """Reference Step-2 memory residuals. Same shapes as kernels.memres."""
    m_v = scalars[1]
    out_total = scalars[2]
    rem_in = jnp.sum(mask * pc[:, None], axis=0)
    return avail - m_v - rem_in - out_total


def eft_score_ref(ready, speed, avail, pft, pc, comm, mask, scalars):
    """Reference for the fused L2 computation (model.eft_score)."""
    return (
        eft_times_ref(ready, speed, pft, pc, comm, mask, scalars),
        mem_residuals_ref(avail, pc, mask, scalars),
    )
