"""Layer-2 JAX model: the fused placement-scoring computation and the
online resource predictor (build-time only; never imported at runtime).

`eft_score` composes the two Pallas kernels (Steps 2–3 of §IV-B) into the
single computation the Rust coordinator executes per task via PJRT.

`predictor` is the §V online-prediction component: scientific-workflow
resource estimates carry a ~15% cold-start error that online methods can
reduce by up to a third ([5], [24], [32] in the paper). We model it as a
ridge regression from observed deviation statistics to a corrected
multiplicative factor, fitted in closed form at AOT time on synthetic
deviation data and exported as a second XLA artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.eft import eft_times
from .kernels.memres import mem_residuals


def eft_score(ready, speed, avail, pft, pc, comm, mask, scalars):
    """Fused tentative-assignment scoring: (ft[K], res[K]).

    Arguments (all f32):
      ready   [K]     processor ready times rt_j
      speed   [K]     processor speeds s_j
      avail   [K]     available memories availM_j
      pft     [P]     parent finish times FT(u)
      pc      [P]     parent file sizes c_{u,v}
      comm    [P, K]  channel ready times rt_{proc(u), j}
      mask    [P, K]  1 if parent p exists and is remote to processor j
      scalars [4]     (w_v, m_v, out_total, 1/beta)
    """
    ft = eft_times(ready, speed, pft, pc, comm, mask, scalars)
    res = mem_residuals(avail, pc, mask, scalars)
    return ft, res


# ---------------------------------------------------------------------------
# Online resource predictor (§V).

#: Feature vector: [est_ratio_bias(=1), mean_obs_work_ratio,
#:                  mean_obs_mem_ratio, log10(est_work)]
PREDICTOR_FEATURES = 4
#: Outputs: corrected (work_ratio, mem_ratio) multipliers.
PREDICTOR_OUTPUTS = 2


def predictor_apply(weights, features):
    """Linear predictor: features [F] -> corrected ratios [2].

    `weights` has shape [F, 2]; baked as a constant at AOT export.
    """
    return features @ weights


def synth_deviation_data(rng: np.random.Generator, n: int = 4096):
    """Synthetic training set mirroring the runtime's deviation process.

    A task type's true resource ratio r ~ N(1, 0.15) (cold-start error);
    the runtime observes a noisy mean ratio over a handful of finished
    instances; the predictor should shrink the observation toward it.
    """
    true_w = rng.normal(1.0, 0.15, size=n)
    true_m = rng.normal(1.0, 0.15, size=n)
    k_obs = rng.integers(1, 8, size=n)
    obs_w = true_w + rng.normal(0, 0.10, size=n) / np.sqrt(k_obs)
    obs_m = true_m + rng.normal(0, 0.10, size=n) / np.sqrt(k_obs)
    logw = rng.uniform(-1.0, 3.0, size=n)
    x = np.stack([np.ones(n), obs_w, obs_m, logw], axis=1).astype(np.float32)
    y = np.stack([true_w, true_m], axis=1).astype(np.float32)
    return x, y


def fit_predictor(seed: int = 0, ridge: float = 1e-2) -> np.ndarray:
    """Closed-form ridge regression: weights [F, 2]."""
    rng = np.random.default_rng(seed)
    x, y = synth_deviation_data(rng)
    f = x.shape[1]
    a = x.T @ x + ridge * np.eye(f, dtype=np.float32)
    w = np.linalg.solve(a, x.T @ y)
    return w.astype(np.float32)


def make_predictor_fn(weights: np.ndarray):
    """Bind fitted weights as constants; returns features [F] -> [2]."""
    w = jnp.asarray(weights)

    def fn(features):
        return (predictor_apply(w, features),)

    return fn
