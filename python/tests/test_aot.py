"""AOT export tests: the HLO text artifacts must exist after lowering and
contain a parseable ENTRY computation (the Rust loader's contract)."""

from compile import aot


def test_eft_export_produces_hlo_text():
    text = aot.export_eft_score()
    assert "ENTRY" in text
    assert "f32[128]" in text  # padded processor axis appears
    assert len(text) > 500


def test_predictor_export_produces_hlo_text():
    text = aot.export_predictor(seed=0)
    assert "ENTRY" in text
    assert "f32[" in text


def test_exports_are_deterministic():
    assert aot.export_predictor(seed=0) == aot.export_predictor(seed=0)
