"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps input contents and (logical) shapes; logical sizes are
padded to the export shapes exactly as the Rust runtime does, so these
tests also pin the padding semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.eft import PAD_PARENTS, PAD_PROCS, eft_times
from compile.kernels.memres import mem_residuals


def pad_inputs(rng, k, p):
    """Random logical (k, p) problem padded to (PAD_PROCS, PAD_PARENTS)."""
    ready = np.zeros(PAD_PROCS, np.float32)
    speed = np.ones(PAD_PROCS, np.float32)
    avail = np.full(PAD_PROCS, -1e30, np.float32)
    ready[:k] = rng.uniform(0, 100, k)
    ready[k:] = 1e30
    speed[:k] = rng.uniform(0.5, 32, k)
    avail[:k] = rng.uniform(0, 64e9, k)

    pft = np.zeros(PAD_PARENTS, np.float32)
    pc = np.zeros(PAD_PARENTS, np.float32)
    comm = np.zeros((PAD_PARENTS, PAD_PROCS), np.float32)
    mask = np.zeros((PAD_PARENTS, PAD_PROCS), np.float32)
    pft[:p] = rng.uniform(0, 100, p)
    pc[:p] = rng.uniform(0, 1e9, p)
    comm[:p, :k] = rng.uniform(0, 100, (p, k))
    # Each parent on a random processor -> remote mask elsewhere.
    for i in range(p):
        proc = rng.integers(0, k)
        mask[i, :k] = 1.0
        mask[i, proc] = 0.0

    scalars = np.array(
        [rng.uniform(0.1, 500), rng.uniform(0, 8e9), rng.uniform(0, 4e9), 1e-9],
        np.float32,
    )
    return ready, speed, avail, pft, pc, comm, mask, scalars


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=PAD_PROCS),
    p=st.integers(min_value=0, max_value=PAD_PARENTS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_eft_kernel_matches_ref(k, p, seed):
    rng = np.random.default_rng(seed)
    ready, speed, avail, pft, pc, comm, mask, scalars = pad_inputs(rng, k, p)
    got = eft_times(ready, speed, pft, pc, comm, mask, scalars)
    want = ref.eft_times_ref(
        jnp.asarray(ready), jnp.asarray(speed), jnp.asarray(pft),
        jnp.asarray(pc), jnp.asarray(comm), jnp.asarray(mask),
        jnp.asarray(scalars),
    )
    np.testing.assert_allclose(np.asarray(got)[:k], np.asarray(want)[:k],
                               rtol=1e-5, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=PAD_PROCS),
    p=st.integers(min_value=0, max_value=PAD_PARENTS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_memres_kernel_matches_ref(k, p, seed):
    rng = np.random.default_rng(seed)
    _, _, avail, _, pc, _, mask, scalars = pad_inputs(rng, k, p)
    got = mem_residuals(avail, pc, mask, scalars)
    want = ref.mem_residuals_ref(
        jnp.asarray(avail), jnp.asarray(pc), jnp.asarray(mask),
        jnp.asarray(scalars),
    )
    # Magnitudes reach ~1e10; f32 tolerance scaled accordingly.
    np.testing.assert_allclose(np.asarray(got)[:k], np.asarray(want)[:k],
                               rtol=1e-5, atol=1e4)


def test_eft_hand_example():
    """The exact hand-computed example from rust scorer unit tests."""
    ready = np.zeros(PAD_PROCS, np.float32)
    speed = np.ones(PAD_PROCS, np.float32)
    ready[:3] = [0.0, 5.0, 2.0]
    ready[3:] = 1e30
    speed[:3] = [1.0, 2.0, 4.0]
    pft = np.zeros(PAD_PARENTS, np.float32)
    pc = np.zeros(PAD_PARENTS, np.float32)
    comm = np.zeros((PAD_PARENTS, PAD_PROCS), np.float32)
    mask = np.zeros((PAD_PARENTS, PAD_PROCS), np.float32)
    pft[:2] = [3.0, 4.0]
    pc[:2] = [10.0, 20.0]
    comm[0, :3] = [0.0, 1.0, 0.0]
    comm[1, :3] = [2.0, 0.0, 6.0]
    mask[0, :3] = [0.0, 1.0, 1.0]  # parent 0 on proc 0
    mask[1, :3] = [1.0, 0.0, 1.0]  # parent 1 on proc 1
    scalars = np.array([8.0, 30.0, 5.0, 0.1], np.float32)
    ft = np.asarray(eft_times(ready, speed, pft, pc, comm, mask, scalars))
    np.testing.assert_allclose(ft[:3], [14.0, 9.0, 10.0], rtol=1e-6)


def test_parent_on_same_proc_contributes_nothing():
    rng = np.random.default_rng(0)
    ready, speed, avail, pft, pc, comm, mask, scalars = pad_inputs(rng, 4, 3)
    # Zero the mask entirely: finish time must be ready + w/speed exactly.
    mask[:] = 0.0
    ft = np.asarray(eft_times(ready, speed, pft, pc, comm, mask, scalars))
    np.testing.assert_allclose(
        ft[:4], ready[:4] + scalars[0] / speed[:4], rtol=1e-6
    )


def test_padded_procs_never_win():
    rng = np.random.default_rng(1)
    ready, speed, avail, pft, pc, comm, mask, scalars = pad_inputs(rng, 5, 2)
    ft = np.asarray(eft_times(ready, speed, pft, pc, comm, mask, scalars))
    assert ft[:5].max() < ft[5:].min()
