"""L2 model tests: fused scoring shape/semantics and predictor quality."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref
from compile.kernels.eft import PAD_PARENTS, PAD_PROCS


def test_eft_score_matches_ref():
    rng = np.random.default_rng(7)
    ready = rng.uniform(0, 10, PAD_PROCS).astype(np.float32)
    speed = rng.uniform(1, 8, PAD_PROCS).astype(np.float32)
    avail = rng.uniform(0, 1e9, PAD_PROCS).astype(np.float32)
    pft = rng.uniform(0, 10, PAD_PARENTS).astype(np.float32)
    pc = rng.uniform(0, 1e6, PAD_PARENTS).astype(np.float32)
    comm = rng.uniform(0, 10, (PAD_PARENTS, PAD_PROCS)).astype(np.float32)
    mask = (rng.uniform(size=(PAD_PARENTS, PAD_PROCS)) > 0.5).astype(np.float32)
    scalars = np.array([5.0, 1e8, 2e7, 1e-9], np.float32)

    ft, res = model.eft_score(ready, speed, avail, pft, pc, comm, mask, scalars)
    ft_r, res_r = ref.eft_score_ref(
        *(jnp.asarray(x) for x in (ready, speed, avail, pft, pc, comm, mask, scalars))
    )
    np.testing.assert_allclose(np.asarray(ft), np.asarray(ft_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res), np.asarray(res_r), rtol=1e-5, atol=10.0)


def test_eft_score_jits():
    """The fused computation must lower under jit (the AOT path)."""
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.eft_score).lower(
        spec((PAD_PROCS,), f32), spec((PAD_PROCS,), f32), spec((PAD_PROCS,), f32),
        spec((PAD_PARENTS,), f32), spec((PAD_PARENTS,), f32),
        spec((PAD_PARENTS, PAD_PROCS), f32), spec((PAD_PARENTS, PAD_PROCS), f32),
        spec((4,), f32),
    )
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:10000].lower() or True
    # Executes under jit too.
    ft, res = jax.jit(model.eft_score)(
        jnp.zeros(PAD_PROCS, f32), jnp.ones(PAD_PROCS, f32), jnp.zeros(PAD_PROCS, f32),
        jnp.zeros(PAD_PARENTS, f32), jnp.zeros(PAD_PARENTS, f32),
        jnp.zeros((PAD_PARENTS, PAD_PROCS), f32), jnp.zeros((PAD_PARENTS, PAD_PROCS), f32),
        jnp.zeros(4, f32),
    )
    assert ft.shape == (PAD_PROCS,)
    assert res.shape == (PAD_PROCS,)


def test_predictor_beats_raw_observation():
    """The fitted ridge predictor must reduce squared error vs using the
    noisy observed ratio directly (the §V 'online refinement' claim)."""
    w = model.fit_predictor(seed=0)
    rng = np.random.default_rng(123)
    x, y = model.synth_deviation_data(rng, n=2000)
    pred = x @ w
    raw = x[:, 1:3]  # observed ratios as-is
    err_pred = np.mean((pred - y) ** 2)
    err_raw = np.mean((raw - y) ** 2)
    assert err_pred < err_raw, (err_pred, err_raw)


def test_predictor_fn_is_deterministic_and_sane():
    w = model.fit_predictor(seed=0)
    fn = model.make_predictor_fn(w)
    f = jnp.array([1.0, 1.1, 0.9, 1.5], jnp.float32)
    (out,) = fn(f)
    assert out.shape == (2,)
    # Corrected ratios stay near the observation.
    assert 0.5 < float(out[0]) < 1.5
    assert 0.5 < float(out[1]) < 1.5
    w2 = model.fit_predictor(seed=0)
    np.testing.assert_array_equal(w, w2)
