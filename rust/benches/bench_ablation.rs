//! Ablations over the design choices DESIGN.md calls out (not in the
//! paper's figures, but justifying its model):
//!
//! 1. **Communication-buffer size** (`MC = f × M`, paper fixes f = 10):
//!    eviction headroom is what lets HEFTM-BL survive — shrinking the
//!    buffer should collapse its success rate while HEFTM-MM, which
//!    barely evicts, stays at 100%.
//! 2. **Eviction policy** (largest- vs smallest-first; paper §VI-B:
//!    "comparable results").
//! 3. **Bandwidth sensitivity**: β scales communication, trading comm
//!    time against memory residency.

mod common;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::memory_constrained_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};

fn workloads() -> Vec<memsched::workflow::Workflow> {
    let mut out = Vec::new();
    for family in ["chipseq", "eager", "methylseq", "atacseq"] {
        for size in [2000usize, 10000] {
            for input in [3usize, 4] {
                let spec = WorkloadSpec {
                    family: family.into(),
                    size: Some(size),
                    input,
                    seed: 42 ^ size as u64,
                };
                out.push(spec.build().expect("workload builds"));
            }
        }
    }
    out
}

fn main() {
    let wfs = workloads();
    println!("== ablations over {} workloads (constrained cluster) ==\n", wfs.len());

    // 1. Buffer-size sweep.
    println!("-- ablation 1: comm-buffer factor (success rate %) --");
    println!("{:<10} {:>10} {:>10} {:>10}", "factor", "HEFTM-BL", "HEFTM-MM", "HEFT");
    for factor in [0.0, 1.0, 5.0, 10.0] {
        let mut cluster = memory_constrained_cluster();
        for p in &mut cluster.processors {
            p.comm_buffer = factor * p.memory;
        }
        let mut rates = Vec::new();
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm, Algorithm::Heft] {
            let ok = wfs
                .iter()
                .filter(|wf| {
                    ScheduleRequest::new(wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run().valid
                })
                .count();
            rates.push(100.0 * ok as f64 / wfs.len() as f64);
        }
        println!("{:<10} {:>10.1} {:>10.1} {:>10.1}", factor, rates[0], rates[1], rates[2]);
    }

    // 2. Eviction policy.
    println!("\n-- ablation 2: eviction policy (HEFTM-BL) --");
    let cluster = memory_constrained_cluster();
    for policy in [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst] {
        let (mut ok, mut evictions, mut makespan_sum, mut valid_n) = (0usize, 0usize, 0.0, 0usize);
        for wf in &wfs {
            let s = ScheduleRequest::new(wf, &cluster).algo(Algorithm::HeftmBl).policy(policy).run();
            if s.valid {
                ok += 1;
                makespan_sum += s.makespan;
                valid_n += 1;
            }
            evictions += s.tasks.iter().map(|t| t.evicted.len()).sum::<usize>();
        }
        println!(
            "{policy:?}: success {}/{}  evictions {}  mean makespan {:.0}s",
            ok,
            wfs.len(),
            evictions,
            makespan_sum / valid_n.max(1) as f64
        );
    }

    // 3. Bandwidth sweep.
    println!("\n-- ablation 3: bandwidth (HEFTM-BL mean makespan, valid only) --");
    for scale in [0.25, 1.0, 4.0] {
        let mut cluster = memory_constrained_cluster();
        cluster.bandwidth *= scale;
        let (mut sum, mut n, mut ok) = (0.0, 0usize, 0usize);
        for wf in &wfs {
            let s = ScheduleRequest::new(wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
            if s.valid {
                sum += s.makespan;
                n += 1;
                ok += 1;
            }
        }
        println!(
            "beta x{scale:<5}: success {}/{}  mean makespan {:.0}s",
            ok,
            wfs.len(),
            sum / n.max(1) as f64
        );
    }
}
