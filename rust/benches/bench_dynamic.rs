//! Figure 8 and the §VI-C validity counts: dynamic executions on the
//! memory-constrained cluster with 10% parameter deviations, with and
//! without schedule recomputation.
//!
//! Expected shape (paper): without recomputation most executions die from
//! memory violations (134/1160 survive); with recomputation nearly every
//! initially-valid schedule survives (HEFTM-MM: all of them), and
//! makespans improve by ~12–24%, growing with workflow size.

mod common;

use memsched::experiments::figures;
use memsched::platform::presets::memory_constrained_cluster;

fn main() {
    let scale = common::scale_from_env();
    let cluster = memory_constrained_cluster();
    println!("== bench_dynamic: suite scale {scale:?}, sigma = 10%, cluster `{}` ==",
        cluster.name);
    let t0 = std::time::Instant::now();
    let results = common::dynamic_suite(scale, &cluster);
    println!(
        "ran {} dynamic experiments in {}\n",
        results.len(),
        memsched::bench::fmt_duration(t0.elapsed())
    );

    println!("-- §VI-C: schedule validity counts --");
    print!("{}", figures::dynamic_validity(&results).to_markdown());
    println!();
    println!("-- Fig 8: makespan improvement (%) of recomputation vs none --");
    print!("{}", figures::dynamic_improvement(&results).to_markdown());
}
