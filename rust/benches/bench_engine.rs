//! Intra-schedule scaling benchmark: one huge workflow, serial scoring
//! vs pool-parallel scoring (`--score-threads`), plus byte-equality of
//! the resulting schedules.
//!
//! This is the hot path ROADMAP calls "the next lever": a 30k-task
//! workflow used to schedule on exactly one core regardless of the
//! service's worker count, because service-level sharding is per *job*.
//! Here the per-task inner loop (tentative scoring against all 72
//! processors of the paper's memory-constrained cluster) fans out across
//! a [`ScorePool`].
//!
//! Knobs: `MEMSCHED_BENCH_TASKS` (default 30000; also runs a 10000-task
//! point), `MEMSCHED_SCORE_THREADS` (default: all cores),
//! `MEMSCHED_BENCH_FAST=1` shrinks the task counts for smoke runs.
//! `MEMSCHED_BENCH_CROSSOVER=1` runs the crossover sweep instead (see
//! [`run_crossover`]) — the measuring harness behind
//! `scheduler::SCORE_PARALLEL_CROSSOVER`.
//!
//! One-shot wall-clock timings (schedules this size run seconds, not
//! microseconds — the sampling harness would only add noise).

mod common;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::{default_cluster, memory_constrained_cluster, small_cluster};
use memsched::scheduler::{Algorithm, EvictionPolicy, Schedule, ScheduleRequest};
use memsched::service::{pool, ScorePool};

fn fingerprint(s: &Schedule) -> (bool, u64, usize) {
    // Cheap structural digest for the byte-equality assertion.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |x: u64| {
        h = (h ^ x).wrapping_mul(0x1000_0000_01b3);
    };
    for t in &s.tasks {
        mix(t.proc as u64);
        mix(t.start.to_bits());
        mix(t.finish.to_bits());
        mix(t.evicted.len() as u64);
    }
    mix(s.makespan.to_bits());
    (s.valid, h, s.tasks.iter().map(|t| t.evicted.len()).sum())
}

/// Sweep the `cluster.len() × mean fan-in` work axis (the quantity
/// [`memsched::scheduler::auto_score_threads`] thresholds on) across the
/// preset clusters × workload families, timing serial vs pooled scoring
/// at each point, and print the smallest work value where the pool wins
/// — the measured refresh for `scheduler::SCORE_PARALLEL_CROSSOVER`
/// (currently 64.0, an estimate). Run via `ci.sh --crossover` on a
/// toolchain box; update the constant (and its boundary test) when the
/// suggestion moves materially.
fn run_crossover(threads: usize, fast: bool) {
    let tasks = if fast { 400 } else { 2000 };
    let reps = if fast { 2 } else { 5 };
    let threads = threads.max(2);
    let pool = ScorePool::new(threads);
    let clusters = [small_cluster(), default_cluster(), memory_constrained_cluster()];
    let families = ["eager", "bacass", "chipseq"];
    println!(
        "== bench_engine crossover: work = cluster × mean fan-in, serial vs {threads}-thread pool, {tasks} tasks ==",
    );

    let mut points: Vec<(f64, f64, String)> = Vec::new();
    for cluster in &clusters {
        for family in families {
            let spec =
                WorkloadSpec { family: family.into(), size: Some(tasks), input: 2, seed: common::SEED };
            let Ok(wf) = spec.build() else { continue };
            let work = cluster.len() as f64 * wf.num_edges() as f64 / wf.num_tasks().max(1) as f64;
            // Min over reps: scheduling at this size runs milliseconds,
            // so take the least-noisy observation.
            let time = |p: Option<&ScorePool>| {
                (0..reps)
                    .map(|_| {
                        let t0 = std::time::Instant::now();
                        std::hint::black_box(
                            ScheduleRequest::new(&wf, cluster)
                                .algo(Algorithm::HeftmBl)
                                .policy(EvictionPolicy::LargestFirst)
                                .score_pool(p)
                                .run(),
                        );
                        t0.elapsed().as_secs_f64()
                    })
                    .fold(f64::INFINITY, f64::min)
            };
            let serial = time(None);
            let pooled = time(Some(&pool));
            points.push((work, serial / pooled, format!("{}/{family}", cluster.name)));
        }
    }
    points.sort_by(|a, b| a.0.total_cmp(&b.0));

    println!("{:>36}  {:>8}  {:>8}", "point", "work", "speedup");
    let mut crossover: Option<f64> = None;
    for (work, speedup, name) in &points {
        println!("{name:>36}  {work:>8.1}  {speedup:>7.2}x");
        if crossover.is_none() && *speedup > 1.0 {
            crossover = Some(*work);
        }
    }
    match crossover {
        Some(w) => println!(
            "suggested scheduler::SCORE_PARALLEL_CROSSOVER ≈ {w:.0} (first work value where \
             the pool wins; currently 64.0)"
        ),
        None => println!(
            "pool never beat serial on this sweep — keep serial below work {:.0}",
            points.last().map_or(0.0, |p| p.0)
        ),
    }
}

fn main() {
    let fast = std::env::var("MEMSCHED_BENCH_FAST").ok().is_some_and(|v| v != "0");
    let top: usize = std::env::var("MEMSCHED_BENCH_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 2000 } else { 30000 });
    let sizes: Vec<usize> = if fast { vec![top] } else { vec![top / 3, top] };
    let threads = std::env::var("MEMSCHED_SCORE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(pool::default_workers);
    if std::env::var("MEMSCHED_BENCH_CROSSOVER").ok().is_some_and(|v| v != "0") {
        return run_crossover(threads, fast);
    }
    let cluster = memory_constrained_cluster();
    let algo = Algorithm::HeftmBl;
    let policy = EvictionPolicy::LargestFirst;
    println!(
        "== bench_engine: {algo:?} on `{}` ({} procs), serial vs {threads} score thread(s) ==",
        cluster.name,
        cluster.len()
    );
    println!(
        "{:>8}  {:>12}  {:>12}  {:>8}  {}",
        "tasks", "serial", "parallel", "speedup", "parity"
    );

    let pool = ScorePool::new(threads);
    for tasks in sizes {
        let spec = WorkloadSpec { family: "chipseq".into(), size: Some(tasks), input: 3, seed: common::SEED };
        let wf = spec.build().expect("workload builds");

        let t0 = std::time::Instant::now();
        let serial = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(policy).run();
        let serial_secs = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let parallel = ScheduleRequest::new(&wf, &cluster)
            .algo(algo)
            .policy(policy)
            .score_pool(Some(&pool))
            .run();
        let parallel_secs = t0.elapsed().as_secs_f64();

        assert_eq!(
            fingerprint(&serial),
            fingerprint(&parallel),
            "parallel scoring must be byte-identical at {tasks} tasks"
        );
        println!(
            "{:>8}  {:>11.2}s  {:>11.2}s  {:>7.2}x  identical ({} evictions)",
            wf.num_tasks(),
            serial_secs,
            parallel_secs,
            serial_secs / parallel_secs,
            fingerprint(&serial).2
        );
        // Score-threads-axis throughput for the CI regression gate
        // (tasks scheduled per second; `tasks` names the requested size
        // so ids stay stable across runs).
        common::emit_bench_entry(
            &format!("engine/tasks={tasks}/serial"),
            tasks as f64 / serial_secs,
            serial_secs,
        );
        common::emit_bench_entry(
            &format!("engine/tasks={tasks}/parallel"),
            tasks as f64 / parallel_secs,
            parallel_secs,
        );
    }
}
