//! Figure 9 (paper §VI-D): scheduler running time vs workflow size, per
//! heuristic (log-scale y in the paper).
//!
//! Expected shape: HEFT/HEFTM-BL/HEFTM-BLC scale near-linearly (tens of
//! ms → tens of seconds at 30 000 tasks on the paper's Xeon); HEFTM-MM is
//! dominated by the MemDag traversal and is orders of magnitude slower on
//! the largest inputs.

mod common;

use memsched::bench::{black_box, fmt_duration, Harness};
use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::memory_constrained_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};

fn main() {
    let sizes: Vec<usize> = match common::scale_from_env() {
        memsched::experiments::SuiteScale::Smoke => vec![200, 1000],
        memsched::experiments::SuiteScale::Quick => vec![200, 1000, 2000, 4000, 10000, 20000],
        memsched::experiments::SuiteScale::Full => {
            memsched::generator::models::PAPER_SIZES.to_vec()
        }
    };
    let cluster = memory_constrained_cluster();
    let mut h = Harness::from_env("heuristic_runtimes (Fig 9)");
    println!("{:>8} {:>14} {:>14} {:>14} {:>14}", "tasks", "HEFT", "HEFTM-BL", "HEFTM-BLC",
        "HEFTM-MM");
    for &n in &sizes {
        let spec =
            WorkloadSpec { family: "chipseq".into(), size: Some(n), input: 3, seed: common::SEED };
        let wf = spec.build().expect("workload builds");
        let mut row = format!("{:>8}", wf.num_tasks());
        for &algo in Algorithm::all() {
            let stats = h.bench(&format!("{}_{n}", algo.label()), || {
                black_box(ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run())
            });
            let mean = stats.map(|s| s.mean).unwrap_or_default();
            row.push_str(&format!(" {:>14}", fmt_duration(mean)));
        }
        println!("{row}");
    }
    h.finish();
}
