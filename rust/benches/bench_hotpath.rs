//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the scheduler's inner
//! loops, the MemDag traversal, the runtime simulator, and the native-vs-
//! XLA scorer comparison.

mod common;

use memsched::bench::{black_box, Harness};
use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::{default_cluster, memory_constrained_cluster};
use memsched::scheduler::engine::ParentInfo;
use memsched::scheduler::{Algorithm, Engine, EvictionPolicy, ScheduleRequest, ScoreBuffers};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};

/// Fill a reusable scoring arena (the engine's per-task pattern).
fn score_buffers(k: usize, parents: usize) -> ScoreBuffers {
    ScoreBuffers {
        proc_ready: (0..k).map(|j| j as f64).collect(),
        speeds: (0..k).map(|j| 1.0 + (j % 7) as f64).collect(),
        avail_mem: (0..k).map(|j| 1e9 + j as f64).collect(),
        parents: (0..parents)
            .map(|p| ParentInfo { finish: p as f64, data: 1e6 * p as f64, proc: p % k })
            .collect(),
        // Row-major parents × procs.
        comm: (0..parents)
            .flat_map(|p| (0..k).map(move |j| (p * j) as f64 * 0.01))
            .collect(),
        work: 50.0,
        memory: 2e8,
        out_total: 1e7,
        bandwidth: 1e9,
        ..Default::default()
    }
}

fn main() {
    let mut h = Harness::from_env("hotpath");

    // Scheduler end-to-end on a mid-size instance (the macro hot path).
    let spec = WorkloadSpec { family: "eager".into(), size: Some(2000), input: 3, seed: 42 };
    let wf = spec.build().unwrap();
    let constrained = memory_constrained_cluster();
    let default = default_cluster();
    for algo in [Algorithm::Heft, Algorithm::HeftmBl, Algorithm::HeftmMm] {
        h.bench(&format!("schedule_2k_{}", algo.label()), || {
            black_box(ScheduleRequest::new(&wf, &constrained).algo(algo).policy(EvictionPolicy::LargestFirst).run())
        });
    }

    // Ranking components.
    h.bench("rank_bottom_levels_2k", || {
        black_box(memsched::scheduler::ranking::bottom_levels(&wf, &constrained))
    });
    h.bench("memdag_traversal_2k", || {
        black_box(memsched::memdag::min_memory_traversal(&wf))
    });

    // Runtime simulator (dynamic mode) on the same instance.
    let schedule = ScheduleRequest::new(&wf, &default).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
    let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.1, 7));
    h.bench("simulate_recompute_2k", || black_box(simulate(&wf, &default, &schedule, &cfg)));
    let cfg2 = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(0.1, 7));
    h.bench("simulate_static_2k", || black_box(simulate(&wf, &default, &schedule, &cfg2)));

    // Scorer: native vs XLA artifact (per-call and schedule-integrated).
    // Outputs land in the arena's `ft`/`res` slots — zero allocation per
    // call, exactly like the engine's hot loop.
    let mut bufs = score_buffers(72, 8);
    let native = memsched::runtime::scorer::NativeScorer;
    h.bench("scorer_native_call", || {
        bufs.score_with(&native);
        black_box(bufs.ft[0])
    });
    match memsched::runtime::scorer::XlaScorer::load_default() {
        Ok(xla) => {
            let mut xbufs = score_buffers(72, 8);
            h.bench("scorer_xla_call", || {
                xbufs.score_with(&xla);
                black_box(xbufs.ft[0])
            });
            let spec_small =
                WorkloadSpec { family: "chipseq".into(), size: Some(200), input: 2, seed: 42 };
            let wf_small = spec_small.build().unwrap();
            let order = Algorithm::HeftmBl.rank_order(&wf_small, &default);
            h.bench("schedule_200_native_scorer", || {
                let engine =
                    Engine::new(&wf_small, &default, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
                black_box(engine.run(&order))
            });
            h.bench("schedule_200_xla_scorer", || {
                let engine =
                    Engine::new(&wf_small, &default, Algorithm::HeftmBl, EvictionPolicy::LargestFirst)
                        .with_scorer(&xla);
                black_box(engine.run(&order))
            });
        }
        Err(e) => eprintln!("XLA scorer unavailable ({e}); run `make artifacts` first"),
    }

    h.finish();
}
