//! Adaptive-recompute benchmark: wall time of Recompute-mode replays,
//! where most of the cost is the mid-run rescheduling passes
//! (`SimRun::recompute` → `Engine::resume`), not the replay core.
//!
//! Three variants over the same sigma × seed grid, all asserted
//! bit-identical:
//! - `rebuild`: a fresh `SelectorState` (PEFT OCT table / Lookahead and
//!   DLS rank inputs) built on every trigger — the pre-fast-path shape;
//! - `hoisted`: the scaffold's lazily built selector state shared by
//!   every trigger (the default), plus the persistent `ResumeArena`;
//! - `pooled`: hoisted + a 4-thread `ScorePool` in the resume scoring
//!   loop (the deterministic min-ft/lowest-ProcId reduction).
//!
//! Workload: a generated chipseq instance on the default cluster under
//! PEFT when its schedule is valid (the OCT table makes selector
//! rebuilding maximally expensive), else the first valid memory-aware
//! fallback. Knobs: `MEMSCHED_BENCH_TASKS` (default 5000),
//! `MEMSCHED_BENCH_FAST=1` shrinks the instance and the grid.

mod common;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::default_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::service::ScorePool;
use memsched::simulator::{DeviationModel, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold};
use std::sync::Arc;

fn outcome_digest(out: &SimOutcome) -> (bool, u64, usize, usize) {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &f in &out.finish_times {
        h = (h ^ f.to_bits()).wrapping_mul(0x1000_0000_01b3);
    }
    h = (h ^ out.makespan.to_bits()).wrapping_mul(0x1000_0000_01b3);
    (out.completed, h, out.recomputations, out.started)
}

fn main() {
    let fast = std::env::var("MEMSCHED_BENCH_FAST").ok().is_some_and(|v| v != "0");
    let tasks: usize = std::env::var("MEMSCHED_BENCH_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 800 } else { 5000 });
    let seeds: u64 = if fast { 2 } else { 6 };
    let sigma = 0.3;

    let spec = WorkloadSpec { family: "chipseq".into(), size: Some(tasks), input: 2, seed: common::SEED };
    let wf = spec.build().expect("workload builds");
    let cluster = default_cluster();
    // PEFT first: its OCT table is the selector state whose per-trigger
    // rebuild the hoisting amortizes. Memory-aware fallbacks keep the
    // bench meaningful if PEFT's schedule is invalid at this size.
    let (algo, schedule) = [Algorithm::Peft, Algorithm::HeftmBl, Algorithm::HeftmMm]
        .into_iter()
        .map(|algo| {
            (algo, ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run())
        })
        .find(|(_, s)| s.valid)
        .expect("some schedule is valid on the default cluster");

    let points: Vec<SimConfig> = (0..seeds)
        .map(|seed| SimConfig::new(SimMode::Recompute, DeviationModel::new(sigma, seed)))
        .collect();
    let scaffold = SimScaffold::new(
        Arc::new(wf.clone()),
        Arc::new(cluster.clone()),
        Arc::new(schedule.clone()),
    );
    println!(
        "== bench_recompute: {} tasks on `{}` under {:?}, {} Recompute points at sigma={} ==",
        wf.num_tasks(),
        cluster.name,
        algo,
        points.len(),
        sigma
    );

    // Per-trigger selector rebuild: every recomputation reconstructs
    // the ranking inputs from scratch before resuming the engine.
    let mut run = SimRun::new();
    run.set_rebuild_selector(true);
    let t0 = std::time::Instant::now();
    let rebuilt: Vec<_> =
        points.iter().map(|cfg| outcome_digest(&run.simulate_with(&scaffold, cfg, None))).collect();
    let rebuild_secs = t0.elapsed().as_secs_f64();

    // Hoisted: the scaffold's selector state, built once, borrowed by
    // every trigger of every point.
    let mut run = SimRun::new();
    let t0 = std::time::Instant::now();
    let hoisted: Vec<_> =
        points.iter().map(|cfg| outcome_digest(&run.simulate_with(&scaffold, cfg, None))).collect();
    let hoisted_secs = t0.elapsed().as_secs_f64();
    assert_eq!(rebuilt, hoisted, "hoisted selector state must be bit-identical to rebuild");

    // Pooled: hoisted + parallel resume scoring.
    let pool = ScorePool::new(4);
    let t0 = std::time::Instant::now();
    let pooled: Vec<_> = points
        .iter()
        .map(|cfg| outcome_digest(&run.simulate_with(&scaffold, cfg, Some(&pool))))
        .collect();
    let pooled_secs = t0.elapsed().as_secs_f64();
    assert_eq!(rebuilt, pooled, "pooled resume scoring must be bit-identical to serial");

    let recomputes: usize = rebuilt.iter().map(|d| d.2).sum();
    let n = points.len() as f64;
    println!("   ({recomputes} recomputations across the grid)");
    println!(
        "{:>10}  {:>10.3}s  ({:>8.2} points/s)",
        "rebuild", rebuild_secs, n / rebuild_secs
    );
    println!(
        "{:>10}  {:>10.3}s  ({:>8.2} points/s)   speedup {:.2}x, identical outcomes",
        "hoisted",
        hoisted_secs,
        n / hoisted_secs,
        rebuild_secs / hoisted_secs
    );
    println!(
        "{:>10}  {:>10.3}s  ({:>8.2} points/s)   speedup {:.2}x, identical outcomes",
        "pooled",
        pooled_secs,
        n / pooled_secs,
        rebuild_secs / pooled_secs
    );
    common::emit_bench_entry(&format!("recompute/tasks={tasks}/rebuild"), n / rebuild_secs, rebuild_secs);
    common::emit_bench_entry(&format!("recompute/tasks={tasks}/hoisted"), n / hoisted_secs, hoisted_secs);
    common::emit_bench_entry(&format!("recompute/tasks={tasks}/pooled"), n / pooled_secs, pooled_secs);
}
