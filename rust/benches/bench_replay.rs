//! Replay-core benchmark: points/sec of replaying one static schedule
//! under many deviation points, scaffold-reuse (one [`SimScaffold`] +
//! one [`SimRun`] arena, the replay engine's execution shape) vs the
//! per-point rebuild the `simulate()` shim performs — the hoisting
//! ROADMAP flagged as the remaining replay bottleneck after the static
//! schedule itself was amortized.
//!
//! Workload: a ~5k-task generated chipseq instance on the default
//! cluster, replayed in FollowStatic mode over a sigma × seed grid
//! (FollowStatic isolates the replay core; Recompute points spend their
//! time in the scheduling engine instead).
//!
//! Four variants over the same grid, all asserted bit-identical:
//! per-point rebuild (the `simulate()` shim), scaffold reuse (the fast
//! path), scaffold with the calendar event queue, and scaffold with
//! `obs` tracing enabled (the `--metrics-json` overhead number).
//!
//! Knobs: `MEMSCHED_BENCH_TASKS` (default 5000), `MEMSCHED_BENCH_FAST=1`
//! shrinks the instance and the point grid for smoke runs. One-shot
//! wall-clock timings, like the other figure benches.

mod common;

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::default_cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::simulator::{
    DeviationModel, EventQueueKind, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold,
};
use std::sync::Arc;

fn outcome_digest(out: &SimOutcome) -> (bool, u64, usize, usize) {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &f in &out.finish_times {
        h = (h ^ f.to_bits()).wrapping_mul(0x1000_0000_01b3);
    }
    h = (h ^ out.makespan.to_bits()).wrapping_mul(0x1000_0000_01b3);
    (out.completed, h, out.recomputations, out.started)
}

fn main() {
    let fast = std::env::var("MEMSCHED_BENCH_FAST").ok().is_some_and(|v| v != "0");
    let tasks: usize = std::env::var("MEMSCHED_BENCH_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 800 } else { 5000 });
    let seeds_per_sigma: u64 = if fast { 4 } else { 16 };
    let sigmas = [0.05, 0.1, 0.2, 0.3];

    let spec = WorkloadSpec { family: "chipseq".into(), size: Some(tasks), input: 2, seed: common::SEED };
    let wf = spec.build().expect("workload builds");
    let cluster = default_cluster();
    // First memory-aware algorithm yielding a valid schedule, so the
    // replay points execute the whole workflow instead of failing early.
    let schedule = [Algorithm::HeftmBl, Algorithm::HeftmMm, Algorithm::HeftmBlc]
        .into_iter()
        .map(|algo| ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run())
        .find(|s| s.valid)
        .expect("some memory-aware schedule is valid on the default cluster");

    let points: Vec<SimConfig> = sigmas
        .iter()
        .flat_map(|&sigma| {
            (0..seeds_per_sigma)
                .map(move |seed| SimConfig::new(SimMode::FollowStatic, DeviationModel::new(sigma, seed)))
        })
        .collect();
    println!(
        "== bench_replay: {} tasks on `{}`, {} replay points (FollowStatic) ==",
        wf.num_tasks(),
        cluster.name,
        points.len()
    );

    // Per-point rebuild: the compatibility shim re-derives the scaffold
    // (rank order, queues, estimate tables), clones the inputs into the
    // scaffold's Arcs, and reallocates run state for every point — all
    // costs the scaffold-reuse path amortizes away.
    let t0 = std::time::Instant::now();
    let rebuilt: Vec<_> = points
        .iter()
        .map(|cfg| outcome_digest(&memsched::simulator::simulate(&wf, &cluster, &schedule, cfg)))
        .collect();
    let rebuild_secs = t0.elapsed().as_secs_f64();

    // Scaffold reuse: one scaffold, one arena, reset between points.
    let scaffold = SimScaffold::new(
        Arc::new(wf.clone()),
        Arc::new(cluster.clone()),
        Arc::new(schedule.clone()),
    );
    let mut run = SimRun::new();
    let t0 = std::time::Instant::now();
    let reused: Vec<_> = points.iter().map(|cfg| outcome_digest(&run.simulate(&scaffold, cfg))).collect();
    let scaffold_secs = t0.elapsed().as_secs_f64();

    assert_eq!(rebuilt, reused, "scaffold path must be bit-identical to per-point rebuild");

    // Calendar-queue variant: same arena, same grid, bucketed event
    // queue instead of the binary heap — pop order (and therefore every
    // outcome bit) is identical; only the wall clock may differ.
    run.set_event_queue(EventQueueKind::Calendar);
    let t0 = std::time::Instant::now();
    let calendar: Vec<_> =
        points.iter().map(|cfg| outcome_digest(&run.simulate(&scaffold, cfg))).collect();
    let calendar_secs = t0.elapsed().as_secs_f64();
    assert_eq!(rebuilt, calendar, "calendar event queue must be bit-identical to the heap");
    run.set_event_queue(EventQueueKind::Heap);

    // Tracing-overhead variant: same grid with the obs layer enabled
    // and a metrics sink draining afterwards — measures what
    // `--metrics-json` costs the replay hot loop (result bytes are
    // unaffected; only time is).
    memsched::obs::set_enabled(true);
    let t0 = std::time::Instant::now();
    let traced: Vec<_> =
        points.iter().map(|cfg| outcome_digest(&run.simulate(&scaffold, cfg))).collect();
    let traced_secs = t0.elapsed().as_secs_f64();
    memsched::obs::set_enabled(false);
    let recs = memsched::obs::drain();
    let sunk = memsched::obs::metrics_records(&recs).len();
    assert_eq!(rebuilt, traced, "tracing must not perturb outcomes");

    let n = points.len() as f64;
    println!(
        "{:>10}  {:>10.3}s  ({:>8.1} points/s)",
        "rebuild", rebuild_secs, n / rebuild_secs
    );
    println!(
        "{:>10}  {:>10.3}s  ({:>8.1} points/s)   speedup {:.2}x, identical outcomes",
        "scaffold",
        scaffold_secs,
        n / scaffold_secs,
        rebuild_secs / scaffold_secs
    );
    println!(
        "{:>10}  {:>10.3}s  ({:>8.1} points/s)   vs heap {:.2}x, identical outcomes",
        "calendar",
        calendar_secs,
        n / calendar_secs,
        scaffold_secs / calendar_secs
    );
    println!(
        "{:>10}  {:>10.3}s  ({:>8.1} points/s)   tracing overhead {:+.1}%, {} metric records",
        "traced",
        traced_secs,
        n / traced_secs,
        (traced_secs / scaffold_secs - 1.0) * 100.0,
        sunk
    );
    // Replay-axis throughput for the CI regression gate (ids keyed on
    // the requested size so they stay stable across machines).
    common::emit_bench_entry(&format!("replay/tasks={tasks}/rebuild"), n / rebuild_secs, rebuild_secs);
    common::emit_bench_entry(&format!("replay/tasks={tasks}/scaffold"), n / scaffold_secs, scaffold_secs);
    common::emit_bench_entry(&format!("replay/tasks={tasks}/calendar"), n / calendar_secs, calendar_secs);
    common::emit_bench_entry(
        &format!("replay/tasks={tasks}/scaffold_traced"),
        n / traced_secs,
        traced_secs,
    );
}
