//! Scheduling-service throughput benchmark: the same job batch executed
//! with 1 worker vs all cores, plus the schedule cache's warm-path
//! speedup. Also re-verifies the byte-identical JSONL guarantee on the
//! bench batch itself.
//!
//! `MEMSCHED_SUITE_SCALE=smoke|quick` sizes the batch (default smoke, so
//! the bench is quick by default); `MEMSCHED_JOBS` caps the parallel
//! worker count.

mod common;

use memsched::experiments::{self, SuiteScale};
use memsched::service::{self, ClusterSpec, Job, SchedulingService};

fn batch(scale: SuiteScale) -> Vec<Job> {
    // The suite grid, duplicated once: the second half exercises the
    // batch-level dedupe exactly like repeated production requests.
    let base = experiments::static_suite_jobs(scale, common::SEED, &ClusterSpec::Named("default".into()));
    let mut jobs = base.clone();
    jobs.extend(base);
    jobs
}

fn run(jobs: Vec<Job>, workers: usize) -> (String, f64, usize) {
    let n = jobs.len();
    let service = SchedulingService::new(workers);
    let t0 = std::time::Instant::now();
    let results = service.run_batch(jobs);
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(results.len(), n);
    assert!(results.iter().all(|r| r.error.is_none()), "bench batch must succeed");
    (service::to_jsonl(&results), secs, service.cache_stats().computed)
}

fn main() {
    let scale = match common::scale_from_env() {
        SuiteScale::Full => SuiteScale::Quick, // full would take far too long here
        s => s,
    };
    let workers = common::workers_from_env();
    let jobs = batch(scale);
    println!(
        "== bench_service: {} jobs (suite scale {scale:?} ×2), {} parallel worker(s) ==",
        jobs.len(),
        workers
    );

    let (serial_out, serial_secs, serial_computed) = run(jobs.clone(), 1);
    println!(
        "workers=1      : {:>8.2}s  ({:.1} jobs/s, {} schedules computed)",
        serial_secs,
        jobs.len() as f64 / serial_secs,
        serial_computed
    );
    common::emit_bench_entry(
        &format!("service/jobs={}/serial", jobs.len()),
        jobs.len() as f64 / serial_secs,
        serial_secs,
    );

    let (parallel_out, parallel_secs, parallel_computed) = run(jobs.clone(), workers);
    println!(
        "workers={workers:<6}: {:>8.2}s  ({:.1} jobs/s, {} schedules computed)",
        parallel_secs,
        jobs.len() as f64 / parallel_secs,
        parallel_computed
    );
    common::emit_bench_entry(
        &format!("service/jobs={}/parallel", jobs.len()),
        jobs.len() as f64 / parallel_secs,
        parallel_secs,
    );
    assert_eq!(serial_out, parallel_out, "JSONL must be byte-identical across worker counts");
    assert_eq!(serial_computed, parallel_computed);
    println!(
        "speedup        : {:.2}x on {} workers (byte-identical output verified)",
        serial_secs / parallel_secs,
        workers
    );

    // Warm-cache path: a service that has already answered the batch.
    let service = SchedulingService::new(workers);
    let _ = service.run_batch(jobs.clone());
    let t0 = std::time::Instant::now();
    let warm = service.run_batch(jobs.clone());
    let warm_secs = t0.elapsed().as_secs_f64();
    assert!(warm.iter().all(|r| r.cache_hit), "second pass must be all cache hits");
    println!(
        "warm cache     : {:>8.2}s  ({:.1} jobs/s, {:.1}x vs cold serial)",
        warm_secs,
        jobs.len() as f64 / warm_secs,
        serial_secs / warm_secs
    );
    common::emit_bench_entry(
        &format!("service/jobs={}/warm", jobs.len()),
        jobs.len() as f64 / warm_secs,
        warm_secs,
    );
}
