//! Figures 5–7 (paper §VI-B-2): static scheduling on the
//! **memory-constrained** cluster (Table II memories ÷ 10).
//!
//! Expected shape (paper): HEFT succeeds only on tiny workflows (4.8%);
//! HEFTM-BL ≈ 38%, HEFTM-BLC ≈ 49%, HEFTM-MM = 100% — MM's memory-minimal
//! traversal is size-insensitive, at the price of higher makespans.

mod common;

use memsched::experiments::figures;
use memsched::platform::presets::memory_constrained_cluster;

fn main() {
    let scale = common::scale_from_env();
    let cluster = memory_constrained_cluster();
    println!(
        "== bench_static_constrained: suite scale {scale:?}, cluster `{}` ==",
        cluster.name
    );
    let t0 = std::time::Instant::now();
    let results = common::static_suite(scale, &cluster);
    println!(
        "ran {} schedules in {}\n",
        results.len(),
        memsched::bench::fmt_duration(t0.elapsed())
    );

    println!("-- Fig 5: success rates (%) by size group (higher is better) --");
    print!("{}", figures::success_rates(&results).to_markdown());
    println!();
    println!("-- Fig 6: makespan normalized by HEFT (smaller is better) --");
    print!("{}", figures::relative_makespans(&results).to_markdown());
    println!();
    println!("-- Fig 7: memory usage (%), all schedules --");
    print!("{}", figures::memory_usage(&results, false).to_markdown());
}
