//! Figures 1–4 (paper §VI-B-1): static scheduling on the **default**
//! cluster — success rates, relative makespans, and memory usage.
//!
//! Expected shape (paper): HEFT schedules only small workflows (24.2%
//! success overall; nothing above ~4 000 tasks), the HEFTM heuristics
//! schedule everything; HEFTM-BL/BLC makespans within ~13–30% of HEFT's
//! (invalid, over-optimistic) ones, HEFTM-MM worse but with a far smaller
//! memory footprint.
//!
//! `MEMSCHED_SUITE_SCALE=smoke|quick|full` selects the workload sweep.

mod common;

use memsched::experiments::figures;
use memsched::platform::presets::default_cluster;

fn main() {
    let scale = common::scale_from_env();
    let cluster = default_cluster();
    println!("== bench_static_default: suite scale {scale:?}, cluster `{}` ==", cluster.name);
    let t0 = std::time::Instant::now();
    let results = common::static_suite(scale, &cluster);
    println!(
        "ran {} schedules in {}\n",
        results.len(),
        memsched::bench::fmt_duration(t0.elapsed())
    );

    println!("-- Fig 1: success rates (%) by size group (higher is better) --");
    print!("{}", figures::success_rates(&results).to_markdown());
    println!();
    println!("-- Fig 2: makespan normalized by HEFT (smaller is better) --");
    print!("{}", figures::relative_makespans(&results).to_markdown());
    println!();
    println!("-- Fig 3: memory usage (%), all schedules incl. invalid HEFT --");
    print!("{}", figures::memory_usage(&results, false).to_markdown());
    println!();
    println!("-- Fig 4: memory usage (%), valid schedules only --");
    print!("{}", figures::memory_usage(&results, true).to_markdown());
}
