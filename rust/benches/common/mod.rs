//! Shared plumbing for the figure benches.
#![allow(dead_code)] // each bench binary uses a subset

use memsched::experiments::{self, DynamicResult, StaticResult, SuiteScale};
use memsched::platform::Cluster;
use memsched::scheduler::Algorithm;

/// Suite scale from `MEMSCHED_SUITE_SCALE` (smoke|quick|full), default quick.
pub fn scale_from_env() -> SuiteScale {
    std::env::var("MEMSCHED_SUITE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(SuiteScale::Quick)
}

pub const SEED: u64 = 42;

/// Run the static suite on a cluster, with progress on stderr.
pub fn static_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<StaticResult> {
    let specs = experiments::suite(scale, SEED);
    let mut out = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        eprint!("\r[{}/{}] {}          ", i + 1, specs.len(), spec.id());
        out.extend(experiments::run_static(spec, cluster).expect("suite workload builds"));
    }
    eprintln!();
    out
}

/// Run the dynamic suite (≤ 2000 tasks, σ = 10%) on a cluster.
pub fn dynamic_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<DynamicResult> {
    let specs: Vec<_> = experiments::suite(scale, SEED)
        .into_iter()
        .filter(|s| s.size.is_none_or(|n| n <= 2000))
        .collect();
    let mut out = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        eprint!("\r[{}/{}] {}          ", i + 1, specs.len(), spec.id());
        for algo in Algorithm::all() {
            out.push(
                experiments::run_dynamic(spec, cluster, algo, 0.1)
                    .expect("suite workload builds"),
            );
        }
    }
    eprintln!();
    out
}
