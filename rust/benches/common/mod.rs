//! Shared plumbing for the figure benches.
#![allow(dead_code)] // each bench binary uses a subset

use memsched::experiments::{self, DynamicResult, StaticResult, SuiteScale};
use memsched::platform::Cluster;
use memsched::service::pool;

/// Suite scale from `MEMSCHED_SUITE_SCALE` (smoke|quick|full), default quick.
pub fn scale_from_env() -> SuiteScale {
    std::env::var("MEMSCHED_SUITE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(SuiteScale::Quick)
}

/// Worker count from `MEMSCHED_JOBS`, default all cores; 0 clamps to 1
/// (matching the CLI's `--jobs 0` behaviour).
pub fn workers_from_env() -> usize {
    std::env::var("MEMSCHED_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(pool::default_workers)
}

/// Intra-schedule scoring threads from `MEMSCHED_SCORE_THREADS`,
/// default 1 (serial scoring); 0 clamps to 1.
pub fn score_threads_from_env() -> usize {
    std::env::var("MEMSCHED_SCORE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(1)
}

pub const SEED: u64 = 42;

/// The bench service configuration from the `MEMSCHED_JOBS` /
/// `MEMSCHED_SCORE_THREADS` environment knobs.
pub fn service_config_from_env() -> memsched::service::ServiceConfig {
    memsched::service::ServiceConfig {
        workers: workers_from_env(),
        score: memsched::service::ScoreThreadSpec::Fixed(score_threads_from_env()),
        ..memsched::service::ServiceConfig::default()
    }
}

/// Run the static suite on a cluster through the scheduling-service pool
/// (the suite runner prints its own progress lines to stderr).
pub fn static_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<StaticResult> {
    experiments::run_static_suite(scale, SEED, cluster, &service_config_from_env())
        .expect("suite workloads build")
}

/// Run the dynamic suite (≤ 2000 tasks, σ = 10%) through the pool.
pub fn dynamic_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<DynamicResult> {
    experiments::run_dynamic_suite(scale, SEED, cluster, &[0.1], &service_config_from_env())
        .expect("suite workloads build")
        .remove(0)
}

/// Append one machine-readable bench entry to the JSONL file named by
/// `MEMSCHED_BENCH_JSON` (no-op when unset). `ci.sh --bench` collects
/// these into `BENCH_ci.json` and gates regressions with
/// `memsched bench-check`.
pub fn emit_bench_entry(id: &str, throughput: f64, seconds: f64) {
    let Some(path) = std::env::var_os("MEMSCHED_BENCH_JSON") else {
        return;
    };
    use memsched::ser::json::obj;
    use std::io::Write as _;
    let line = obj(vec![
        ("id", id.into()),
        ("throughput", throughput.into()),
        ("seconds", seconds.into()),
    ])
    .to_string_compact();
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
        }
        Err(e) => eprintln!("warning: cannot append bench entry to {path:?}: {e}"),
    }
}
