//! Shared plumbing for the figure benches.
#![allow(dead_code)] // each bench binary uses a subset

use memsched::experiments::{self, DynamicResult, StaticResult, SuiteScale};
use memsched::platform::Cluster;
use memsched::service::pool;

/// Suite scale from `MEMSCHED_SUITE_SCALE` (smoke|quick|full), default quick.
pub fn scale_from_env() -> SuiteScale {
    std::env::var("MEMSCHED_SUITE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(SuiteScale::Quick)
}

/// Worker count from `MEMSCHED_JOBS`, default all cores; 0 clamps to 1
/// (matching the CLI's `--jobs 0` behaviour).
pub fn workers_from_env() -> usize {
    std::env::var("MEMSCHED_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(pool::default_workers)
}

/// Intra-schedule scoring threads from `MEMSCHED_SCORE_THREADS`,
/// default 1 (serial scoring); 0 clamps to 1.
pub fn score_threads_from_env() -> usize {
    std::env::var("MEMSCHED_SCORE_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(1)
}

pub const SEED: u64 = 42;

/// Run the static suite on a cluster through the scheduling-service pool
/// (the suite runner prints its own progress lines to stderr).
pub fn static_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<StaticResult> {
    experiments::run_static_suite(scale, SEED, cluster, workers_from_env(), score_threads_from_env())
        .expect("suite workloads build")
}

/// Run the dynamic suite (≤ 2000 tasks, σ = 10%) through the pool.
pub fn dynamic_suite(scale: SuiteScale, cluster: &Cluster) -> Vec<DynamicResult> {
    experiments::run_dynamic_suite(scale, SEED, cluster, 0.1, workers_from_env(), score_threads_from_env())
        .expect("suite workloads build")
}
