//! Criterion-style micro/macro benchmark harness (criterion itself is
//! unavailable offline).
//!
//! Usage from a `harness = false` bench binary:
//!
//! ```no_run
//! use memsched::bench::Harness;
//! let mut h = Harness::from_env("my_bench");
//! h.bench("fast_thing", || { /* measured work */ });
//! h.finish();
//! ```
//!
//! Each benchmark is warmed up, then sampled until both a minimum sample
//! count and a minimum measuring time are reached. Reported statistics:
//! mean ± stddev, median, min/max. `MEMSCHED_BENCH_FAST=1` shrinks the
//! budget (used by `cargo test`-adjacent smoke runs).

use std::time::{Duration, Instant};

/// Benchmark statistics for one target.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(name: &str, mut xs: Vec<f64>) -> Stats {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len().max(1);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = xs.get(n / 2).copied().unwrap_or(mean);
        Stats {
            name: name.to_string(),
            samples: xs.len(),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            median: Duration::from_secs_f64(median),
            min: Duration::from_secs_f64(xs.first().copied().unwrap_or(0.0)),
            max: Duration::from_secs_f64(xs.last().copied().unwrap_or(0.0)),
        }
    }
}

/// Pretty-print a duration with an adaptive unit.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Bench harness: collects targets, prints a report, optionally filters by
/// the first CLI argument (like `cargo bench -- <filter>`).
pub struct Harness {
    suite: String,
    filter: Option<String>,
    min_samples: usize,
    min_time: Duration,
    warmup: Duration,
    results: Vec<Stats>,
}

impl Harness {
    pub fn new(suite: &str) -> Harness {
        let fast = std::env::var("MEMSCHED_BENCH_FAST").ok().is_some_and(|v| v != "0");
        Harness {
            suite: suite.to_string(),
            filter: None,
            min_samples: if fast { 3 } else { 10 },
            min_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
            warmup: if fast { Duration::from_millis(10) } else { Duration::from_millis(100) },
            results: Vec::new(),
        }
    }

    /// Construct and pick up a name filter from `argv[1]` (skipping the
    /// `--bench` flag cargo passes to bench binaries).
    pub fn from_env(suite: &str) -> Harness {
        let mut h = Harness::new(suite);
        h.filter = std::env::args().skip(1).find(|a| a != "--bench" && !a.starts_with("--"));
        println!("== bench suite: {suite} ==");
        h
    }

    /// Override sampling budget (for long end-to-end targets).
    pub fn budget(&mut self, min_samples: usize, min_time: Duration) -> &mut Self {
        self.min_samples = min_samples;
        self.min_time = min_time;
        self
    }

    fn matches(&self, name: &str) -> bool {
        self.filter.as_deref().is_none_or(|f| name.contains(f))
    }

    /// Measure a closure. The closure's return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Option<Stats> {
        if !self.matches(name) {
            return None;
        }
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Sampling.
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_samples || start.elapsed() < self.min_time {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let stats = Stats::from_samples(name, samples);
        println!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, n={})",
            stats.name,
            fmt_duration(stats.mean),
            fmt_duration(stats.stddev),
            fmt_duration(stats.median),
            stats.samples
        );
        self.results.push(stats.clone());
        Some(stats)
    }

    /// Run a target once (for throughput-style end-to-end tables that do
    /// their own reporting); still honors the filter.
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.matches(name) {
            return;
        }
        println!("-- {name} --");
        let t0 = Instant::now();
        f();
        println!("-- {name}: {} --", fmt_duration(t0.elapsed()));
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print the closing banner.
    pub fn finish(&self) {
        println!("== {}: {} target(s) measured ==", self.suite, self.results.len());
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("MEMSCHED_BENCH_FAST", "1");
        let mut h = Harness::new("test");
        let s = h
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..1000 {
                    acc = acc.wrapping_add(i);
                }
                acc
            })
            .unwrap();
        assert!(s.samples >= 3);
        assert!(s.mean > Duration::ZERO);
        assert!(s.min <= s.median && s.median <= s.max);
        h.finish();
    }

    #[test]
    fn filter_skips() {
        std::env::set_var("MEMSCHED_BENCH_FAST", "1");
        let mut h = Harness::new("test");
        h.filter = Some("match_me".to_string());
        assert!(h.bench("other", || 1).is_none());
        assert!(h.bench("match_me_exactly", || 1).is_some());
        assert_eq!(h.results().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.000 µs");
        assert!(fmt_duration(Duration::from_nanos(3)).ends_with("ns"));
    }
}
