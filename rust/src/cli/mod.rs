//! Minimal command-line argument parser (no external crates available in
//! this offline environment).
//!
//! Model: `program <subcommand> [--key value]... [--flag]...`. Parsed
//! eagerly into an [`Args`] map; typed accessors consume entries so that
//! [`Args::finish`] can reject unknown/unused options with a helpful error.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    options: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
    used: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator (first item = program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut it = items.into_iter();
        let program = it.next().unwrap_or_else(|| "memsched".to_string());
        let mut args = Args { program, ..Default::default() };
        let mut rest: Vec<String> = it.collect();
        rest.reverse(); // treat as stack
        while let Some(item) = rest.pop() {
            if let Some(stripped) = item.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: everything after is positional.
                    while let Some(p) = rest.pop() {
                        args.positionals.push(p);
                    }
                    break;
                }
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let value = match inline_val {
                    Some(v) => Some(v),
                    None => {
                        // Consume the next item as a value unless it looks
                        // like another option.
                        match rest.last() {
                            Some(next) if !next.starts_with("--") => rest.pop(),
                            _ => None,
                        }
                    }
                };
                args.options.entry(key).or_default().push(value.unwrap_or_default());
            } else if args.subcommand.is_none() && args.positionals.is_empty() {
                args.subcommand = Some(item);
            } else {
                args.positionals.push(item);
            }
        }
        Ok(args)
    }

    /// Parse from `std::env::args()`.
    pub fn from_env() -> Result<Args> {
        Args::parse_from(std::env::args())
    }

    fn take(&mut self, key: &str) -> Option<String> {
        self.used.insert(key.to_string());
        self.options.get(key).and_then(|v| v.last().cloned())
    }

    /// Optional string option.
    pub fn opt_str(&mut self, key: &str) -> Option<String> {
        self.take(key).filter(|s| !s.is_empty())
    }

    /// Required string option.
    pub fn req_str(&mut self, key: &str) -> Result<String> {
        self.opt_str(key).ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// Boolean flag (present → true). `--key=false` is honored.
    pub fn flag(&mut self, key: &str) -> bool {
        self.used.insert(key.to_string());
        match self.options.get(key).and_then(|v| v.last()) {
            Some(v) if v == "false" || v == "0" => false,
            Some(_) => true,
            None => false,
        }
    }

    /// Optional typed option.
    pub fn opt<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<T>> {
        match self.opt_str(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow!("invalid value `{s}` for --{key}")),
        }
    }

    /// Typed option with a default.
    pub fn opt_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T> {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// Required typed option.
    pub fn req<T: std::str::FromStr>(&mut self, key: &str) -> Result<T> {
        self.opt(key)?.ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// All values provided for a repeatable option.
    pub fn multi(&mut self, key: &str) -> Vec<String> {
        self.used.insert(key.to_string());
        self.options.get(key).cloned().unwrap_or_default()
    }

    /// Comma-separated list option (`--sizes 200,1000,2000`).
    pub fn list(&mut self, key: &str) -> Vec<String> {
        self.opt_str(key)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect())
            .unwrap_or_default()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Error on any option never consumed by an accessor (catches typos).
    pub fn finish(&self) -> Result<()> {
        let unknown: Vec<&String> =
            self.options.keys().filter(|k| !self.used.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!(
                "unknown option(s): {}",
                unknown.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse_from(items.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = parse(&["prog", "schedule", "--algo", "heftm-bl", "--seed", "42"]);
        assert_eq!(a.subcommand.as_deref(), Some("schedule"));
        assert_eq!(a.req_str("algo").unwrap(), "heftm-bl");
        assert_eq!(a.req::<u64>("seed").unwrap(), 42);
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax_and_flags() {
        let mut a = parse(&["prog", "run", "--tasks=100", "--verbose", "--quiet=false"]);
        assert_eq!(a.req::<usize>("tasks").unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_missing() {
        let mut a = parse(&["prog", "x"]);
        assert_eq!(a.opt_or("n", 7usize).unwrap(), 7);
        assert!(a.req_str("missing").is_err());
        assert!(a.opt::<usize>("absent").unwrap().is_none());
    }

    #[test]
    fn invalid_typed_value() {
        let mut a = parse(&["prog", "x", "--n", "abc"]);
        assert!(a.req::<usize>("n").is_err());
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = parse(&["prog", "x", "--oops", "1", "--fine", "2"]);
        let _ = a.opt_str("fine");
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_and_multi() {
        let mut a = parse(&["prog", "x", "--sizes", "200, 1000,2000", "--wf", "a", "--wf", "b"]);
        assert_eq!(a.list("sizes"), vec!["200", "1000", "2000"]);
        assert_eq!(a.multi("wf"), vec!["a", "b"]);
    }

    #[test]
    fn positionals_and_terminator() {
        let a = parse(&["prog", "cmd", "p1", "--k", "v", "--", "--not-an-option"]);
        assert_eq!(a.positionals(), &["p1".to_string(), "--not-an-option".to_string()]);
    }
}
