//! Minimal command-line argument parser (no external crates available in
//! this offline environment).
//!
//! Model: `program <subcommand> [--key value]... [--flag]...`. Parsed
//! eagerly into an [`Args`] map; typed accessors consume entries so that
//! [`Args::finish`] can reject unknown/unused options with a helpful error.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    options: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
    used: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator (first item = program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut it = items.into_iter();
        let program = it.next().unwrap_or_else(|| "memsched".to_string());
        let mut args = Args { program, ..Default::default() };
        let mut rest: Vec<String> = it.collect();
        rest.reverse(); // treat as stack
        while let Some(item) = rest.pop() {
            if let Some(stripped) = item.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminator: everything after is positional.
                    while let Some(p) = rest.pop() {
                        args.positionals.push(p);
                    }
                    break;
                }
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let value = match inline_val {
                    Some(v) => Some(v),
                    None => {
                        // Consume the next item as a value unless it looks
                        // like another option.
                        match rest.last() {
                            Some(next) if !next.starts_with("--") => rest.pop(),
                            _ => None,
                        }
                    }
                };
                args.options.entry(key).or_default().push(value.unwrap_or_default());
            } else if args.subcommand.is_none() && args.positionals.is_empty() {
                args.subcommand = Some(item);
            } else {
                args.positionals.push(item);
            }
        }
        Ok(args)
    }

    /// Parse from `std::env::args()`.
    pub fn from_env() -> Result<Args> {
        Args::parse_from(std::env::args())
    }

    fn take(&mut self, key: &str) -> Option<String> {
        self.used.insert(key.to_string());
        self.options.get(key).and_then(|v| v.last().cloned())
    }

    /// Optional string option.
    pub fn opt_str(&mut self, key: &str) -> Option<String> {
        self.take(key).filter(|s| !s.is_empty())
    }

    /// Optional string option that rejects a present-but-valueless key
    /// instead of silently reading it as absent. That state arises two
    /// ways — an explicit empty `--key=`, or a bare `--key` whose value
    /// was swallowed because the next token starts with `--` (values
    /// beginning with `--` are only accepted in the `=` form) — and the
    /// diagnostic covers both.
    pub fn opt_val(&mut self, key: &str) -> Result<Option<String>> {
        self.used.insert(key.to_string());
        match self.options.get(key).and_then(|v| v.last()) {
            None => Ok(None),
            Some(s) if s.is_empty() => bail!(
                "missing or empty value for --{key}: pass it as --{key}=<value> \
                 (values beginning with `--` are only accepted in that form)"
            ),
            Some(s) => Ok(Some(s.clone())),
        }
    }

    /// Required string option. Distinguishes an absent option from one
    /// whose value was swallowed: a bare `--key` followed by another
    /// `--...` token records an empty value, because values beginning
    /// with `--` can only be passed in the `--key=value` form (the
    /// check itself lives in [`Args::opt_val`]).
    pub fn req_str(&mut self, key: &str) -> Result<String> {
        self.opt_val(key)?
            .ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// Boolean flag (present → true). `--key=false` is honored.
    pub fn flag(&mut self, key: &str) -> bool {
        self.used.insert(key.to_string());
        match self.options.get(key).and_then(|v| v.last()) {
            Some(v) if v == "false" || v == "0" => false,
            Some(_) => true,
            None => false,
        }
    }

    /// Optional typed option. A present key whose value was swallowed
    /// (see [`Args::opt_val`]) is an error, not a silent default.
    pub fn opt<T: std::str::FromStr>(&mut self, key: &str) -> Result<Option<T>> {
        match self.opt_val(key)? {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow!("invalid value `{s}` for --{key}")),
        }
    }

    /// Typed option with a default.
    pub fn opt_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T> {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// Required typed option.
    pub fn req<T: std::str::FromStr>(&mut self, key: &str) -> Result<T> {
        self.opt(key)?.ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// All values provided for a repeatable option.
    pub fn multi(&mut self, key: &str) -> Vec<String> {
        self.used.insert(key.to_string());
        self.options.get(key).cloned().unwrap_or_default()
    }

    /// Comma-separated list option (`--sizes 200,1000,2000`).
    pub fn list(&mut self, key: &str) -> Vec<String> {
        self.opt_str(key)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect())
            .unwrap_or_default()
    }

    /// Typed comma-separated list (`--sigmas 0.1,0.2,0.5`). An absent
    /// key yields an empty vector; any unparsable element is an error.
    pub fn list_of<T: std::str::FromStr>(&mut self, key: &str) -> Result<Vec<T>> {
        self.list(key)
            .iter()
            .map(|s| s.parse::<T>().map_err(|_| anyhow!("invalid value `{s}` in --{key}")))
            .collect()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Error on any option never consumed by an accessor (catches typos).
    pub fn finish(&self) -> Result<()> {
        let unknown: Vec<&String> =
            self.options.keys().filter(|k| !self.used.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!(
                "unknown option(s): {}",
                unknown.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse_from(items.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = parse(&["prog", "schedule", "--algo", "heftm-bl", "--seed", "42"]);
        assert_eq!(a.subcommand.as_deref(), Some("schedule"));
        assert_eq!(a.req_str("algo").unwrap(), "heftm-bl");
        assert_eq!(a.req::<u64>("seed").unwrap(), 42);
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax_and_flags() {
        let mut a = parse(&["prog", "run", "--tasks=100", "--verbose", "--quiet=false"]);
        assert_eq!(a.req::<usize>("tasks").unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_missing() {
        let mut a = parse(&["prog", "x"]);
        assert_eq!(a.opt_or("n", 7usize).unwrap(), 7);
        assert!(a.req_str("missing").is_err());
        assert!(a.opt::<usize>("absent").unwrap().is_none());
    }

    #[test]
    fn invalid_typed_value() {
        let mut a = parse(&["prog", "x", "--n", "abc"]);
        assert!(a.req::<usize>("n").is_err());
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = parse(&["prog", "x", "--oops", "1", "--fine", "2"]);
        let _ = a.opt_str("fine");
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_and_multi() {
        let mut a = parse(&["prog", "x", "--sizes", "200, 1000,2000", "--wf", "a", "--wf", "b"]);
        assert_eq!(a.list("sizes"), vec!["200", "1000", "2000"]);
        assert_eq!(a.multi("wf"), vec!["a", "b"]);
    }

    #[test]
    fn typed_lists_parse_and_reject() {
        let mut a = parse(&["prog", "x", "--sigmas", "0.1, 0.2,0.5"]);
        assert_eq!(a.list_of::<f64>("sigmas").unwrap(), vec![0.1, 0.2, 0.5]);
        assert!(a.list_of::<f64>("absent").unwrap().is_empty());
        let mut b = parse(&["prog", "x", "--sigmas", "0.1,zero.2"]);
        let err = b.list_of::<f64>("sigmas").unwrap_err().to_string();
        assert!(err.contains("zero.2"), "unhelpful error: {err}");
    }

    #[test]
    fn positionals_and_terminator() {
        let a = parse(&["prog", "cmd", "p1", "--k", "v", "--", "--not-an-option"]);
        assert_eq!(a.positionals(), &["p1".to_string(), "--not-an-option".to_string()]);
    }

    #[test]
    fn equals_syntax_accepts_values_beginning_with_dashes() {
        let mut a = parse(&["prog", "x", "--key=--weird", "--num=-3"]);
        assert_eq!(a.req_str("key").unwrap(), "--weird");
        assert_eq!(a.req::<i64>("num").unwrap(), -3);
        a.finish().unwrap();
    }

    #[test]
    fn swallowed_value_reports_equals_form() {
        // `--key --other 1`: `--other` looks like an option, so --key has
        // no value; the error must point at the --key=<value> form.
        let mut a = parse(&["prog", "x", "--key", "--other", "1"]);
        let err = a.req_str("key").unwrap_err().to_string();
        assert!(err.contains("--key=<value>"), "unhelpful error: {err}");
        // The next option still parsed normally.
        assert_eq!(a.req::<u32>("other").unwrap(), 1);
        // Typed accessors refuse the swallowed value too.
        let mut b = parse(&["prog", "x", "--sigma", "--seed", "7"]);
        let err = b.opt::<f64>("sigma").unwrap_err().to_string();
        assert!(err.contains("--sigma=<value>"), "unhelpful error: {err}");
        // ... and so does the checked optional-string accessor.
        let mut c = parse(&["prog", "x", "--out", "--jobs", "4"]);
        let err = c.opt_val("out").unwrap_err().to_string();
        assert!(err.contains("--out=<value>"), "unhelpful error: {err}");
        assert_eq!(c.opt_val("jobs").unwrap().as_deref(), Some("4"));
        assert_eq!(c.opt_val("absent").unwrap(), None);
    }

    #[test]
    fn repeated_options_last_wins_and_multi_collects() {
        let mut a = parse(&["prog", "x", "--n", "1", "--n", "2", "--n", "3"]);
        assert_eq!(a.req::<u64>("n").unwrap(), 3, "scalar accessors take the last value");
        let mut b = parse(&["prog", "x", "--n", "1", "--n", "2", "--n", "3"]);
        assert_eq!(b.multi("n"), vec!["1", "2", "3"]);
        b.finish().unwrap();
    }

    #[test]
    fn repeated_flags_stay_true() {
        let mut a = parse(&["prog", "x", "--verbose", "--verbose"]);
        assert!(a.flag("verbose"));
        // Last value wins for flags too: an explicit =false overrides.
        let mut b = parse(&["prog", "x", "--verbose", "--verbose=false"]);
        assert!(!b.flag("verbose"));
        a.finish().unwrap();
        b.finish().unwrap();
    }
}
