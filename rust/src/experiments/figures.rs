//! Figure/table builders (§VI): aggregate experiment results into exactly
//! the rows/series the paper reports. Shared by the bench harnesses and
//! the `memsched experiment` CLI.

use super::{DynamicResult, StaticResult};
use crate::metrics::{cell, GroupedStat, SuccessRate};
use crate::scheduler::Algorithm;
use crate::ser::csv::CsvWriter;
use crate::workflow::SizeGroup;

/// One label per standalone algorithm, in [`Algorithm::all`]'s order
/// (HEFT first — the `[1..]` slices below drop the normalization row).
/// Derived, not hardcoded, so a new algorithm variant cannot silently
/// skip a suite column.
fn algo_labels() -> Vec<&'static str> {
    Algorithm::all().iter().map(|a| a.label()).collect()
}

/// Figs 1 / 5: success rate (%) by size group and algorithm.
pub fn success_rates(results: &[StaticResult]) -> CsvWriter {
    let mut sr = SuccessRate::default();
    for r in results {
        sr.add(r.group, r.algo.label(), r.valid);
    }
    let mut w = CsvWriter::new(vec!["algorithm", "tiny", "small", "middle", "big", "overall"]);
    for label in algo_labels() {
        let mut row = vec![label.to_string()];
        for g in SizeGroup::all() {
            row.push(cell(sr.rate(g, label)));
        }
        row.push(cell(sr.overall(label)));
        w.row(row);
    }
    w
}

/// Figs 2 / 6: mean makespan normalized by HEFT's, by size group.
/// (HEFT's own schedules are often invalid; the paper still normalizes by
/// them as an optimistic lower bound.)
pub fn relative_makespans(results: &[StaticResult]) -> CsvWriter {
    let mut g = GroupedStat::default();
    for r in results {
        if r.algo != Algorithm::Heft && r.heft_makespan > 0.0 && r.makespan.is_finite() {
            g.add(r.group, r.algo.label(), r.makespan / r.heft_makespan);
        }
    }
    let mut w = CsvWriter::new(vec!["algorithm", "tiny", "small", "middle", "big"]);
    for label in &algo_labels()[1..] {
        let mut row = vec![label.to_string()];
        for grp in SizeGroup::all() {
            row.push(match g.mean(grp, label) {
                Some(x) => format!("{x:.3}"),
                None => "-".into(),
            });
        }
        w.row(row);
    }
    w
}

/// Figs 3 / 4 / 7: mean peak memory usage (%) by size group; optionally
/// restricted to valid schedules (Fig 4).
pub fn memory_usage(results: &[StaticResult], valid_only: bool) -> CsvWriter {
    let mut g = GroupedStat::default();
    for r in results {
        if !valid_only || r.valid {
            g.add(r.group, r.algo.label(), 100.0 * r.mem_usage);
        }
    }
    let mut w = CsvWriter::new(vec!["algorithm", "tiny", "small", "middle", "big"]);
    for label in algo_labels() {
        let mut row = vec![label.to_string()];
        for grp in SizeGroup::all() {
            row.push(cell(g.mean(grp, label)));
        }
        w.row(row);
    }
    w
}

/// Fig 9: mean scheduler running time (s) per algorithm and instance size.
pub fn heuristic_runtimes(results: &[StaticResult]) -> CsvWriter {
    use std::collections::BTreeMap;
    let mut by: BTreeMap<(usize, &'static str), Vec<f64>> = BTreeMap::new();
    let mut sizes: Vec<usize> = Vec::new();
    for r in results {
        by.entry((r.tasks, r.algo.label())).or_default().push(r.sched_seconds);
        if !sizes.contains(&r.tasks) {
            sizes.push(r.tasks);
        }
    }
    sizes.sort_unstable();
    let mut header = vec!["tasks"];
    header.extend(algo_labels());
    let mut w = CsvWriter::new(header);
    for n in sizes {
        let mut row = vec![n.to_string()];
        for label in algo_labels() {
            let val = by.get(&(n, label)).map(|xs| xs.iter().sum::<f64>() / xs.len() as f64);
            row.push(match val {
                Some(x) => format!("{x:.4}"),
                None => "-".into(),
            });
        }
        w.row(row);
    }
    w
}

/// §VI-C validity counts: initial / with recomputation / without.
pub fn dynamic_validity(results: &[DynamicResult]) -> CsvWriter {
    let mut w = CsvWriter::new(vec![
        "algorithm",
        "experiments",
        "valid_initial",
        "valid_with_recompute",
        "valid_without_recompute",
        "mean_recomputations",
    ]);
    for &algo in Algorithm::all() {
        let rs: Vec<&DynamicResult> = results.iter().filter(|r| r.algo == algo).collect();
        if rs.is_empty() {
            continue;
        }
        let init = rs.iter().filter(|r| r.initially_valid).count();
        let rec = rs.iter().filter(|r| r.recompute_ok).count();
        let sta = rs.iter().filter(|r| r.static_ok).count();
        let mean_rc = rs.iter().map(|r| r.recomputations as f64).sum::<f64>() / rs.len() as f64;
        w.row(vec![
            algo.label().to_string(),
            rs.len().to_string(),
            init.to_string(),
            rec.to_string(),
            sta.to_string(),
            format!("{mean_rc:.1}"),
        ]);
    }
    w
}

/// Fig 8: self-relative makespan improvement (%) of recomputation vs no
/// recomputation, by size group (pairs where both executions completed).
pub fn dynamic_improvement(results: &[DynamicResult]) -> CsvWriter {
    let mut g = GroupedStat::default();
    for r in results {
        if let Some(imp) = r.improvement() {
            g.add(r.group, r.algo.label(), imp);
        }
    }
    let mut w = CsvWriter::new(vec!["algorithm", "tiny", "small", "middle", "big"]);
    for label in algo_labels() {
        let mut row = vec![label.to_string()];
        for grp in SizeGroup::all() {
            row.push(cell(g.mean(grp, label)));
        }
        w.row(row);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn static_result(
        group: SizeGroup,
        algo: Algorithm,
        valid: bool,
        makespan: f64,
    ) -> StaticResult {
        StaticResult {
            spec_id: "x".into(),
            group,
            tasks: 100,
            algo,
            valid,
            makespan,
            mem_usage: 0.5,
            heft_makespan: 10.0,
            sched_seconds: 0.01,
        }
    }

    #[test]
    fn success_rate_table_shape() {
        let rs = vec![
            static_result(SizeGroup::Tiny, Algorithm::Heft, true, 10.0),
            static_result(SizeGroup::Tiny, Algorithm::Heft, false, 10.0),
            static_result(SizeGroup::Tiny, Algorithm::HeftmBl, true, 12.0),
        ];
        let t = success_rates(&rs);
        let csv = t.to_csv();
        assert!(csv.contains("HEFT,50.0"));
        assert!(csv.contains("HEFTM-BL,100.0"));
        assert_eq!(t.len(), Algorithm::all().len()); // one row per algorithm
    }

    #[test]
    fn relative_makespan_normalized() {
        let rs = vec![
            static_result(SizeGroup::Small, Algorithm::Heft, false, 10.0),
            static_result(SizeGroup::Small, Algorithm::HeftmBl, true, 12.0),
        ];
        let t = relative_makespans(&rs);
        assert!(t.to_csv().contains("HEFTM-BL,-,1.200"));
    }

    #[test]
    fn memory_usage_valid_only_filters() {
        let mut bad = static_result(SizeGroup::Tiny, Algorithm::Heft, false, 1.0);
        bad.mem_usage = 2.0; // 200%
        let ok = static_result(SizeGroup::Tiny, Algorithm::HeftmBl, true, 1.0);
        let all = memory_usage(&[bad.clone(), ok.clone()], false);
        assert!(all.to_csv().contains("HEFT,200.0"));
        let valid = memory_usage(&[bad, ok], true);
        assert!(valid.to_csv().contains("HEFT,-"));
    }

    #[test]
    fn dynamic_tables() {
        let r = DynamicResult {
            spec_id: "x".into(),
            group: SizeGroup::Tiny,
            algo: Algorithm::HeftmMm,
            initially_valid: true,
            recompute_ok: true,
            recompute_makespan: 80.0,
            recomputations: 3,
            static_ok: true,
            static_makespan: 100.0,
        };
        let v = dynamic_validity(&[r.clone()]);
        assert!(v.to_csv().contains("HEFTM-MM,1,1,1,1,3.0"));
        let imp = dynamic_improvement(&[r]);
        assert!(imp.to_csv().contains("HEFTM-MM,20.0"));
    }
}
