//! Experiment harness (§VI): builds the paper's workload suite and runs
//! the static and dynamic evaluations whose aggregates regenerate every
//! figure (see DESIGN.md's per-experiment index).
//!
//! Suite (§VI-A-1): the five real-workflow models at native (tiny) size
//! plus size-scaled variants of the four scalable families, each bound
//! with historical weights at five input sizes. The full paper sweep
//! (up to 30 000 tasks) is behind [`SuiteScale::Full`]; the default
//! [`SuiteScale::Quick`] covers all four size groups with a budget that
//! fits CI.

pub mod figures;

use crate::generator::{self, models};
use crate::platform::Cluster;
use crate::scheduler::{Algorithm, EvictionPolicy, Schedule, ScheduleRequest};
use crate::service::{
    ClusterSpec, Job, JobResult, JobSource, ReplaySweep, SchedulingService, ScorePool,
    ServiceConfig, SimJob,
};
use crate::simulator::{DeviationModel, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold};
use crate::traces::{self, HistoricalData, TraceConfig};
use crate::workflow::{SizeGroup, Workflow};
use std::sync::Arc;

/// How large a suite to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SuiteScale {
    /// Sizes {200, 1k, 2k, 4k, 10k, 20k}, 2 input sizes: every size group
    /// populated, minutes of runtime.
    Quick,
    /// Tiny-only (unit/integration tests): native workflows, 2 inputs.
    Smoke,
    /// The paper's full sweep: 11 sizes × 5 inputs (tens of minutes).
    Full,
}

impl SuiteScale {
    pub fn sizes(self) -> Vec<usize> {
        match self {
            SuiteScale::Smoke => vec![],
            SuiteScale::Quick => vec![200, 1000, 2000, 4000, 10000, 20000],
            SuiteScale::Full => models::PAPER_SIZES.to_vec(),
        }
    }

    pub fn inputs(self) -> Vec<usize> {
        match self {
            SuiteScale::Smoke | SuiteScale::Quick => vec![2, 4],
            SuiteScale::Full => vec![0, 1, 2, 3, 4],
        }
    }
}

impl std::str::FromStr for SuiteScale {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "smoke" => Ok(SuiteScale::Smoke),
            "quick" => Ok(SuiteScale::Quick),
            "full" => Ok(SuiteScale::Full),
            other => anyhow::bail!("unknown suite scale `{other}`"),
        }
    }
}

/// One workload instance of the suite.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Family (model workflow name).
    pub family: String,
    /// Target size; `None` = the native (tiny) expansion.
    pub size: Option<usize>,
    /// Input-size index (0..5).
    pub input: usize,
    /// Seed for generator + trace synthesis.
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn id(&self) -> String {
        match self.size {
            Some(s) => format!("{}_{s}_in{}", self.family, self.input),
            None => format!("{}_native_in{}", self.family, self.input),
        }
    }

    /// Materialize: generate the graph and bind trace weights.
    pub fn build(&self) -> anyhow::Result<Workflow> {
        let model = models::by_name(&self.family)
            .ok_or_else(|| anyhow::anyhow!("unknown model `{}`", self.family))?;
        let graph = match self.size {
            Some(s) => generator::scale_to(&model, s, self.seed)?,
            None => generator::expand(&model, 12)?,
        };
        let types = traces::task_types(&graph);
        // Per-family trace tables: same types → same table across sizes.
        let data = HistoricalData::synthesize(
            &types,
            &TraceConfig::default(),
            self.seed ^ fxhash(&self.family),
        );
        Ok(traces::bind_weights(&graph, &data, self.input))
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    })
}

/// The workload suite at the given scale.
pub fn suite(scale: SuiteScale, seed: u64) -> Vec<WorkloadSpec> {
    let mut specs = Vec::new();
    // Native (tiny) instances: all five models.
    for model in models::all_models() {
        for &input in &scale.inputs() {
            specs.push(WorkloadSpec { family: model.name.clone(), size: None, input, seed });
        }
    }
    // Size-scaled instances: four scalable families.
    for model in models::scalable_models() {
        for &size in &scale.sizes() {
            for &input in &scale.inputs() {
                specs.push(WorkloadSpec {
                    family: model.name.clone(),
                    size: Some(size),
                    input,
                    seed: seed ^ (size as u64),
                });
            }
        }
    }
    specs
}

/// Result of one static scheduling run.
#[derive(Debug, Clone)]
pub struct StaticResult {
    pub spec_id: String,
    pub group: SizeGroup,
    /// Actual number of tasks in the instance.
    pub tasks: usize,
    pub algo: Algorithm,
    pub valid: bool,
    pub makespan: f64,
    pub mem_usage: f64,
    /// HEFT's makespan on the same instance (for Figs 2/6 normalization).
    pub heft_makespan: f64,
    /// Scheduler wall time, seconds (Fig 9).
    pub sched_seconds: f64,
}

/// Run the static evaluation of one workload against every standalone
/// algorithm ([`Algorithm::all`]).
pub fn run_static(spec: &WorkloadSpec, cluster: &Cluster) -> anyhow::Result<Vec<StaticResult>> {
    let wf = spec.build()?;
    let group = SizeGroup::of(wf.num_tasks());
    let mut results = Vec::with_capacity(Algorithm::all().len());
    let mut heft_makespan = f64::NAN;
    for &algo in Algorithm::all() {
        let t0 = std::time::Instant::now();
        let s = ScheduleRequest::new(&wf, cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
        let dt = t0.elapsed().as_secs_f64();
        if algo == Algorithm::Heft {
            heft_makespan = s.makespan;
        }
        results.push(StaticResult {
            spec_id: spec.id(),
            group,
            tasks: wf.num_tasks(),
            algo,
            valid: s.valid,
            makespan: s.makespan,
            mem_usage: s.mean_mem_usage(),
            heft_makespan,
            sched_seconds: dt,
        });
    }
    Ok(results)
}

/// Result of one dynamic experiment (one workload × one algorithm).
#[derive(Debug, Clone)]
pub struct DynamicResult {
    pub spec_id: String,
    pub group: SizeGroup,
    pub algo: Algorithm,
    /// Static schedule was valid to begin with.
    pub initially_valid: bool,
    /// Execution with recomputation completed.
    pub recompute_ok: bool,
    pub recompute_makespan: f64,
    pub recomputations: usize,
    /// Execution without recomputation completed.
    pub static_ok: bool,
    pub static_makespan: f64,
}

impl DynamicResult {
    /// Fig 8 metric: makespan improvement (%) of recomputation vs not,
    /// where both executions completed.
    pub fn improvement(&self) -> Option<f64> {
        if self.recompute_ok && self.static_ok && self.static_makespan > 0.0 {
            Some(100.0 * (self.static_makespan - self.recompute_makespan) / self.static_makespan)
        } else {
            None
        }
    }
}

/// Run the dynamic evaluation (paper §VI-C): both execution modes under
/// the 10% deviation model. Serial shim over [`run_dynamic_pooled`] —
/// the two are bit-identical for any pool, so this stays the baseline
/// the parity tests compare against.
pub fn run_dynamic(
    spec: &WorkloadSpec,
    cluster: &Cluster,
    algo: Algorithm,
    sigma: f64,
) -> anyhow::Result<DynamicResult> {
    run_dynamic_pooled(spec, cluster, algo, sigma, None)
}

/// [`run_dynamic`] with an optional scoring pool applied to both the
/// static schedule computation and every Recompute-mode mid-run
/// rescheduling pass. The pooled per-task reduction is deterministic
/// (min finish time, lowest `ProcId` on ties — exactly the serial
/// order), so outcomes are bit-identical for any pool size. The two
/// executions replay one static schedule, so they share one
/// [`SimScaffold`] (including its lazily hoisted selector state) and
/// one [`SimRun`] arena (bit-identical to two standalone `simulate`
/// calls).
pub fn run_dynamic_pooled(
    spec: &WorkloadSpec,
    cluster: &Cluster,
    algo: Algorithm,
    sigma: f64,
    pool: Option<&ScorePool>,
) -> anyhow::Result<DynamicResult> {
    let wf = spec.build()?;
    let group = SizeGroup::of(wf.num_tasks());
    let schedule: Schedule = ScheduleRequest::new(&wf, cluster)
        .algo(algo)
        .policy(EvictionPolicy::LargestFirst)
        .score_pool(pool)
        .run();
    let initially_valid = schedule.valid;
    let dev = DeviationModel::new(sigma, spec.seed ^ 0xdeu64);
    let (rec, stat): (SimOutcome, SimOutcome) = if initially_valid {
        let scaffold =
            SimScaffold::new(Arc::new(wf), Arc::new(cluster.clone()), Arc::new(schedule));
        let mut run = SimRun::new();
        // Summary variant: DynamicResult never reads finish_times.
        (
            run.simulate_summary_with(&scaffold, &SimConfig::new(SimMode::Recompute, dev), pool),
            run.simulate_summary_with(&scaffold, &SimConfig::new(SimMode::FollowStatic, dev), pool),
        )
    } else {
        // Invalid initial schedule: executions are not attempted.
        let nan = SimOutcome {
            completed: false,
            makespan: f64::NAN,
            failure: None,
            recomputations: 0,
            started: 0,
            finish_times: vec![],
        };
        (nan.clone(), nan)
    };
    Ok(DynamicResult {
        spec_id: spec.id(),
        group,
        algo,
        initially_valid,
        recompute_ok: rec.completed,
        recompute_makespan: rec.makespan,
        recomputations: rec.recomputations,
        static_ok: stat.completed,
        static_makespan: stat.makespan,
    })
}

/// Run a batch through the service's ordered streaming API, printing a
/// per-job completion counter to stderr every ~5% of the batch (and at
/// the end). Suite runs previously printed only a start line — on the
/// Full sweep that meant tens of silent minutes.
fn run_batch_with_progress(service: &SchedulingService, jobs: Vec<Job>) -> Vec<JobResult> {
    let total = jobs.len();
    let step = (total / 20).max(1);
    let mut out: Vec<JobResult> = Vec::with_capacity(total);
    service.run_batch_streaming(jobs, |r| {
        out.push(r);
        let done = out.len();
        if done % step == 0 || done == total {
            eprintln!("  progress: {done}/{total} jobs");
        }
    });
    out
}

/// [`run_batch_with_progress`], replay-sweep flavoured: the counter runs
/// over the flattened replay-point stream.
fn run_sweeps_with_progress(service: &SchedulingService, sweeps: Vec<ReplaySweep>) -> Vec<JobResult> {
    let total: usize = sweeps.iter().map(ReplaySweep::num_results).sum();
    let step = (total / 20).max(1);
    let mut out: Vec<JobResult> = Vec::with_capacity(total);
    service.run_replay_sweeps_streaming(sweeps, |r| {
        out.push(r);
        let done = out.len();
        if done % step == 0 || done == total {
            eprintln!("  progress: {done}/{total} replay points");
        }
    });
    out
}

/// Print the service's run-summary record (cache-hit / schedule-reuse
/// counters) to stderr — the machine-readable side channel `ci.sh`
/// greps; the figure tables on stdout stay byte-deterministic.
fn eprint_summary(service: &SchedulingService, results: &[JobResult]) {
    let hits = results.iter().filter(|r| r.cache_hit).count();
    let failed = results.iter().filter(|r| r.error.is_some()).count();
    eprintln!("{}", service.summary_json(results.len(), hits, failed).to_string_compact());
}

/// Build the static-evaluation job grid (workflow × size × input ×
/// algorithm) for submission through the scheduling service. Job order is
/// spec-major, algorithm-minor with [`Algorithm::all`]'s ordering — the
/// suite runners below rely on it for reassembly.
pub fn static_suite_jobs(scale: SuiteScale, seed: u64, cluster: &ClusterSpec) -> Vec<Job> {
    jobs_for_specs(&suite(scale, seed), cluster)
}

/// One static job per (spec, algorithm) cell, spec-major in the given
/// spec order, algorithm-minor in [`Algorithm::all`] order.
fn jobs_for_specs(specs: &[WorkloadSpec], cluster: &ClusterSpec) -> Vec<Job> {
    let mut jobs = Vec::with_capacity(specs.len() * Algorithm::all().len());
    for spec in specs {
        for &algo in Algorithm::all() {
            jobs.push(Job {
                source: JobSource::Generated(spec.clone()),
                cluster: cluster.clone(),
                algo,
                policy: EvictionPolicy::LargestFirst,
                sim: None,
            });
        }
    }
    jobs
}

/// Run the static suite through a scheduling service built from `cfg`.
/// Semantically identical to looping [`run_static`] over [`suite`]
/// (same workloads, same normalization by HEFT's makespan), but the
/// grid executes on the work-stealing pool and identical (workflow,
/// cluster, algorithm) cells dedupe through the schedule cache — which
/// may additionally be disk-backed (`cfg.cache_dir`) so repeated
/// invocations share schedules across processes. Score threads > 1 (or
/// `Auto`) parallelize the inside of each schedule computation (shared
/// [`ScorePool`](crate::service::ScorePool); byte-identical results) —
/// the lever for huge single workflows.
///
/// Progress: one stderr counter line per ~5% of completed jobs (fed
/// from the service's ordered streaming sink), plus a final JSONL
/// summary record with the cache/reuse counters.
///
/// Caveat: `sched_seconds` (Fig 9) is wall time measured while other
/// schedules may be computing on sibling workers; for contention-free
/// heuristic timings, run with `cfg.workers = 1`.
pub fn run_static_suite(
    scale: SuiteScale,
    seed: u64,
    cluster: &Cluster,
    cfg: &ServiceConfig,
) -> anyhow::Result<Vec<StaticResult>> {
    let specs = suite(scale, seed);
    let cspec = ClusterSpec::Inline(Arc::new(cluster.clone()));
    // Jobs are built from the very `specs` vec the reassembly below
    // indexes, so the chunk arithmetic cannot drift out of sync.
    let jobs = jobs_for_specs(&specs, &cspec);
    let service = cfg.build()?;
    eprintln!(
        "static suite `{}`: {} workloads × {} algorithms on {} worker(s), {} score thread(s)...",
        cluster.name,
        specs.len(),
        Algorithm::all().len(),
        service.workers(),
        service.score_threads()
    );
    let results = run_batch_with_progress(&service, jobs);
    eprint_summary(&service, &results);
    let algos = Algorithm::all();
    let mut out = Vec::with_capacity(results.len());
    for (si, spec) in specs.iter().enumerate() {
        let chunk = &results[si * algos.len()..(si + 1) * algos.len()];
        for r in chunk {
            if let Some(e) = &r.error {
                anyhow::bail!("suite workload `{}` failed: {e}", spec.id());
            }
        }
        // Algorithm::all() leads with HEFT, whose makespan normalizes the
        // spec's rows (Figs 2/6) exactly as in the serial `run_static`.
        let heft_makespan = chunk[0].makespan;
        for (ai, algo) in algos.into_iter().enumerate() {
            let r = &chunk[ai];
            out.push(StaticResult {
                spec_id: spec.id(),
                group: SizeGroup::of(r.tasks),
                tasks: r.tasks,
                algo,
                valid: r.valid,
                makespan: r.makespan,
                mem_usage: r.mem_usage,
                heft_makespan,
                sched_seconds: r.seconds,
            });
        }
    }
    Ok(out)
}

/// The dynamic suite's workload set: sizes ≤ 2000 of the full grid (the
/// paper's §VI-C restriction).
pub fn dynamic_suite_specs(scale: SuiteScale, seed: u64) -> Vec<WorkloadSpec> {
    suite(scale, seed).into_iter().filter(|s| s.size.is_none_or(|n| n <= 2000)).collect()
}

/// The dynamic suite as replay sweeps: one sweep per (workload,
/// algorithm) cell carrying `2 × sigmas.len()` replay points —
/// `[Recompute, FollowStatic]` per sigma, in the given sigma order, with
/// the suite's per-spec deviation seed. Shared by
/// [`run_dynamic_suite`] and `memsched batch --suite … --sigmas …`.
pub fn dynamic_suite_sweeps(
    specs: &[WorkloadSpec],
    cluster: &ClusterSpec,
    sigmas: &[f64],
) -> Vec<ReplaySweep> {
    let mut sweeps = Vec::with_capacity(specs.len() * Algorithm::all().len());
    for spec in specs {
        let dev_seed = spec.seed ^ 0xdeu64;
        for &algo in Algorithm::all() {
            let points: Vec<SimJob> = sigmas
                .iter()
                .flat_map(|&sigma| {
                    [SimMode::Recompute, SimMode::FollowStatic]
                        .into_iter()
                        .map(move |mode| SimJob { mode, sigma, seed: dev_seed })
                })
                .collect();
            sweeps.push(ReplaySweep {
                source: JobSource::Generated(spec.clone()),
                cluster: cluster.clone(),
                algo,
                policy: EvictionPolicy::LargestFirst,
                points,
            });
        }
    }
    sweeps
}

/// Run the dynamic suite (sizes ≤ 2000, both execution modes per
/// workload × algorithm) under every deviation level in `sigmas`,
/// through the service's replay engine: each (workload, algorithm)
/// cell's static schedule is computed **exactly once** and replayed at
/// every `(sigma, mode)` point — previously each sigma level recomputed
/// the full schedule grid from scratch.
///
/// Returns one result vector per sigma, in `sigmas` order; each vector
/// is element-for-element (bit-)identical to what a single-sigma run
/// produces, so multi-sigma output concatenates to the per-sigma
/// baseline.
pub fn run_dynamic_suite(
    scale: SuiteScale,
    seed: u64,
    cluster: &Cluster,
    sigmas: &[f64],
    cfg: &ServiceConfig,
) -> anyhow::Result<Vec<Vec<DynamicResult>>> {
    anyhow::ensure!(!sigmas.is_empty(), "at least one sigma level is required");
    let specs = dynamic_suite_specs(scale, seed);
    let cspec = ClusterSpec::Inline(Arc::new(cluster.clone()));
    let sweeps = dynamic_suite_sweeps(&specs, &cspec, sigmas);
    let service = cfg.build()?;
    eprintln!(
        "dynamic suite `{}`: {} workloads × {} algorithms × {} sigma(s) × 2 modes on {} worker(s), {} score thread(s)...",
        cluster.name,
        specs.len(),
        Algorithm::all().len(),
        sigmas.len(),
        service.workers(),
        service.score_threads()
    );
    let results = run_sweeps_with_progress(&service, sweeps);
    eprint_summary(&service, &results);
    // Reassemble the flattened stream (sweep-major over spec × algo,
    // point-minor: sigma-major, [Recompute, FollowStatic]-minor) into
    // per-sigma tables.
    let mut out: Vec<Vec<DynamicResult>> =
        sigmas.iter().map(|_| Vec::with_capacity(specs.len() * Algorithm::all().len())).collect();
    let mut it = results.iter();
    for spec in &specs {
        for &algo in Algorithm::all() {
            for per_sigma in out.iter_mut() {
                let rec = it.next().expect("one Recompute row per (spec, algo, sigma)");
                let stat = it.next().expect("one FollowStatic row per (spec, algo, sigma)");
                for r in [rec, stat] {
                    if let Some(e) = &r.error {
                        anyhow::bail!("suite workload `{}` failed: {e}", spec.id());
                    }
                }
                let rsim = rec.sim.as_ref().expect("dynamic jobs carry sim results");
                let ssim = stat.sim.as_ref().expect("dynamic jobs carry sim results");
                per_sigma.push(DynamicResult {
                    spec_id: spec.id(),
                    group: SizeGroup::of(rec.tasks),
                    algo,
                    initially_valid: rec.valid,
                    recompute_ok: rsim.completed,
                    recompute_makespan: rsim.makespan,
                    recomputations: rsim.recomputations,
                    static_ok: ssim.completed,
                    static_makespan: ssim.makespan,
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets;

    #[test]
    fn suite_composition() {
        let smoke = suite(SuiteScale::Smoke, 1);
        // 5 models × 2 inputs, no scaled sizes.
        assert_eq!(smoke.len(), 10);
        let quick = suite(SuiteScale::Quick, 1);
        // 10 native + 4 families × 6 sizes × 2 inputs.
        assert_eq!(quick.len(), 10 + 4 * 6 * 2);
        let full = suite(SuiteScale::Full, 1);
        // 25 native + 4 × 11 × 5 = 245 (the paper's suite scale).
        assert_eq!(full.len(), 25 + 220);
    }

    #[test]
    fn spec_build_is_deterministic() {
        let spec = WorkloadSpec { family: "eager".into(), size: Some(200), input: 1, seed: 5 };
        let a = spec.build().unwrap();
        let b = spec.build().unwrap();
        assert_eq!(a.num_tasks(), b.num_tasks());
        assert_eq!(a.task(3).work, b.task(3).work);
        let group = SizeGroup::of(a.num_tasks());
        assert_eq!(group, SizeGroup::Tiny);
    }

    #[test]
    fn static_run_produces_all_algorithms() {
        let spec = WorkloadSpec { family: "bacass".into(), size: None, input: 0, seed: 2 };
        let cluster = presets::small_cluster();
        let rs = run_static(&spec, &cluster).unwrap();
        assert_eq!(rs.len(), Algorithm::all().len());
        assert!(rs.iter().any(|r| r.algo == Algorithm::Heft));
        // HEFT makespan recorded for normalization on every row.
        assert!(rs.iter().all(|r| r.heft_makespan > 0.0));
    }

    #[test]
    fn dynamic_run_smoke() {
        let spec = WorkloadSpec { family: "chipseq".into(), size: None, input: 0, seed: 3 };
        let cluster = presets::small_cluster();
        let r = run_dynamic(&spec, &cluster, Algorithm::HeftmBl, 0.1).unwrap();
        assert!(r.initially_valid);
        assert!(r.recompute_ok);
        if let Some(imp) = r.improvement() {
            assert!(imp.abs() < 100.0);
        }
    }

    #[test]
    fn pooled_dynamic_run_matches_serial_bit_exactly() {
        let spec = WorkloadSpec { family: "chipseq".into(), size: None, input: 0, seed: 3 };
        let cluster = presets::small_cluster();
        let pool = ScorePool::new(4);
        for algo in [Algorithm::HeftmBl, Algorithm::Peft, Algorithm::Dls] {
            let serial = run_dynamic(&spec, &cluster, algo, 0.3).unwrap();
            let pooled = run_dynamic_pooled(&spec, &cluster, algo, 0.3, Some(&pool)).unwrap();
            assert_eq!(serial.recompute_makespan.to_bits(), pooled.recompute_makespan.to_bits());
            assert_eq!(serial.static_makespan.to_bits(), pooled.static_makespan.to_bits());
            assert_eq!(serial.recomputations, pooled.recomputations);
            assert_eq!(serial.recompute_ok, pooled.recompute_ok);
        }
    }

    fn cfg(workers: usize, score_threads: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            score: crate::service::ScoreThreadSpec::Fixed(score_threads),
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn pooled_static_suite_matches_serial() {
        let cluster = presets::small_cluster();
        let pooled = run_static_suite(SuiteScale::Smoke, 1, &cluster, &cfg(4, 1)).unwrap();
        let mut serial = Vec::new();
        for spec in suite(SuiteScale::Smoke, 1) {
            serial.extend(run_static(&spec, &cluster).unwrap());
        }
        assert_eq!(pooled.len(), serial.len());
        for (p, s) in pooled.iter().zip(&serial) {
            assert_eq!(p.spec_id, s.spec_id);
            assert_eq!(p.algo, s.algo);
            assert_eq!(p.valid, s.valid);
            assert_eq!(p.makespan, s.makespan, "{}/{:?}", p.spec_id, p.algo);
            assert_eq!(p.heft_makespan, s.heft_makespan);
            assert_eq!(p.mem_usage, s.mem_usage);
            assert_eq!(p.tasks, s.tasks);
        }
    }

    #[test]
    fn pooled_dynamic_suite_matches_serial() {
        let cluster = presets::small_cluster();
        let pooled = run_dynamic_suite(SuiteScale::Smoke, 1, &cluster, &[0.1], &cfg(4, 2)).unwrap();
        assert_eq!(pooled.len(), 1, "one table per sigma");
        let mut serial = Vec::new();
        for spec in suite(SuiteScale::Smoke, 1) {
            for &algo in Algorithm::all() {
                serial.push(run_dynamic(&spec, &cluster, algo, 0.1).unwrap());
            }
        }
        assert_eq!(pooled[0].len(), serial.len());
        for (p, s) in pooled[0].iter().zip(&serial) {
            assert_eq!(p.spec_id, s.spec_id);
            assert_eq!(p.algo, s.algo);
            assert_eq!(p.initially_valid, s.initially_valid);
            assert_eq!(p.recompute_ok, s.recompute_ok);
            assert_eq!(p.static_ok, s.static_ok);
            // NaN markers (skipped executions) compare via bits.
            assert_eq!(p.recompute_makespan.to_bits(), s.recompute_makespan.to_bits());
            assert_eq!(p.static_makespan.to_bits(), s.static_makespan.to_bits());
            assert_eq!(p.recomputations, s.recomputations);
        }
    }

    fn dynamic_results_bit_equal(a: &[DynamicResult], b: &[DynamicResult]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.spec_id, y.spec_id);
            assert_eq!(x.algo, y.algo);
            assert_eq!(x.initially_valid, y.initially_valid);
            assert_eq!(x.recompute_ok, y.recompute_ok);
            assert_eq!(x.static_ok, y.static_ok);
            assert_eq!(x.recompute_makespan.to_bits(), y.recompute_makespan.to_bits());
            assert_eq!(x.static_makespan.to_bits(), y.static_makespan.to_bits());
            assert_eq!(x.recomputations, y.recomputations);
        }
    }

    #[test]
    fn multi_sigma_suite_matches_per_sigma_baseline() {
        // The replay-engine guarantee at suite level: a multi-sigma run
        // equals the per-sigma runs, table for table, bit for bit —
        // across worker counts.
        let cluster = presets::small_cluster();
        let sigmas = [0.1, 0.3];
        let multi = run_dynamic_suite(SuiteScale::Smoke, 1, &cluster, &sigmas, &cfg(4, 1)).unwrap();
        assert_eq!(multi.len(), 2);
        for (si, &sigma) in sigmas.iter().enumerate() {
            let single =
                run_dynamic_suite(SuiteScale::Smoke, 1, &cluster, &[sigma], &cfg(1, 1)).unwrap();
            dynamic_results_bit_equal(&multi[si], &single[0]);
        }
    }

    #[test]
    fn multi_sigma_sweeps_compute_each_schedule_once() {
        // Acceptance check, service-level: the sweep grid of a
        // multi-sigma dynamic suite computes one schedule per
        // (workload, algorithm) cell, however many sigmas it replays.
        let cluster = presets::small_cluster();
        let specs = dynamic_suite_specs(SuiteScale::Smoke, 1);
        let cspec = ClusterSpec::Inline(Arc::new(cluster.clone()));
        let sweeps = dynamic_suite_sweeps(&specs, &cspec, &[0.1, 0.2, 0.5]);
        let service = SchedulingService::new(4);
        let results = service.run_replay_sweeps(sweeps);
        assert!(results.iter().all(|r| r.error.is_none()));
        assert_eq!(results.len(), specs.len() * Algorithm::all().len() * 3 * 2);
        let stats = service.cache_stats();
        assert_eq!(
            stats.computed,
            specs.len() * Algorithm::all().len(),
            "each static schedule must be computed exactly once"
        );
        assert_eq!(stats.lookups, results.len());
    }
}
