//! Workflow generation (paper §VI-A-1a).
//!
//! The paper evaluates on five real nf-core workflows (atacseq, bacass,
//! chipseq, eager, methylseq) plus size-scaled variants produced by the
//! WfGen/WfCommons generator. Neither the nextflow DAG dumps nor WfGen are
//! available offline, so this module provides:
//!
//! - [`models`]: structural *model workflows* for the five pipelines —
//!   stage-structured DAGs (per-sample chains, scatter fan-outs, gather
//!   joins) with task types mirroring the published pipeline stages;
//! - [`expand`]: instantiation of a model for a number of samples;
//! - [`scale_to`]: WfGen-like scaling of a model to a target task count.
//!
//! Weights (work/memory/file sizes) are *not* assigned here; they are bound
//! from historical traces by [`crate::traces::bind_weights`], exactly as in
//! the paper.

pub mod models;

use crate::util::rng::Rng;
use crate::workflow::{Workflow, WorkflowBuilder};
use anyhow::{bail, Result};

/// How a stage's tasks are instantiated and wired to the previous stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StageKind {
    /// One task per sample, connected to the same sample's previous tasks.
    PerSample,
    /// `width` tasks per sample (fan-out within the sample lane).
    Scatter(usize),
    /// A single task joining *all* tasks of the previous stage.
    Gather,
    /// A fixed number of tasks independent of the sample count; previous
    /// tasks are distributed round-robin over them.
    Fixed(usize),
}

/// One pipeline stage: a task type and an instantiation rule.
#[derive(Debug, Clone)]
pub struct Stage {
    /// Task type (binds to historical trace rows), e.g. `bwa_align`.
    pub task_type: String,
    pub kind: StageKind,
}

impl Stage {
    pub fn new(task_type: &str, kind: StageKind) -> Stage {
        Stage { task_type: task_type.to_string(), kind }
    }
}

/// A model workflow: an ordered list of stages.
#[derive(Debug, Clone)]
pub struct ModelWorkflow {
    pub name: String,
    pub stages: Vec<Stage>,
}

impl ModelWorkflow {
    /// Tasks produced per sample lane (scatter widths included).
    pub fn tasks_per_sample(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s.kind {
                StageKind::PerSample => 1,
                StageKind::Scatter(w) => w,
                _ => 0,
            })
            .sum()
    }

    /// Tasks independent of the sample count.
    pub fn fixed_tasks(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s.kind {
                StageKind::Gather => 1,
                StageKind::Fixed(c) => c,
                _ => 0,
            })
            .sum()
    }

    /// Total tasks for `samples` lanes.
    pub fn total_tasks(&self, samples: usize) -> usize {
        self.tasks_per_sample() * samples + self.fixed_tasks()
    }
}

/// Instantiate a model for `samples` sample lanes. Deterministic: no
/// randomness is used for the base expansion (jitter belongs to
/// [`scale_to`]).
pub fn expand(model: &ModelWorkflow, samples: usize) -> Result<Workflow> {
    expand_named(model, samples, &model.name)
}

fn expand_named(model: &ModelWorkflow, samples: usize, name: &str) -> Result<Workflow> {
    if samples == 0 {
        bail!("cannot expand model `{}` with zero samples", model.name);
    }
    if model.stages.is_empty() {
        bail!("model `{}` has no stages", model.name);
    }
    let mut b = WorkflowBuilder::new(name);
    // prev_per_sample[s] = the sample-lane frontier tasks of lane s;
    // prev_global = frontier tasks of the last global (gather/fixed) stage.
    let mut prev_per_sample: Vec<Vec<usize>> = vec![Vec::new(); samples];
    let mut prev_global: Vec<usize> = Vec::new();
    let mut lanes_active = false; // are per-sample frontiers current?

    for (si, stage) in model.stages.iter().enumerate() {
        match stage.kind {
            StageKind::PerSample | StageKind::Scatter(_) => {
                let width = match stage.kind {
                    StageKind::Scatter(w) => w.max(1),
                    _ => 1,
                };
                for s in 0..samples {
                    let mut new_frontier = Vec::with_capacity(width);
                    for w in 0..width {
                        let tname = if width == 1 {
                            format!("{}_{}", stage.task_type, s)
                        } else {
                            format!("{}_{}_{}", stage.task_type, s, w)
                        };
                        let id = b.task(tname, &stage.task_type, 0.0, 0.0);
                        if lanes_active {
                            for &p in &prev_per_sample[s] {
                                b.edge(p, id, 0.0);
                            }
                        } else {
                            // First stage, or following a global stage.
                            for &p in &prev_global {
                                b.edge(p, id, 0.0);
                            }
                        }
                        new_frontier.push(id);
                    }
                    prev_per_sample[s] = new_frontier;
                }
                lanes_active = true;
            }
            StageKind::Gather | StageKind::Fixed(_) => {
                let count = match stage.kind {
                    StageKind::Fixed(c) => c.max(1),
                    _ => 1,
                };
                let sources: Vec<usize> = if lanes_active {
                    prev_per_sample.iter().flatten().copied().collect()
                } else {
                    prev_global.clone()
                };
                let mut new_global = Vec::with_capacity(count);
                for c in 0..count {
                    let tname = if count == 1 {
                        format!("{}_s{}", stage.task_type, si)
                    } else {
                        format!("{}_s{}_{}", stage.task_type, si, c)
                    };
                    let id = b.task(tname, &stage.task_type, 0.0, 0.0);
                    if count == 1 {
                        for &p in &sources {
                            b.edge(p, id, 0.0);
                        }
                    } else {
                        // Round-robin distribution over the fixed tasks.
                        for (i, &p) in sources.iter().enumerate() {
                            if i % count == c {
                                b.edge(p, id, 0.0);
                            }
                        }
                    }
                    new_global.push(id);
                }
                prev_global = new_global;
                lanes_active = false;
            }
        }
    }
    b.build()
}

/// WfGen-like scaling: produce a variant of `model` with approximately
/// `target_tasks` tasks. Mirrors the paper's generator behaviour: the
/// sample count is derived from the target, and scatter widths receive a
/// small seeded jitter so that different sizes are not exact photocopies
/// (§VI-A-1a notes the generator's "varying nature").
pub fn scale_to(model: &ModelWorkflow, target_tasks: usize, seed: u64) -> Result<Workflow> {
    if target_tasks == 0 {
        bail!("target task count must be positive");
    }
    let mut rng = Rng::new(seed ^ 0x7767_656e); // "wgen"
    // Jitter scatter widths by -1/0/+1 (clamped to >= 1).
    let mut jittered = model.clone();
    for st in &mut jittered.stages {
        if let StageKind::Scatter(w) = st.kind {
            let delta = rng.range_inclusive(0, 2) as i64 - 1;
            st.kind = StageKind::Scatter(((w as i64 + delta).max(1)) as usize);
        }
    }
    let per_sample = jittered.tasks_per_sample().max(1);
    let fixed = jittered.fixed_tasks();
    let samples = ((target_tasks.saturating_sub(fixed)) as f64 / per_sample as f64)
        .round()
        .max(1.0) as usize;
    let name = format!("{}_{}", model.name, target_tasks);
    expand_named(&jittered, samples, &name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::models::*;

    #[test]
    fn expand_produces_valid_dag() {
        for model in all_models() {
            let wf = expand(&model, 4).unwrap();
            assert!(wf.num_tasks() > 0, "{}", model.name);
            let order = wf.topological_order();
            assert!(wf.is_topological_order(&order), "{}", model.name);
            // Connected enough: exactly the first stage's tasks are sources.
            assert!(!wf.sources().is_empty());
        }
    }

    #[test]
    fn task_count_formula_matches() {
        for model in all_models() {
            for samples in [1, 3, 10] {
                let wf = expand(&model, samples).unwrap();
                assert_eq!(
                    wf.num_tasks(),
                    model.total_tasks(samples),
                    "{} samples={samples}",
                    model.name
                );
            }
        }
    }

    #[test]
    fn gather_joins_all_lanes() {
        let model = ModelWorkflow {
            name: "g".into(),
            stages: vec![
                Stage::new("a", StageKind::PerSample),
                Stage::new("join", StageKind::Gather),
            ],
        };
        let wf = expand(&model, 5).unwrap();
        assert_eq!(wf.num_tasks(), 6);
        let gather = wf.sinks()[0];
        assert_eq!(wf.in_degree(gather), 5);
    }

    #[test]
    fn per_sample_after_gather_fans_out_from_it() {
        let model = ModelWorkflow {
            name: "g2".into(),
            stages: vec![
                Stage::new("a", StageKind::PerSample),
                Stage::new("join", StageKind::Gather),
                Stage::new("b", StageKind::PerSample),
            ],
        };
        let wf = expand(&model, 3).unwrap();
        // join has out-degree 3 (one per sample lane).
        let join = (0..wf.num_tasks()).find(|&u| wf.task(u).task_type == "join").unwrap();
        assert_eq!(wf.out_degree(join), 3);
    }

    #[test]
    fn scatter_width_multiplies_tasks() {
        let model = ModelWorkflow {
            name: "sc".into(),
            stages: vec![
                Stage::new("a", StageKind::PerSample),
                Stage::new("b", StageKind::Scatter(3)),
                Stage::new("c", StageKind::PerSample),
            ],
        };
        let wf = expand(&model, 2).unwrap();
        assert_eq!(wf.num_tasks(), 2 * (1 + 3 + 1));
        // Each c task joins its sample's 3 scatter tasks.
        let c0 = (0..wf.num_tasks()).find(|&u| wf.task(u).name == "c_0").unwrap();
        assert_eq!(wf.in_degree(c0), 3);
    }

    #[test]
    fn fixed_distributes_round_robin() {
        let model = ModelWorkflow {
            name: "fx".into(),
            stages: vec![
                Stage::new("a", StageKind::PerSample),
                Stage::new("b", StageKind::Fixed(2)),
            ],
        };
        let wf = expand(&model, 4).unwrap();
        let sinks = wf.sinks();
        assert_eq!(sinks.len(), 2);
        assert_eq!(wf.in_degree(sinks[0]), 2);
        assert_eq!(wf.in_degree(sinks[1]), 2);
    }

    #[test]
    fn scale_hits_target_approximately() {
        for model in scalable_models() {
            for target in [200usize, 1000, 4000] {
                let wf = scale_to(&model, target, 11).unwrap();
                let n = wf.num_tasks();
                let err = (n as f64 - target as f64).abs() / target as f64;
                assert!(err < 0.25, "{}: target {target}, got {n}", model.name);
                assert!(wf.is_topological_order(&wf.topological_order()));
            }
        }
    }

    #[test]
    fn scale_is_deterministic_per_seed() {
        let model = &scalable_models()[0];
        let a = scale_to(model, 1000, 5).unwrap();
        let b = scale_to(model, 1000, 5).unwrap();
        assert_eq!(a.num_tasks(), b.num_tasks());
        assert_eq!(a.num_edges(), b.num_edges());
    }

    #[test]
    fn zero_inputs_rejected() {
        let model = &all_models()[0];
        assert!(expand(model, 0).is_err());
        assert!(scale_to(model, 0, 1).is_err());
    }
}
