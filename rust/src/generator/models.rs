//! Model workflows for the five nf-core pipelines used in the paper
//! (§VI-A-1a): atacseq, bacass, chipseq, eager, methylseq.
//!
//! Each model encodes the pipeline's published stage structure: per-sample
//! processing chains, within-sample scatter (e.g. per-replicate or
//! per-context analysis), and global gather/report stages. Task type names
//! follow the nf-core process names so that historical trace tables key
//! naturally.

use super::{ModelWorkflow, Stage, StageKind};
use StageKind::{Fixed, Gather, PerSample, Scatter};

/// nf-core/atacseq: ATAC-seq peak calling.
pub fn atacseq() -> ModelWorkflow {
    ModelWorkflow {
        name: "atacseq".into(),
        stages: vec![
            Stage::new("fastqc", PerSample),
            Stage::new("trim_galore", PerSample),
            Stage::new("bwa_mem", PerSample),
            Stage::new("samtools_filter", Scatter(2)),
            Stage::new("picard_merge", PerSample),
            Stage::new("macs2_callpeak", PerSample),
            Stage::new("consensus_peaks", Gather),
            Stage::new("homer_annotate", Fixed(2)),
            Stage::new("multiqc", Gather),
        ],
    }
}

/// nf-core/bacass: bacterial assembly. Short pipeline; the paper's
/// generator failed on it, so it is only used at its native (tiny) size.
pub fn bacass() -> ModelWorkflow {
    ModelWorkflow {
        name: "bacass".into(),
        stages: vec![
            Stage::new("fastqc", PerSample),
            Stage::new("skewer_trim", PerSample),
            Stage::new("unicycler", PerSample),
            Stage::new("prokka", PerSample),
            Stage::new("quast", Gather),
            Stage::new("multiqc", Gather),
        ],
    }
}

/// nf-core/chipseq: ChIP-seq analysis.
pub fn chipseq() -> ModelWorkflow {
    ModelWorkflow {
        name: "chipseq".into(),
        stages: vec![
            Stage::new("fastqc", PerSample),
            Stage::new("trim_galore", PerSample),
            Stage::new("bwa_mem", PerSample),
            Stage::new("picard_markdup", PerSample),
            Stage::new("phantompeakqualtools", Scatter(2)),
            Stage::new("macs2_callpeak", PerSample),
            Stage::new("homer_annotatepeaks", PerSample),
            Stage::new("igv_session", Gather),
            Stage::new("multiqc", Gather),
        ],
    }
}

/// nf-core/eager: ancient DNA analysis (the longest per-sample chain).
pub fn eager() -> ModelWorkflow {
    ModelWorkflow {
        name: "eager".into(),
        stages: vec![
            Stage::new("fastqc", PerSample),
            Stage::new("adapter_removal", PerSample),
            Stage::new("bwa_aln", PerSample),
            Stage::new("samtools_filter", PerSample),
            Stage::new("dedup", PerSample),
            Stage::new("damageprofiler", Scatter(2)),
            Stage::new("angsd_contamination", PerSample),
            Stage::new("qualimap", PerSample),
            Stage::new("genotyping_hc", PerSample),
            Stage::new("mixemt", Gather),
            Stage::new("multiqc", Gather),
        ],
    }
}

/// nf-core/methylseq: bisulfite sequencing (wide methylation scatter).
pub fn methylseq() -> ModelWorkflow {
    ModelWorkflow {
        name: "methylseq".into(),
        stages: vec![
            Stage::new("fastqc", PerSample),
            Stage::new("trim_galore", PerSample),
            Stage::new("bismark_align", PerSample),
            Stage::new("bismark_deduplicate", PerSample),
            Stage::new("methylation_extract", Scatter(3)),
            Stage::new("bismark_report", PerSample),
            Stage::new("qualimap", PerSample),
            Stage::new("preseq", Gather),
            Stage::new("multiqc", Gather),
        ],
    }
}

/// All five real-workflow models.
pub fn all_models() -> Vec<ModelWorkflow> {
    vec![atacseq(), bacass(), chipseq(), eager(), methylseq()]
}

/// The four models used for size-scaled variants (bacass excluded, as in
/// the paper: it "leads to errors in the generator").
pub fn scalable_models() -> Vec<ModelWorkflow> {
    vec![atacseq(), chipseq(), eager(), methylseq()]
}

/// Look up a model by name.
pub fn by_name(name: &str) -> Option<ModelWorkflow> {
    all_models().into_iter().find(|m| m.name == name)
}

/// The paper's size sweep for generated workflows (§VI-A-1a).
pub const PAPER_SIZES: [usize; 11] =
    [200, 1000, 2000, 4000, 8000, 10000, 15000, 18000, 20000, 25000, 30000];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_models_exist() {
        let names: Vec<String> = all_models().into_iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["atacseq", "bacass", "chipseq", "eager", "methylseq"]);
    }

    #[test]
    fn scalable_excludes_bacass() {
        assert!(scalable_models().iter().all(|m| m.name != "bacass"));
        assert_eq!(scalable_models().len(), 4);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("eager").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn native_sizes_are_tiny() {
        // Real workflows in the paper are the "tiny" group (≤ 200 tasks):
        // with a realistic sample count they stay under 200.
        for m in all_models() {
            let wf = super::super::expand(&m, 12).unwrap();
            assert!(wf.num_tasks() <= 200, "{}: {}", m.name, wf.num_tasks());
        }
    }
}
