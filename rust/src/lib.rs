//! memsched: memory-aware adaptive scheduling of scientific workflows on
//! heterogeneous architectures.
//!
//! Reproduction of S. Kulagina, A. Benoit, H. Meyerhenke, *"Memory-aware
//! Adaptive Scheduling of Scientific Workflows on Heterogeneous
//! Architectures"* (CCGrid 2025).
//!
//! # Architecture
//!
//! - [`workflow`]: the DAG substrate (tasks `w_u`, `m_u`; edges `c_{u,v}`).
//! - [`platform`]: heterogeneous clusters (speed, memory, comm buffer).
//! - [`traces`]: synthetic Lotaru-like historical task data + weight binding.
//! - [`generator`]: nf-core-like model workflows, WfGen-like size scaling.
//! - [`memdag`]: series-parallelization + min-peak-memory traversal ([19]).
//! - [`scheduler`]: HEFT baseline and the three memory-aware HEFTM variants
//!   with eviction into communication buffers, plus schedule retracing.
//!   Internally split into a `Send + Sync` scoring layer (pure tentative
//!   placement, parallelizable across processors via the service's
//!   `ScorePool` — `--score-threads`) and a single-threaded commit layer;
//!   schedules are byte-identical for any thread count.
//! - [`simulator`]: the runtime system — discrete-event execution with
//!   parameter deviations and on-the-fly schedule recomputation.
//! - [`runtime`]: PJRT bridge running the AOT-compiled XLA scoring/predictor
//!   artifacts from `artifacts/*.hlo.txt` (built once by `make artifacts`).
//! - [`experiments`], [`metrics`]: the harness regenerating every figure of
//!   the paper's evaluation (see DESIGN.md for the experiment index).
//! - [`service`]: the parallel scheduling service — batches of jobs
//!   (workflow source + platform + algorithm config + sim mode) executed
//!   on a sharded work-stealing `std::thread` pool, deduplicated through
//!   a content-addressed schedule cache, and streamed as JSONL whose
//!   bytes are identical for any worker count (DESIGN.md §Service). The
//!   experiments suite and the `memsched batch` subcommand both run
//!   through it.
//! - [`obs`]: crate-wide observability — typed events and timing spans
//!   recorded into per-thread ring buffers behind a single enable flag,
//!   exported as Chrome trace-event JSON (`memsched trace`), versioned
//!   metrics JSONL (`--metrics-json`), and live daemon stats
//!   (`{"ctl":"stats"}`). Side-channel only: result streams are
//!   byte-identical with tracing on or off.
//! - [`ser`], [`cli`], [`bench`], [`testing`]: in-tree substrates (JSON,
//!   arg parsing, bench statistics, property testing) — the build
//!   environment is offline, so these common utilities are implemented
//!   here rather than pulled from crates.io (the few external crate names
//!   that remain, `anyhow`/`libc`/`log`/`xla`, resolve to vendored shims
//!   under `rust/vendor/`).

pub mod bench;
pub mod cli;
pub mod experiments;
pub mod generator;
pub mod memdag;
pub mod metrics;
pub mod obs;
pub mod platform;
pub mod runtime;
pub mod scheduler;
pub mod ser;
pub mod service;
pub mod simulator;
pub mod testing;
pub mod traces;
pub mod util;
pub mod workflow;
