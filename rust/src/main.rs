//! `memsched` — memory-aware adaptive workflow scheduling CLI.
//!
//! Subcommands:
//!
//! - `generate`      synthesize a workflow (model + size) to JSON
//! - `info`          print workflow statistics
//! - `cluster-info`  print a cluster configuration (Table II presets)
//! - `schedule`      compute a static schedule and report it
//! - `simulate`      run the dynamic runtime system on a schedule
//! - `batch`         run a JSONL job batch on the parallel scheduling service
//! - `experiment`    run an evaluation suite and print a figure's table
//!
//! Run `memsched help` for the full usage text.

use anyhow::{bail, Context as _, Result};
use memsched::cli::Args;
use memsched::experiments::{self, figures, SuiteScale};
use memsched::platform::Cluster;
use memsched::scheduler::{compute_schedule, Algorithm, EvictionPolicy};
use memsched::ser::json::Value;
use memsched::service::{ClusterSpec, Job, JobSource, SchedulingService, SimJob};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::workflow;

const USAGE: &str = "\
memsched — memory-aware adaptive scheduling of scientific workflows

USAGE:
  memsched <command> [options]

COMMANDS:
  generate      --model <name> [--tasks N] [--seed S] [--input 0..4] --out wf.json
  info          --workflow <file.json|.dot>
  cluster-info  [--cluster default|memory-constrained|file.json]
  schedule      --workflow <file> [--cluster C] [--algo heft|heftm-bl|heftm-blc|heftm-mm]
                [--eviction largest|smallest] [--scorer native|xla]
                [--score-threads N] [--out schedule.json]
  simulate      --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--no-recompute]
  retrace       --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--lose-proc J]...   assess deviation impact on a schedule (§V)
  batch         --input jobs.jsonl | --suite smoke|quick|full  [--jobs N]
                [--score-threads N] [--cache-bytes B] [--repeat K] [--seed S]
                [--cluster C] [--out results.jsonl]
                run a job batch on the multi-threaded scheduling service;
                results stream incrementally as JSONL (in job order, as
                each ordered slot completes), byte-identical for any
                --jobs/--score-threads; --cache-bytes caps the schedule
                cache (LRU by approximate bytes, default unbounded)
  experiment    --figure fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|validity
                [--scale smoke|quick|full] [--seed S] [--jobs N]
                [--score-threads N] [--markdown]
  help          print this text

Models: atacseq, bacass, chipseq, eager, methylseq.

Batch job lines are JSON objects:
  {\"model\": \"chipseq\", \"tasks\": 200, \"input\": 2, \"seed\": 42}   (generated)
  {\"workflow\": \"wf.json\"}                                      (from file)
with optional \"cluster\", \"algo\", \"eviction\", and
\"sim\": {\"mode\": \"recompute\"|\"static\", \"sigma\": 0.1, \"seed\": 7}.";

fn main() {
    // Die quietly when piped into `head` etc. (default SIGPIPE behaviour).
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.clone().as_deref() {
        Some("generate") => cmd_generate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("cluster-info") => cmd_cluster_info(&mut args),
        Some("schedule") => cmd_schedule(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("retrace") => cmd_retrace(&mut args),
        Some("batch") => cmd_batch(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn load_workflow(args: &mut Args) -> Result<workflow::Workflow> {
    let path = args.req_str("workflow")?;
    workflow::io::load(std::path::Path::new(&path))
}

fn load_cluster(args: &mut Args) -> Result<Cluster> {
    Cluster::load(&args.opt_val("cluster")?.unwrap_or_else(|| "default".into()))
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let model_name = args.req_str("model")?;
    let model = memsched::generator::models::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{model_name}`"))?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let input: usize = args.opt_or("input", 2)?;
    let graph = match args.opt::<usize>("tasks")? {
        Some(n) => memsched::generator::scale_to(&model, n, seed)?,
        None => memsched::generator::expand(&model, 12)?,
    };
    let types = memsched::traces::task_types(&graph);
    let data = memsched::traces::HistoricalData::synthesize(
        &types,
        &memsched::traces::TraceConfig::default(),
        seed,
    );
    let wf = memsched::traces::bind_weights(&graph, &data, input);
    let out = args.req_str("out")?;
    args.finish()?;
    workflow::io::save(&wf, std::path::Path::new(&out))?;
    println!("wrote {} ({} tasks, {} edges)", out, wf.num_tasks(), wf.num_edges());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    args.finish()?;
    let s = wf.stats();
    println!("workflow: {}", wf.name);
    println!("  tasks:        {}", s.tasks);
    println!("  edges:        {}", s.edges);
    println!("  sources:      {}", s.sources);
    println!("  sinks:        {}", s.sinks);
    println!("  depth:        {}", s.depth);
    println!("  max in/out:   {}/{}", s.max_in_degree, s.max_out_degree);
    println!("  total work:   {:.3e}", s.total_work);
    println!("  total data:   {:.3e} bytes", s.total_data);
    println!("  max r_u:      {:.3e} bytes", s.max_memory_requirement);
    println!("  size group:   {}", workflow::SizeGroup::of(s.tasks).label());
    Ok(())
}

fn cmd_cluster_info(args: &mut Args) -> Result<()> {
    let cluster = load_cluster(args)?;
    args.finish()?;
    println!(
        "cluster: {} ({} processors, β = {:.3e} B/s)",
        cluster.name,
        cluster.len(),
        cluster.bandwidth
    );
    // Aggregate per kind (Table II).
    let mut kinds: Vec<&str> = cluster.processors.iter().map(|p| p.kind.as_str()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    println!(
        "{:<8} {:>6} {:>12} {:>14} {:>14}",
        "kind", "count", "speed", "memory(GB)", "buffer(GB)"
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kind in kinds {
        let ps: Vec<_> = cluster.processors.iter().filter(|p| p.kind == kind).collect();
        println!(
            "{:<8} {:>6} {:>12.1} {:>14.1} {:>14.1}",
            kind,
            ps.len(),
            ps[0].speed,
            ps[0].memory / GB,
            ps[0].comm_buffer / GB
        );
    }
    Ok(())
}

fn cmd_schedule(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let policy: EvictionPolicy = args.opt_or("eviction", EvictionPolicy::LargestFirst)?;
    let scorer_kind = args.opt_val("scorer")?.unwrap_or_else(|| "native".into());
    let score_threads = score_threads_arg(args)?;
    let out = args.opt_val("out")?;
    args.finish()?;

    let t0 = std::time::Instant::now();
    let schedule = match scorer_kind.as_str() {
        "native" => {
            // Parallel tentative scoring (byte-identical to serial).
            let pool = (score_threads > 1)
                .then(|| memsched::service::ScorePool::new(score_threads));
            memsched::scheduler::compute_schedule_with(&wf, &cluster, algo, policy, pool.as_ref())
        }
        "xla" => {
            if score_threads > 1 {
                eprintln!(
                    "note: --score-threads {score_threads} is ignored with --scorer xla — the \
                     batched scorer already orders all processors in one call"
                );
            }
            let scorer = memsched::runtime::scorer::XlaScorer::load_default()?;
            let order = algo.rank_order(&wf, &cluster);
            memsched::scheduler::Engine::new(&wf, &cluster, algo, policy)
                .with_scorer(&scorer)
                .run(&order)
        }
        other => bail!("unknown scorer `{other}` (native, xla)"),
    };
    let dt = t0.elapsed();

    println!("algorithm:   {}", algo.label());
    println!("valid:       {}", schedule.valid);
    println!("makespan:    {:.3}", schedule.makespan);
    println!(
        "mem usage:   {:.1}% (mean peak over used processors)",
        100.0 * schedule.mean_mem_usage()
    );
    println!("procs used:  {}/{}", schedule.procs_used(), cluster.len());
    println!("evictions:   {}", schedule.tasks.iter().map(|t| t.evicted.len()).sum::<usize>());
    println!("sched time:  {}", memsched::bench::fmt_duration(dt));
    if !schedule.valid {
        println!(
            "failures:    {} (first: {:?})",
            schedule.failures.len(),
            schedule.failures.first()
        );
    }
    if let Some(path) = out {
        let json = schedule_json(&wf, &schedule);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn schedule_json(
    wf: &workflow::Workflow,
    s: &memsched::scheduler::Schedule,
) -> memsched::ser::json::Value {
    use memsched::ser::json::{obj, Value};
    let tasks: Vec<Value> = s
        .tasks
        .iter()
        .enumerate()
        .map(|(v, t)| {
            obj(vec![
                ("task", wf.task(v).name.as_str().into()),
                ("proc", t.proc.into()),
                ("start", t.start.into()),
                ("finish", t.finish.into()),
                ("evictions", t.evicted.len().into()),
            ])
        })
        .collect();
    obj(vec![
        ("workflow", wf.name.as_str().into()),
        ("algorithm", s.algorithm.label().into()),
        ("valid", s.valid.into()),
        ("makespan", s.makespan.into()),
        ("tasks", Value::Array(tasks)),
    ])
}

fn cmd_simulate(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let no_recompute = args.flag("no-recompute");
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        println!("initial schedule invalid; execution not attempted");
        return Ok(());
    }
    let mode = if no_recompute { SimMode::FollowStatic } else { SimMode::Recompute };
    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
    let out = simulate(&wf, &cluster, &schedule, &cfg);
    println!("mode:            {mode:?}");
    println!("completed:       {}", out.completed);
    println!("makespan:        {:.3}", out.makespan);
    println!("recomputations:  {}", out.recomputations);
    println!("tasks started:   {}/{}", out.started, wf.num_tasks());
    if let Some(f) = out.failure {
        println!("failure:         {f:?}");
    }
    Ok(())
}

/// §V: compute a schedule, apply a deviation, and retrace it — reporting
/// whether the schedule survives and the updated makespan.
fn cmd_retrace(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let lost: Vec<usize> = args
        .multi("lose-proc")
        .iter()
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --lose-proc `{s}`")))
        .collect::<Result<_>>()?;
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        anyhow::bail!("initial schedule invalid; nothing to retrace");
    }
    let actual = DeviationModel::new(sigma, seed).deviate_workflow(&wf);
    let r = memsched::scheduler::retrace::retrace(
        &actual,
        &cluster,
        &schedule,
        EvictionPolicy::LargestFirst,
        &lost,
    );
    println!("deviation:       sigma={sigma} seed={seed} lost_procs={lost:?}");
    println!("still valid:     {}", r.valid);
    if r.valid {
        println!(
            "new makespan:    {:.3} ({:+.1}% vs plan)",
            r.makespan,
            100.0 * (r.makespan - schedule.makespan) / schedule.makespan
        );
    }
    if let Some(t) = r.failed_task {
        println!("first violation: task {t} (`{}`): {:?}", wf.task(t).name, r.failure);
        println!("(a dynamic run would recompute here: `memsched simulate ...`)");
    }
    Ok(())
}

/// `--jobs N` (clamped to ≥ 1), defaulting to all cores.
fn workers_arg(args: &mut Args) -> Result<usize> {
    Ok(match args.opt::<usize>("jobs")? {
        Some(n) => n.max(1),
        None => memsched::service::pool::default_workers(),
    })
}

/// `--score-threads N` (clamped to ≥ 1), defaulting to serial scoring.
fn score_threads_arg(args: &mut Args) -> Result<usize> {
    Ok(args.opt_or("score-threads", 1usize)?.max(1))
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let figure = args.req_str("figure")?;
    let scale: SuiteScale = args.opt_or("scale", SuiteScale::Quick)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let workers = workers_arg(args)?;
    let score_threads = score_threads_arg(args)?;
    let markdown = args.flag("markdown");
    args.finish()?;

    if figure == "fig9" && workers > 1 {
        eprintln!(
            "note: fig9 reports per-heuristic wall times; with --jobs {workers} they are \
             measured under pool contention — pass --jobs 1 for clean timings"
        );
    }

    // Every suite runs through the scheduling-service pool on `workers`
    // threads (serial per-spec loops lived here before).
    let table = match figure.as_str() {
        "fig1" | "fig2" | "fig3" | "fig4" => {
            let cluster = memsched::platform::presets::default_cluster();
            let results =
                experiments::run_static_suite(scale, seed, &cluster, workers, score_threads)?;
            match figure.as_str() {
                "fig1" => figures::success_rates(&results),
                "fig2" => figures::relative_makespans(&results),
                "fig3" => figures::memory_usage(&results, false),
                _ => figures::memory_usage(&results, true),
            }
        }
        "fig5" | "fig6" | "fig7" | "fig9" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results =
                experiments::run_static_suite(scale, seed, &cluster, workers, score_threads)?;
            match figure.as_str() {
                "fig5" => figures::success_rates(&results),
                "fig6" => figures::relative_makespans(&results),
                "fig7" => figures::memory_usage(&results, false),
                _ => figures::heuristic_runtimes(&results),
            }
        }
        "fig8" | "validity" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results =
                experiments::run_dynamic_suite(scale, seed, &cluster, 0.1, workers, score_threads)?;
            if figure == "fig8" {
                figures::dynamic_improvement(&results)
            } else {
                figures::dynamic_validity(&results)
            }
        }
        other => bail!("unknown figure `{other}`"),
    };
    print!("{}", if markdown { table.to_markdown() } else { table.to_csv() });
    Ok(())
}

/// Run a batch of scheduling jobs on the multi-threaded service and
/// stream the results as JSONL (stdout or `--out`). Lines are emitted
/// **incrementally**, in job order, as each ordered slot completes —
/// long batches show progress instead of buffering until the end. The
/// output bytes are identical for any `--jobs`/`--score-threads` value;
/// the run summary goes to stderr.
fn cmd_batch(args: &mut Args) -> Result<()> {
    let input = args.opt_val("input")?;
    let suite = args.opt_val("suite")?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let default_cluster = args.opt_val("cluster")?.unwrap_or_else(|| "default".into());
    let workers = workers_arg(args)?;
    let score_threads = score_threads_arg(args)?;
    let cache_bytes: Option<usize> = args.opt("cache-bytes")?;
    let repeat: usize = args.opt_or("repeat", 1)?;
    if repeat == 0 {
        bail!("--repeat must be at least 1");
    }
    let out = args.opt_val("out")?;
    args.finish()?;

    let base: Vec<Job> = match (&input, &suite) {
        (Some(path), None) => parse_jobs_file(path, &default_cluster, seed)?,
        (None, Some(scale_str)) => {
            let scale: SuiteScale = scale_str.parse()?;
            experiments::static_suite_jobs(scale, seed, &ClusterSpec::Named(default_cluster))
        }
        _ => bail!("batch requires exactly one of --input <jobs.jsonl> or --suite <smoke|quick|full>"),
    };
    if base.is_empty() {
        bail!("batch is empty");
    }
    let mut jobs = Vec::with_capacity(base.len() * repeat);
    for _ in 0..repeat {
        jobs.extend(base.iter().cloned());
    }

    let t0 = std::time::Instant::now();
    let service = SchedulingService::new(workers)
        .with_score_threads(score_threads)
        .with_cache_bytes(cache_bytes);

    // Stream each JSONL line the moment its ordered slot completes.
    // Per-line flush only for stdout (where incremental visibility is
    // the point); file output keeps BufWriter batching — the emitter
    // lock serializes this sink across pool workers, so a syscall per
    // line would throttle the whole pool.
    use std::io::Write as _;
    let flush_each_line = out.is_none();
    let mut writer: Box<dyn std::io::Write + Send> = match &out {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
        )),
        None => Box::new(std::io::stdout()),
    };
    let mut write_err: Option<std::io::Error> = None;
    let (mut emitted, mut dedup_hits, mut failed) = (0usize, 0usize, 0usize);
    service.run_batch_streaming(jobs, |r| {
        emitted += 1;
        if r.cache_hit {
            dedup_hits += 1;
        }
        if r.error.is_some() {
            failed += 1;
        }
        if write_err.is_none() {
            let res = writer
                .write_all(r.to_jsonl().as_bytes())
                .and_then(|()| writer.write_all(b"\n"))
                .and_then(|()| if flush_each_line { writer.flush() } else { Ok(()) });
            if let Err(e) = res {
                write_err = Some(e);
            }
        }
    });
    let final_flush = writer.flush();
    if let Some(e) = write_err.or(final_flush.err()) {
        return Err(anyhow::Error::new(e)
            .context(format!("writing results to {}", out.as_deref().unwrap_or("stdout"))));
    }

    let stats = service.cache_stats();
    eprintln!(
        "batch: {emitted} jobs ({dedup_hits} deduped), {} schedules computed, {} cache hits, \
         {workers} worker(s), {} score thread(s), {}",
        stats.computed,
        stats.hits(),
        service.score_threads(),
        memsched::bench::fmt_duration(t0.elapsed())
    );
    if failed > 0 {
        bail!("{failed} of {emitted} jobs failed (see the `error` lines)");
    }
    Ok(())
}

/// Parse a JSONL job file (one JSON object per line; `#` comments and
/// blank lines ignored). `default_seed` (the CLI's `--seed`) applies to
/// generated jobs whose lines omit an explicit `seed`.
fn parse_jobs_file(path: &str, default_cluster: &str, default_seed: u64) -> Result<Vec<Job>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading job file {path}"))?;
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v = Value::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", lineno + 1))?;
        jobs.push(
            parse_job(&v, default_cluster, default_seed)
                .with_context(|| format!("{path}:{} (job {})", lineno + 1, jobs.len() + 1))?,
        );
    }
    Ok(jobs)
}

fn parse_job(v: &Value, default_cluster: &str, default_seed: u64) -> Result<Job> {
    // Mirror Args::finish's strictness: a typo'd key must error, not
    // silently fall back to a default.
    const JOB_KEYS: [&str; 9] =
        ["workflow", "model", "tasks", "input", "seed", "cluster", "algo", "eviction", "sim"];
    let fields = v.as_object().ok_or_else(|| anyhow::anyhow!("job line must be a JSON object"))?;
    for (key, _) in fields {
        if !JOB_KEYS.contains(&key.as_str()) {
            bail!("unknown job field `{key}` (expected one of {})", JOB_KEYS.join(", "));
        }
    }
    let source = match (v.get("workflow"), v.get("model")) {
        (Some(wf), None) => {
            // Generator-only knobs on a file job would be silently dead;
            // reject them like any other unusable input.
            for generator_key in ["tasks", "input", "seed"] {
                if v.get(generator_key).is_some() {
                    bail!(
                        "`{generator_key}` only applies to generated jobs (`model`), not `workflow` files"
                    );
                }
            }
            let path = wf
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`workflow` must be a file path string"))?;
            JobSource::File(std::path::PathBuf::from(path))
        }
        (None, Some(model)) => {
            let family = model
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`model` must be a model name string"))?
                .to_string();
            let size = match v.get("tasks") {
                None => None,
                Some(t) => Some(
                    t.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("`tasks` must be a non-negative integer"))?,
                ),
            };
            let input = match v.get("input") {
                None => 2,
                Some(i) => i
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("`input` must be a non-negative integer"))?,
            };
            let seed = match v.get("seed") {
                None => default_seed,
                Some(s) => s.as_u64().ok_or_else(|| anyhow::anyhow!("`seed` must be an integer"))?,
            };
            JobSource::Generated(experiments::WorkloadSpec { family, size, input, seed })
        }
        _ => bail!("a job needs exactly one of `workflow` (file) or `model` (generator)"),
    };
    let cluster = ClusterSpec::Named(match v.get("cluster") {
        None => default_cluster.to_string(),
        Some(c) => c
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`cluster` must be a string"))?
            .to_string(),
    });
    let algo: Algorithm = match v.get("algo") {
        None => Algorithm::HeftmBl,
        Some(a) => a
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`algo` must be a string"))?
            .parse()?,
    };
    let policy: EvictionPolicy = match v.get("eviction") {
        None => EvictionPolicy::LargestFirst,
        Some(p) => p
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`eviction` must be a string"))?
            .parse()?,
    };
    let sim = match v.get("sim") {
        None => None,
        Some(s) => {
            const SIM_KEYS: [&str; 3] = ["mode", "sigma", "seed"];
            let fields =
                s.as_object().ok_or_else(|| anyhow::anyhow!("`sim` must be a JSON object"))?;
            for (key, _) in fields {
                if !SIM_KEYS.contains(&key.as_str()) {
                    bail!("unknown sim field `{key}` (expected one of {})", SIM_KEYS.join(", "));
                }
            }
            let mode: SimMode = s.req_str("mode")?.parse()?;
            let sigma = match s.get("sigma") {
                None => 0.1,
                Some(x) => x
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("`sim.sigma` must be a number"))?,
            };
            let seed = match s.get("seed") {
                None => default_seed,
                Some(x) => x
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("`sim.seed` must be an integer"))?,
            };
            Some(SimJob { mode, sigma, seed })
        }
    };
    Ok(Job { source, cluster, algo, policy, sim })
}
