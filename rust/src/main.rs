//! `memsched` — memory-aware adaptive workflow scheduling CLI.
//!
//! Subcommands:
//!
//! - `generate`      synthesize a workflow (model + size) to JSON
//! - `info`          print workflow statistics
//! - `cluster-info`  print a cluster configuration (Table II presets)
//! - `schedule`      compute a static schedule and report it
//! - `simulate`      run the dynamic runtime system on a schedule
//! - `batch`         run a JSONL job batch on the parallel scheduling service
//! - `experiment`    run an evaluation suite and print a figure's table
//! - `bench-check`   compare bench JSONL against a baseline (CI gate)
//!
//! Run `memsched help` for the full usage text.

use anyhow::{bail, Context as _, Result};
use memsched::cli::Args;
use memsched::experiments::{self, figures, SuiteScale};
use memsched::platform::Cluster;
use memsched::scheduler::{compute_schedule, Algorithm, EvictionPolicy};
use memsched::ser::json::Value;
use memsched::service::{
    ClusterSpec, Job, JobSource, ReplaySweep, ScoreThreadSpec, ServiceConfig, SimJob, SimResult,
};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::workflow;

const USAGE: &str = "\
memsched — memory-aware adaptive scheduling of scientific workflows

USAGE:
  memsched <command> [options]

COMMANDS:
  generate      --model <name> [--tasks N] [--seed S] [--input 0..4] --out wf.json
  info          --workflow <file.json|.dot>
  cluster-info  [--cluster default|memory-constrained|file.json]
  schedule      --workflow <file> [--cluster C] [--algo heft|heftm-bl|heftm-blc|heftm-mm]
                [--eviction largest|smallest] [--scorer native|xla]
                [--score-threads N|auto] [--out schedule.json]
  simulate      --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--no-recompute] [--json]
                --json prints the simulation outcome as one JSONL object
                (the `sim` object of a batch result line, full precision)
  retrace       --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--lose-proc J]...   assess deviation impact on a schedule (§V)
  batch         --input jobs.jsonl | --suite smoke|quick|full  [--jobs N]
                [--sigmas 0.1,0.2,...] [--score-threads N|auto] [--cache-bytes B]
                [--cache-dir DIR] [--cache-dir-bytes B] [--repeat K] [--seed S]
                [--cluster C] [--out results.jsonl]
                run a job batch on the multi-threaded scheduling service;
                results stream incrementally as JSONL (in job order, as
                each ordered slot completes), byte-identical for any
                --jobs/--score-threads and warm/cold --cache-dir;
                --sigmas turns a --suite batch into a dynamic replay
                sweep (one static schedule per workload × algorithm,
                replayed at every sigma × mode); --cache-bytes caps the
                in-memory schedule cache (LRU by approximate bytes),
                --cache-dir adds a disk-backed cache shared across
                invocations and --cache-dir-bytes bounds it (LRU by
                mtime, oldest entries evicted first); a JSONL summary
                record with the cache-hit / schedule-reuse / scaffold
                counters goes to stderr
  experiment    --figure fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|validity
                [--scale smoke|quick|full] [--seed S] [--jobs N]
                [--sigmas 0.1,0.3] [--score-threads N|auto]
                [--cache-dir DIR] [--cache-dir-bytes B] [--markdown]
                --sigmas (dynamic figures fig8/validity only) prints one
                table per sigma, scheduling each workload exactly once
  bench-check   --current BENCH_ci.json --baseline <file> [--tolerance 2.0]
                fail when a bench throughput regresses more than
                tolerance× against the baseline (used by ci.sh --bench)
  help          print this text

Models: atacseq, bacass, chipseq, eager, methylseq.

Batch job lines are JSON objects:
  {\"model\": \"chipseq\", \"tasks\": 200, \"input\": 2, \"seed\": 42}   (generated)
  {\"workflow\": \"wf.json\"}                                      (from file)
with optional \"cluster\", \"algo\", \"eviction\", and either
\"sim\": {\"mode\": \"recompute\"|\"static\", \"sigma\": 0.1, \"seed\": 7}  (one point)
or \"sweep\": [{\"mode\": ..., \"sigma\": ..., \"seed\": ...}, ...]        (replay sweep:
the workflow is scheduled once and replayed at every point).";

fn main() {
    // Die quietly when piped into `head` etc. (default SIGPIPE behaviour).
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.clone().as_deref() {
        Some("generate") => cmd_generate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("cluster-info") => cmd_cluster_info(&mut args),
        Some("schedule") => cmd_schedule(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("retrace") => cmd_retrace(&mut args),
        Some("batch") => cmd_batch(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("bench-check") => cmd_bench_check(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn load_workflow(args: &mut Args) -> Result<workflow::Workflow> {
    let path = args.req_str("workflow")?;
    workflow::io::load(std::path::Path::new(&path))
}

fn load_cluster(args: &mut Args) -> Result<Cluster> {
    Cluster::load(&args.opt_val("cluster")?.unwrap_or_else(|| "default".into()))
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let model_name = args.req_str("model")?;
    let model = memsched::generator::models::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{model_name}`"))?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let input: usize = args.opt_or("input", 2)?;
    let graph = match args.opt::<usize>("tasks")? {
        Some(n) => memsched::generator::scale_to(&model, n, seed)?,
        None => memsched::generator::expand(&model, 12)?,
    };
    let types = memsched::traces::task_types(&graph);
    let data = memsched::traces::HistoricalData::synthesize(
        &types,
        &memsched::traces::TraceConfig::default(),
        seed,
    );
    let wf = memsched::traces::bind_weights(&graph, &data, input);
    let out = args.req_str("out")?;
    args.finish()?;
    workflow::io::save(&wf, std::path::Path::new(&out))?;
    println!("wrote {} ({} tasks, {} edges)", out, wf.num_tasks(), wf.num_edges());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    args.finish()?;
    let s = wf.stats();
    println!("workflow: {}", wf.name);
    println!("  tasks:        {}", s.tasks);
    println!("  edges:        {}", s.edges);
    println!("  sources:      {}", s.sources);
    println!("  sinks:        {}", s.sinks);
    println!("  depth:        {}", s.depth);
    println!("  max in/out:   {}/{}", s.max_in_degree, s.max_out_degree);
    println!("  total work:   {:.3e}", s.total_work);
    println!("  total data:   {:.3e} bytes", s.total_data);
    println!("  max r_u:      {:.3e} bytes", s.max_memory_requirement);
    println!("  size group:   {}", workflow::SizeGroup::of(s.tasks).label());
    Ok(())
}

fn cmd_cluster_info(args: &mut Args) -> Result<()> {
    let cluster = load_cluster(args)?;
    args.finish()?;
    println!(
        "cluster: {} ({} processors, β = {:.3e} B/s)",
        cluster.name,
        cluster.len(),
        cluster.bandwidth
    );
    // Aggregate per kind (Table II).
    let mut kinds: Vec<&str> = cluster.processors.iter().map(|p| p.kind.as_str()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    println!(
        "{:<8} {:>6} {:>12} {:>14} {:>14}",
        "kind", "count", "speed", "memory(GB)", "buffer(GB)"
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kind in kinds {
        let ps: Vec<_> = cluster.processors.iter().filter(|p| p.kind == kind).collect();
        println!(
            "{:<8} {:>6} {:>12.1} {:>14.1} {:>14.1}",
            kind,
            ps.len(),
            ps[0].speed,
            ps[0].memory / GB,
            ps[0].comm_buffer / GB
        );
    }
    Ok(())
}

fn cmd_schedule(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let policy: EvictionPolicy = args.opt_or("eviction", EvictionPolicy::LargestFirst)?;
    let scorer_kind = args.opt_val("scorer")?.unwrap_or_else(|| "native".into());
    let score_threads = score_threads_arg(args)?;
    let out = args.opt_val("out")?;
    args.finish()?;

    let t0 = std::time::Instant::now();
    // Resolve `auto` against this (workflow, cluster) instance.
    let score_spec = score_threads;
    let score_threads = match score_spec {
        ScoreThreadSpec::Fixed(n) => n,
        ScoreThreadSpec::Auto => memsched::scheduler::auto_score_threads(&wf, &cluster),
    };
    let schedule = match scorer_kind.as_str() {
        "native" => {
            // Parallel tentative scoring (byte-identical to serial).
            let pool = (score_threads > 1)
                .then(|| memsched::service::ScorePool::new(score_threads));
            memsched::scheduler::compute_schedule_with(&wf, &cluster, algo, policy, pool.as_ref())
        }
        "xla" => {
            // Only nag about an *explicit* thread request; the `auto`
            // default resolving to many threads is not the user's doing.
            if let ScoreThreadSpec::Fixed(n) = score_spec {
                if n > 1 {
                    eprintln!(
                        "note: --score-threads {n} is ignored with --scorer xla — the \
                         batched scorer already orders all processors in one call"
                    );
                }
            }
            let scorer = memsched::runtime::scorer::XlaScorer::load_default()?;
            let order = algo.rank_order(&wf, &cluster);
            memsched::scheduler::Engine::new(&wf, &cluster, algo, policy)
                .with_scorer(&scorer)
                .run(&order)
        }
        other => bail!("unknown scorer `{other}` (native, xla)"),
    };
    let dt = t0.elapsed();

    println!("algorithm:   {}", algo.label());
    println!("valid:       {}", schedule.valid);
    println!("makespan:    {:.3}", schedule.makespan);
    println!(
        "mem usage:   {:.1}% (mean peak over used processors)",
        100.0 * schedule.mean_mem_usage()
    );
    println!("procs used:  {}/{}", schedule.procs_used(), cluster.len());
    println!("evictions:   {}", schedule.tasks.iter().map(|t| t.evicted.len()).sum::<usize>());
    println!("sched time:  {}", memsched::bench::fmt_duration(dt));
    if !schedule.valid {
        println!(
            "failures:    {} (first: {:?})",
            schedule.failures.len(),
            schedule.failures.first()
        );
    }
    if let Some(path) = out {
        let json = schedule_json(&wf, &schedule);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn schedule_json(
    wf: &workflow::Workflow,
    s: &memsched::scheduler::Schedule,
) -> memsched::ser::json::Value {
    use memsched::ser::json::{obj, Value};
    let tasks: Vec<Value> = s
        .tasks
        .iter()
        .enumerate()
        .map(|(v, t)| {
            obj(vec![
                ("task", wf.task(v).name.as_str().into()),
                ("proc", t.proc.into()),
                ("start", t.start.into()),
                ("finish", t.finish.into()),
                ("evictions", t.evicted.len().into()),
            ])
        })
        .collect();
    obj(vec![
        ("workflow", wf.name.as_str().into()),
        ("algorithm", s.algorithm.label().into()),
        ("valid", s.valid.into()),
        ("makespan", s.makespan.into()),
        ("tasks", Value::Array(tasks)),
    ])
}

fn cmd_simulate(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let no_recompute = args.flag("no-recompute");
    let json = args.flag("json");
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    if !json {
        println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    }
    if !schedule.valid {
        if json {
            // Machine-readable error object on stdout *and* a non-zero
            // exit, so scripted consumers can't mistake it for a sim
            // object.
            use memsched::ser::json::obj;
            println!("{}", obj(vec![("error", "initial schedule invalid".into())]).to_string_compact());
            bail!("initial schedule invalid; execution not attempted");
        }
        println!("initial schedule invalid; execution not attempted");
        return Ok(());
    }
    let mode = if no_recompute { SimMode::FollowStatic } else { SimMode::Recompute };
    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
    // Through the scaffold-backed shim — the same replay core the
    // service's sweep path drives (scaffold build + one run).
    let out = simulate(&wf, &cluster, &schedule, &cfg);
    if json {
        // Exactly the `sim` object of a batch JSONL line — one shared
        // mapping + serializer (`SimResult`), so `ci.sh --smoke` can
        // byte-compare these against the replay engine's sweep output.
        println!("{}", SimResult::from_outcome(mode, &out).to_json().to_string_compact());
        return Ok(());
    }
    println!("mode:            {mode:?}");
    println!("completed:       {}", out.completed);
    println!("makespan:        {:.3}", out.makespan);
    println!("recomputations:  {}", out.recomputations);
    println!("tasks started:   {}/{}", out.started, wf.num_tasks());
    if let Some(f) = out.failure {
        println!("failure:         {f:?}");
    }
    Ok(())
}

/// §V: compute a schedule, apply a deviation, and retrace it — reporting
/// whether the schedule survives and the updated makespan.
fn cmd_retrace(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let lost: Vec<usize> = args
        .multi("lose-proc")
        .iter()
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --lose-proc `{s}`")))
        .collect::<Result<_>>()?;
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        anyhow::bail!("initial schedule invalid; nothing to retrace");
    }
    let actual = DeviationModel::new(sigma, seed).deviate_workflow(&wf);
    let r = memsched::scheduler::retrace::retrace(
        &actual,
        &cluster,
        &schedule,
        EvictionPolicy::LargestFirst,
        &lost,
    );
    println!("deviation:       sigma={sigma} seed={seed} lost_procs={lost:?}");
    println!("still valid:     {}", r.valid);
    if r.valid {
        println!(
            "new makespan:    {:.3} ({:+.1}% vs plan)",
            r.makespan,
            100.0 * (r.makespan - schedule.makespan) / schedule.makespan
        );
    }
    if let Some(t) = r.failed_task {
        println!("first violation: task {t} (`{}`): {:?}", wf.task(t).name, r.failure);
        println!("(a dynamic run would recompute here: `memsched simulate ...`)");
    }
    Ok(())
}

/// `--jobs N` (clamped to ≥ 1), defaulting to all cores.
fn workers_arg(args: &mut Args) -> Result<usize> {
    Ok(match args.opt::<usize>("jobs")? {
        Some(n) => n.max(1),
        None => memsched::service::pool::default_workers(),
    })
}

/// `--score-threads N|auto`, defaulting to `auto`: serial below the
/// measured `cluster × fan-in` crossover, all cores above it —
/// schedules are byte-identical either way.
fn score_threads_arg(args: &mut Args) -> Result<ScoreThreadSpec> {
    args.opt_or("score-threads", ScoreThreadSpec::Auto)
}

/// The service configuration shared by `batch` and `experiment`:
/// `--jobs`, `--score-threads`, `--cache-bytes`, `--cache-dir`,
/// `--cache-dir-bytes`.
fn service_config_args(args: &mut Args) -> Result<ServiceConfig> {
    Ok(ServiceConfig {
        workers: workers_arg(args)?,
        score: score_threads_arg(args)?,
        cache_bytes: args.opt("cache-bytes")?,
        cache_dir: args.opt_val("cache-dir")?.map(std::path::PathBuf::from),
        cache_dir_bytes: args.opt("cache-dir-bytes")?,
    })
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let figure = args.req_str("figure")?;
    let scale: SuiteScale = args.opt_or("scale", SuiteScale::Quick)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let cfg = service_config_args(args)?;
    let sigmas: Vec<f64> = args.list_of("sigmas")?;
    let markdown = args.flag("markdown");
    args.finish()?;

    let dynamic_figure = matches!(figure.as_str(), "fig8" | "validity");
    if !sigmas.is_empty() && !dynamic_figure {
        bail!("--sigmas only applies to the dynamic figures (fig8, validity)");
    }
    if figure == "fig9" && cfg.workers > 1 {
        eprintln!(
            "note: fig9 reports per-heuristic wall times; with --jobs {} they are \
             measured under pool contention — pass --jobs 1 for clean timings",
            cfg.workers
        );
    }

    // Every suite runs through the scheduling-service pool (serial
    // per-spec loops lived here before).
    let render = |t: &memsched::ser::csv::CsvWriter| -> String {
        if markdown {
            t.to_markdown()
        } else {
            t.to_csv()
        }
    };
    let out = match figure.as_str() {
        "fig1" | "fig2" | "fig3" | "fig4" => {
            let cluster = memsched::platform::presets::default_cluster();
            let results = experiments::run_static_suite(scale, seed, &cluster, &cfg)?;
            let table = match figure.as_str() {
                "fig1" => figures::success_rates(&results),
                "fig2" => figures::relative_makespans(&results),
                "fig3" => figures::memory_usage(&results, false),
                _ => figures::memory_usage(&results, true),
            };
            render(&table)
        }
        "fig5" | "fig6" | "fig7" | "fig9" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results = experiments::run_static_suite(scale, seed, &cluster, &cfg)?;
            let table = match figure.as_str() {
                "fig5" => figures::success_rates(&results),
                "fig6" => figures::relative_makespans(&results),
                "fig7" => figures::memory_usage(&results, false),
                _ => figures::heuristic_runtimes(&results),
            };
            render(&table)
        }
        "fig8" | "validity" => {
            // Headers only when --sigmas was passed: the legacy
            // single-sigma default keeps its pure-CSV stdout format.
            let sigma_headers = !sigmas.is_empty();
            let sigmas = if sigmas.is_empty() { vec![0.1] } else { sigmas };
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            // One replay-engine pass: each static schedule is computed
            // once and replayed at every sigma level.
            let per_sigma = experiments::run_dynamic_suite(scale, seed, &cluster, &sigmas, &cfg)?;
            // One self-contained `# sigma=…`-headed table per sigma, so
            // a multi-sigma run's output is byte-identical to the
            // per-sigma (`--sigmas <s>`) runs concatenated.
            let mut out = String::new();
            for (sigma, results) in sigmas.iter().zip(&per_sigma) {
                let table = if figure == "fig8" {
                    figures::dynamic_improvement(results)
                } else {
                    figures::dynamic_validity(results)
                };
                if sigma_headers {
                    out.push_str(&format!("# sigma={sigma}\n"));
                }
                out.push_str(&render(&table));
            }
            out
        }
        other => bail!("unknown figure `{other}`"),
    };
    print!("{out}");
    Ok(())
}

/// A batch submission: plain per-point jobs or replay sweeps. The two
/// emit byte-identical JSONL for equal flattened content; sweeps
/// additionally guarantee the schedule-once-replay-many execution shape.
enum Batch {
    Jobs(Vec<Job>),
    Sweeps(Vec<ReplaySweep>),
}

impl Batch {
    fn len(&self) -> usize {
        match self {
            Batch::Jobs(jobs) => jobs.len(),
            Batch::Sweeps(sweeps) => sweeps.iter().map(ReplaySweep::num_results).sum(),
        }
    }

    fn repeated(self, repeat: usize) -> Batch {
        match self {
            Batch::Jobs(base) => {
                let mut jobs = Vec::with_capacity(base.len() * repeat);
                for _ in 0..repeat {
                    jobs.extend(base.iter().cloned());
                }
                Batch::Jobs(jobs)
            }
            Batch::Sweeps(base) => {
                let mut sweeps = Vec::with_capacity(base.len() * repeat);
                for _ in 0..repeat {
                    sweeps.extend(base.iter().cloned());
                }
                Batch::Sweeps(sweeps)
            }
        }
    }
}

/// Run a batch of scheduling jobs (or replay sweeps) on the
/// multi-threaded service and stream the results as JSONL (stdout or
/// `--out`). Lines are emitted **incrementally**, in job order, as each
/// ordered slot completes — long batches show progress instead of
/// buffering until the end. The output bytes are identical for any
/// `--jobs`/`--score-threads` value and for warm/cold `--cache-dir`;
/// the run summary (human line + JSONL record) goes to stderr.
fn cmd_batch(args: &mut Args) -> Result<()> {
    let input = args.opt_val("input")?;
    let suite = args.opt_val("suite")?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let default_cluster = args.opt_val("cluster")?.unwrap_or_else(|| "default".into());
    let cfg = service_config_args(args)?;
    let sigmas: Vec<f64> = args.list_of("sigmas")?;
    let repeat: usize = args.opt_or("repeat", 1)?;
    if repeat == 0 {
        bail!("--repeat must be at least 1");
    }
    let out = args.opt_val("out")?;
    args.finish()?;

    let base: Batch = match (&input, &suite) {
        (Some(path), None) => {
            if !sigmas.is_empty() {
                bail!("--sigmas only applies to --suite batches; put a `sweep` array on the job lines instead");
            }
            parse_jobs_file(path, &default_cluster, seed)?
        }
        (None, Some(scale_str)) => {
            let scale: SuiteScale = scale_str.parse()?;
            let cluster = ClusterSpec::Named(default_cluster);
            if sigmas.is_empty() {
                Batch::Jobs(experiments::static_suite_jobs(scale, seed, &cluster))
            } else {
                // Dynamic replay sweeps: one static schedule per
                // (workload, algorithm), replayed at every sigma × mode.
                let specs = experiments::dynamic_suite_specs(scale, seed);
                Batch::Sweeps(experiments::dynamic_suite_sweeps(&specs, &cluster, &sigmas))
            }
        }
        _ => bail!("batch requires exactly one of --input <jobs.jsonl> or --suite <smoke|quick|full>"),
    };
    if base.len() == 0 {
        bail!("batch is empty");
    }
    let batch = base.repeated(repeat);

    let t0 = std::time::Instant::now();
    let service = cfg.build()?;

    // Stream each JSONL line the moment its ordered slot completes.
    // Per-line flush only for stdout (where incremental visibility is
    // the point); file output keeps BufWriter batching — the emitter
    // lock serializes this sink across pool workers, so a syscall per
    // line would throttle the whole pool.
    use std::io::Write as _;
    let flush_each_line = out.is_none();
    let mut writer: Box<dyn std::io::Write + Send> = match &out {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
        )),
        None => Box::new(std::io::stdout()),
    };
    let mut write_err: Option<std::io::Error> = None;
    let (mut emitted, mut dedup_hits, mut failed) = (0usize, 0usize, 0usize);
    {
        let sink = |r: memsched::service::JobResult| {
            emitted += 1;
            if r.cache_hit {
                dedup_hits += 1;
            }
            if r.error.is_some() {
                failed += 1;
            }
            if write_err.is_none() {
                let res = writer
                    .write_all(r.to_jsonl().as_bytes())
                    .and_then(|()| writer.write_all(b"\n"))
                    .and_then(|()| if flush_each_line { writer.flush() } else { Ok(()) });
                if let Err(e) = res {
                    write_err = Some(e);
                }
            }
        };
        match batch {
            Batch::Jobs(jobs) => service.run_batch_streaming(jobs, sink),
            Batch::Sweeps(sweeps) => service.run_replay_sweeps_streaming(sweeps, sink),
        }
    }
    let final_flush = writer.flush();
    if let Some(e) = write_err.or(final_flush.err()) {
        return Err(anyhow::Error::from(e)
            .context(format!("writing results to {}", out.as_deref().unwrap_or("stdout"))));
    }

    let stats = service.cache_stats();
    eprintln!(
        "batch: {emitted} jobs ({dedup_hits} deduped), {} schedules computed, {} cache hits \
         ({} from disk), {} worker(s), {} score thread(s), {}",
        stats.computed,
        stats.hits(),
        stats.disk_hits,
        service.workers(),
        service.score_threads(),
        memsched::bench::fmt_duration(t0.elapsed())
    );
    // Machine-readable summary record (stderr: the JSONL result stream
    // on stdout/--out must stay byte-identical across warm/cold caches).
    eprintln!("{}", service.summary_json(emitted, dedup_hits, failed).to_string_compact());
    if failed > 0 {
        bail!("{failed} of {emitted} jobs failed (see the `error` lines)");
    }
    Ok(())
}

/// Parse a JSONL job file (one JSON object per line; `#` comments and
/// blank lines ignored). `default_seed` (the CLI's `--seed`) applies to
/// generated jobs whose lines omit an explicit `seed`. If any line
/// carries a `sweep` array the whole batch runs through the replay
/// engine (plain lines become one-point sweeps); the output bytes are
/// identical either way.
fn parse_jobs_file(path: &str, default_cluster: &str, default_seed: u64) -> Result<Batch> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading job file {path}"))?;
    let mut parsed: Vec<(Job, Option<Vec<SimJob>>)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v = Value::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", lineno + 1))?;
        parsed.push(
            parse_job(&v, default_cluster, default_seed)
                .with_context(|| format!("{path}:{} (job {})", lineno + 1, parsed.len() + 1))?,
        );
    }
    if parsed.iter().any(|(_, sweep)| sweep.is_some()) {
        Ok(Batch::Sweeps(
            parsed
                .into_iter()
                .map(|(job, sweep)| match sweep {
                    Some(points) => ReplaySweep::from_job(job).with_points(points),
                    None => ReplaySweep::from_job(job),
                })
                .collect(),
        ))
    } else {
        Ok(Batch::Jobs(parsed.into_iter().map(|(job, _)| job).collect()))
    }
}

/// One parsed job line: the job itself plus, when the line carried a
/// `sweep` array, its replay points.
fn parse_job(v: &Value, default_cluster: &str, default_seed: u64) -> Result<(Job, Option<Vec<SimJob>>)> {
    // Mirror Args::finish's strictness: a typo'd key must error, not
    // silently fall back to a default.
    const JOB_KEYS: [&str; 10] =
        ["workflow", "model", "tasks", "input", "seed", "cluster", "algo", "eviction", "sim", "sweep"];
    let fields = v.as_object().ok_or_else(|| anyhow::anyhow!("job line must be a JSON object"))?;
    for (key, _) in fields {
        if !JOB_KEYS.contains(&key.as_str()) {
            bail!("unknown job field `{key}` (expected one of {})", JOB_KEYS.join(", "));
        }
    }
    let source = match (v.get("workflow"), v.get("model")) {
        (Some(wf), None) => {
            // Generator-only knobs on a file job would be silently dead;
            // reject them like any other unusable input.
            for generator_key in ["tasks", "input", "seed"] {
                if v.get(generator_key).is_some() {
                    bail!(
                        "`{generator_key}` only applies to generated jobs (`model`), not `workflow` files"
                    );
                }
            }
            let path = wf
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`workflow` must be a file path string"))?;
            JobSource::File(std::path::PathBuf::from(path))
        }
        (None, Some(model)) => {
            let family = model
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`model` must be a model name string"))?
                .to_string();
            let size = match v.get("tasks") {
                None => None,
                Some(t) => Some(
                    t.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("`tasks` must be a non-negative integer"))?,
                ),
            };
            let input = match v.get("input") {
                None => 2,
                Some(i) => i
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("`input` must be a non-negative integer"))?,
            };
            let seed = match v.get("seed") {
                None => default_seed,
                Some(s) => s.as_u64().ok_or_else(|| anyhow::anyhow!("`seed` must be an integer"))?,
            };
            JobSource::Generated(experiments::WorkloadSpec { family, size, input, seed })
        }
        _ => bail!("a job needs exactly one of `workflow` (file) or `model` (generator)"),
    };
    let cluster = ClusterSpec::Named(match v.get("cluster") {
        None => default_cluster.to_string(),
        Some(c) => c
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`cluster` must be a string"))?
            .to_string(),
    });
    let algo: Algorithm = match v.get("algo") {
        None => Algorithm::HeftmBl,
        Some(a) => a
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`algo` must be a string"))?
            .parse()?,
    };
    let policy: EvictionPolicy = match v.get("eviction") {
        None => EvictionPolicy::LargestFirst,
        Some(p) => p
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`eviction` must be a string"))?
            .parse()?,
    };
    let sim = match v.get("sim") {
        None => None,
        Some(s) => Some(parse_sim_point(s, default_seed)?),
    };
    let sweep = match v.get("sweep") {
        None => None,
        Some(s) => {
            if sim.is_some() {
                bail!("a job takes `sim` (one point) or `sweep` (many points), not both");
            }
            let points = s
                .as_array()
                .ok_or_else(|| anyhow::anyhow!("`sweep` must be an array of sim points"))?;
            Some(
                points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        parse_sim_point(p, default_seed)
                            .with_context(|| format!("sweep point {}", i + 1))
                    })
                    .collect::<Result<Vec<SimJob>>>()?,
            )
        }
    };
    Ok((Job { source, cluster, algo, policy, sim }, sweep))
}

/// Compare a bench JSONL file (entries `{"id": ..., "throughput": ...,
/// "seconds": ...}`, as emitted by the benches under
/// `MEMSCHED_BENCH_JSON`) against a baseline file: fail when any shared
/// id's throughput regressed more than `--tolerance`× (default 2×, wide
/// enough to absorb machine noise but not an accidental serial path).
/// Ids present on only one side are reported and skipped — baselines
/// from differently-sized machines simply compare fewer entries.
fn cmd_bench_check(args: &mut Args) -> Result<()> {
    let current_path = args.req_str("current")?;
    let baseline_path = args.req_str("baseline")?;
    let tolerance: f64 = args.opt_or("tolerance", 2.0)?;
    args.finish()?;
    if tolerance.is_nan() || tolerance < 1.0 {
        bail!("--tolerance must be >= 1.0");
    }

    let load = |path: &str| -> Result<std::collections::BTreeMap<String, f64>> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading bench file {path}"))?;
        let mut entries = std::collections::BTreeMap::new();
        for v in memsched::ser::json::parse_jsonl(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        {
            let id = v.req_str("id").with_context(|| format!("bench entry in {path}"))?;
            let throughput =
                v.req_f64("throughput").with_context(|| format!("bench entry `{id}` in {path}"))?;
            if throughput.is_nan() || throughput <= 0.0 {
                bail!("bench entry `{id}` in {path} has non-positive throughput {throughput}");
            }
            entries.insert(id.to_string(), throughput);
        }
        Ok(entries)
    };
    let current = load(&current_path)?;
    let baseline = load(&baseline_path)?;

    let (mut compared, mut regressions) = (0usize, 0usize);
    for (id, base) in &baseline {
        match current.get(id) {
            None => println!("{id}: not in current run (skipped)"),
            Some(cur) => {
                compared += 1;
                let slowdown = base / cur;
                let verdict = if slowdown > tolerance {
                    regressions += 1;
                    "REGRESSED"
                } else {
                    "ok"
                };
                println!(
                    "{id}: baseline {base:.2}/s, current {cur:.2}/s ({slowdown:.2}x slowdown) {verdict}"
                );
            }
        }
    }
    for id in current.keys().filter(|id| !baseline.contains_key(*id)) {
        println!("{id}: new metric (no baseline)");
    }
    if compared == 0 {
        eprintln!("warning: no comparable bench entries between {current_path} and {baseline_path}");
    }
    if regressions > 0 {
        bail!("{regressions} bench metric(s) regressed more than {tolerance}x against {baseline_path}");
    }
    Ok(())
}

/// One simulation point (`sim` object or a `sweep` array element).
fn parse_sim_point(s: &Value, default_seed: u64) -> Result<SimJob> {
    const SIM_KEYS: [&str; 3] = ["mode", "sigma", "seed"];
    let fields = s.as_object().ok_or_else(|| anyhow::anyhow!("sim point must be a JSON object"))?;
    for (key, _) in fields {
        if !SIM_KEYS.contains(&key.as_str()) {
            bail!("unknown sim field `{key}` (expected one of {})", SIM_KEYS.join(", "));
        }
    }
    let mode: SimMode = s.req_str("mode")?.parse()?;
    let sigma = match s.get("sigma") {
        None => 0.1,
        Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("`sim.sigma` must be a number"))?,
    };
    let seed = match s.get("seed") {
        None => default_seed,
        Some(x) => x.as_u64().ok_or_else(|| anyhow::anyhow!("`sim.seed` must be an integer"))?,
    };
    Ok(SimJob { mode, sigma, seed })
}
