//! `memsched` — memory-aware adaptive workflow scheduling CLI.
//!
//! Subcommands:
//!
//! - `generate`      synthesize a workflow (model + size) to JSON
//! - `info`          print workflow statistics
//! - `cluster-info`  print a cluster configuration (Table II presets)
//! - `schedule`      compute a static schedule and report it
//! - `simulate`      run the dynamic runtime system on a schedule
//! - `trace`         render one simulated execution as Chrome trace-event JSON
//! - `batch`         run a JSONL job batch on the parallel scheduling service
//! - `serve`         run a persistent scheduler daemon on a Unix socket / stdio
//! - `client`        submit a job file to a running `serve` daemon
//! - `experiment`    run an evaluation suite and print a figure's table
//! - `bench-check`   compare bench JSONL against a baseline (CI gate)
//!
//! Run `memsched help` for the full usage text.

use anyhow::{bail, Context as _, Result};
use memsched::cli::Args;
use memsched::experiments::{self, figures, SuiteScale};
use memsched::platform::Cluster;
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::ser::json::Value;
use memsched::service::{
    ClusterSpec, Job, JobSpec, ParseDefaults, ReplaySweep, ScoreThreadSpec, ServeOptions,
    ServiceConfig, SimResult,
};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::workflow;

const USAGE: &str = "\
memsched — memory-aware adaptive scheduling of scientific workflows

USAGE:
  memsched <command> [options]

COMMANDS:
  generate      --model <name> [--tasks N] [--seed S] [--input 0..4] --out wf.json
  info          --workflow <file.json|.dot>
  cluster-info  [--cluster default|memory-constrained|file.json]
  schedule      --workflow <file> [--cluster C]
                [--algo heft|heftm-bl|heftm-blc|heftm-mm|peft|lookahead|dls|portfolio]
                [--eviction largest|smallest] [--scorer native|xla]
                [--score-threads N|auto] [--out schedule.json]
                `portfolio` runs every algorithm and commits the best
                candidate; every result row reports the workload's
                makespan lower bound and the schedule's optimality gap
  simulate      --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--no-recompute] [--json]
                --json prints the simulation outcome as one JSONL object
                (the `sim` object of a batch result line, full precision)
  retrace       --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--lose-proc J]...   assess deviation impact on a schedule (§V)
  trace         --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--no-recompute] [--check] [--out trace.json]
                simulate once with event tracing on and render the
                execution as Chrome trace-event JSON (load in Perfetto /
                chrome://tracing): one process track per processor with
                a slice per executed task, a per-processor
                memory-waterline counter track, and recomputation
                instants; --check re-parses the rendered output and
                fails unless it is well-formed (>=1 task slice per
                track, monotone timestamps)
  batch         --input jobs.jsonl | --suite smoke|quick|full  [--jobs N]
                [--sigmas 0.1,0.2,...] [--score-threads N|auto] [--score-pools P]
                [--cache-bytes B] [--cache-dir DIR] [--cache-dir-bytes B]
                [--repeat K] [--seed S] [--no-portfolio-prune]
                [--cluster C] [--out results.jsonl] [--metrics-json PATH]
                run a job batch on the multi-threaded scheduling service;
                results stream incrementally as JSONL (in job order, as
                each ordered slot completes), byte-identical for any
                --jobs/--score-threads and warm/cold --cache-dir;
                --sigmas turns a --suite batch into a dynamic replay
                sweep (one static schedule per workload × algorithm,
                replayed at every sigma × mode); --cache-bytes caps the
                in-memory schedule cache (LRU by approximate bytes),
                --cache-dir adds a disk-backed cache shared across
                invocations and --cache-dir-bytes bounds it (LRU by
                mtime, oldest entries evicted first); a versioned JSONL
                summary record with the cache-hit / schedule-reuse /
                scaffold counters goes to stderr; --metrics-json enables
                event tracing (result bytes unchanged) and writes the
                aggregated counters + span histograms as JSONL to PATH
  serve         --socket <path> | --stdio  [--jobs N] [--score-threads N|auto]
                [--score-pools P] [--cache-bytes B] [--cache-dir DIR]
                [--cache-dir-bytes B]
                [--cluster C] [--seed S] [--max-frame-bytes B]
                [--max-queued-per-client N] [--metrics-json PATH]
                run a persistent scheduler daemon: clients submit
                length-delimited job frames (the exact `batch --input`
                line grammar; see DESIGN.md) over a Unix socket and
                result frames stream back byte-identical to `memsched
                batch` on the same lines; admission drains client queues
                round-robin (fair share), each queue is capped
                (--max-queued-per-client; overflow is rejected with a
                structured error frame, never buffered unboundedly), and
                the in-memory/disk schedule caches are shared live
                across clients; SIGTERM/SIGINT or a {\"ctl\":\"shutdown\"}
                frame drains in-flight work, prints a per-client summary
                record to stderr, and exits 0; a {\"ctl\":\"stats\"} frame
                answers with live global counters + per-client summaries
  client        --socket <path> [--input jobs.jsonl] [--stats] [--shutdown]
                submit a JSONL job file (default: stdin) to a running
                `memsched serve` daemon: result lines go to stdout
                (byte-identical to `memsched batch --input` on the same
                file), error frames to stderr; --stats then asks for the
                daemon's live {\"ctl\":\"stats\"} metrics and prints the
                reply (with --stats and no --input, stdin is not read —
                a stats-only probe); --shutdown asks the daemon to drain
                and exit after this client's work
  experiment    --figure fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|validity
                [--scale smoke|quick|full] [--seed S] [--jobs N]
                [--sigmas 0.1,0.3] [--score-threads N|auto] [--score-pools P]
                [--cache-dir DIR] [--cache-dir-bytes B] [--markdown]
                [--metrics-json PATH]
                --sigmas (dynamic figures fig8/validity only) prints one
                table per sigma, scheduling each workload exactly once
  bench-check   --current BENCH_ci.json --baseline <file> [--tolerance 2.0]
                fail when a bench throughput regresses more than
                tolerance× against the baseline (used by ci.sh --bench)
  help          print this text

Models: atacseq, bacass, chipseq, eager, methylseq.

Batch job lines are JSON objects:
  {\"model\": \"chipseq\", \"tasks\": 200, \"input\": 2, \"seed\": 42}   (generated)
  {\"workflow\": \"wf.json\"}                                      (from file)
with optional \"cluster\", \"algo\", \"eviction\", and either
\"sim\": {\"mode\": \"recompute\"|\"static\", \"sigma\": 0.1, \"seed\": 7}  (one point)
or \"sweep\": [{\"mode\": ..., \"sigma\": ..., \"seed\": ...}, ...]        (replay sweep:
the workflow is scheduled once and replayed at every point).";

fn main() {
    // Die quietly when piped into `head` etc. (default SIGPIPE behaviour).
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.clone().as_deref() {
        Some("generate") => cmd_generate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("cluster-info") => cmd_cluster_info(&mut args),
        Some("schedule") => cmd_schedule(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("retrace") => cmd_retrace(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("batch") => cmd_batch(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("client") => cmd_client(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("bench-check") => cmd_bench_check(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn load_workflow(args: &mut Args) -> Result<workflow::Workflow> {
    let path = args.req_str("workflow")?;
    workflow::io::load(std::path::Path::new(&path))
}

fn load_cluster(args: &mut Args) -> Result<Cluster> {
    Cluster::load(&args.opt_val("cluster")?.unwrap_or_else(|| "default".into()))
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let model_name = args.req_str("model")?;
    let model = memsched::generator::models::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{model_name}`"))?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let input: usize = args.opt_or("input", 2)?;
    let graph = match args.opt::<usize>("tasks")? {
        Some(n) => memsched::generator::scale_to(&model, n, seed)?,
        None => memsched::generator::expand(&model, 12)?,
    };
    let types = memsched::traces::task_types(&graph);
    let data = memsched::traces::HistoricalData::synthesize(
        &types,
        &memsched::traces::TraceConfig::default(),
        seed,
    );
    let wf = memsched::traces::bind_weights(&graph, &data, input);
    let out = args.req_str("out")?;
    args.finish()?;
    workflow::io::save(&wf, std::path::Path::new(&out))?;
    println!("wrote {} ({} tasks, {} edges)", out, wf.num_tasks(), wf.num_edges());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    args.finish()?;
    let s = wf.stats();
    println!("workflow: {}", wf.name);
    println!("  tasks:        {}", s.tasks);
    println!("  edges:        {}", s.edges);
    println!("  sources:      {}", s.sources);
    println!("  sinks:        {}", s.sinks);
    println!("  depth:        {}", s.depth);
    println!("  max in/out:   {}/{}", s.max_in_degree, s.max_out_degree);
    println!("  total work:   {:.3e}", s.total_work);
    println!("  total data:   {:.3e} bytes", s.total_data);
    println!("  max r_u:      {:.3e} bytes", s.max_memory_requirement);
    println!("  size group:   {}", workflow::SizeGroup::of(s.tasks).label());
    Ok(())
}

fn cmd_cluster_info(args: &mut Args) -> Result<()> {
    let cluster = load_cluster(args)?;
    args.finish()?;
    println!(
        "cluster: {} ({} processors, β = {:.3e} B/s)",
        cluster.name,
        cluster.len(),
        cluster.bandwidth
    );
    // Aggregate per kind (Table II).
    let mut kinds: Vec<&str> = cluster.processors.iter().map(|p| p.kind.as_str()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    println!(
        "{:<8} {:>6} {:>12} {:>14} {:>14}",
        "kind", "count", "speed", "memory(GB)", "buffer(GB)"
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kind in kinds {
        let ps: Vec<_> = cluster.processors.iter().filter(|p| p.kind == kind).collect();
        println!(
            "{:<8} {:>6} {:>12.1} {:>14.1} {:>14.1}",
            kind,
            ps.len(),
            ps[0].speed,
            ps[0].memory / GB,
            ps[0].comm_buffer / GB
        );
    }
    Ok(())
}

fn cmd_schedule(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let policy: EvictionPolicy = args.opt_or("eviction", EvictionPolicy::LargestFirst)?;
    let scorer_kind = args.opt_val("scorer")?.unwrap_or_else(|| "native".into());
    let score_threads = score_threads_arg(args)?;
    let out = args.opt_val("out")?;
    args.finish()?;

    let t0 = std::time::Instant::now();
    // Resolve `auto` against this (workflow, cluster) instance.
    let score_spec = score_threads;
    let score_threads = match score_spec {
        ScoreThreadSpec::Fixed(n) => n,
        ScoreThreadSpec::Auto => memsched::scheduler::auto_score_threads(&wf, &cluster),
    };
    let schedule = match scorer_kind.as_str() {
        "native" => {
            // Parallel tentative scoring (byte-identical to serial).
            let pool = (score_threads > 1)
                .then(|| memsched::service::ScorePool::new(score_threads));
            ScheduleRequest::new(&wf, &cluster)
                .algo(algo)
                .policy(policy)
                .score_pool(pool.as_ref())
                .run()
        }
        "xla" => {
            // Only nag about an *explicit* thread request; the `auto`
            // default resolving to many threads is not the user's doing.
            if let ScoreThreadSpec::Fixed(n) = score_spec {
                if n > 1 {
                    eprintln!(
                        "note: --score-threads {n} is ignored with --scorer xla — the \
                         batched scorer already orders all processors in one call"
                    );
                }
            }
            // The portfolio is a meta-algorithm over the builder path;
            // it cannot be driven through a raw Engine.
            if algo == Algorithm::Portfolio {
                bail!("--scorer xla does not support --algo portfolio (use --scorer native)");
            }
            let scorer = memsched::runtime::scorer::XlaScorer::load_default()?;
            let order = algo.rank_order(&wf, &cluster);
            memsched::scheduler::Engine::new(&wf, &cluster, algo, policy)
                .with_scorer(&scorer)
                .run(&order)
        }
        other => bail!("unknown scorer `{other}` (native, xla)"),
    };
    let dt = t0.elapsed();

    println!("algorithm:   {}", algo.label());
    println!("valid:       {}", schedule.valid);
    println!("makespan:    {:.3}", schedule.makespan);
    println!(
        "mem usage:   {:.1}% (mean peak over used processors)",
        100.0 * schedule.mean_mem_usage()
    );
    println!("procs used:  {}/{}", schedule.procs_used(), cluster.len());
    println!("evictions:   {}", schedule.tasks.iter().map(|t| t.evicted.len()).sum::<usize>());
    println!("sched time:  {}", memsched::bench::fmt_duration(dt));
    if !schedule.valid {
        println!(
            "failures:    {} (first: {:?})",
            schedule.failures.len(),
            schedule.failures.first()
        );
    }
    if let Some(path) = out {
        let json = schedule_json(&wf, &schedule);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn schedule_json(
    wf: &workflow::Workflow,
    s: &memsched::scheduler::Schedule,
) -> memsched::ser::json::Value {
    use memsched::ser::json::{obj, Value};
    let tasks: Vec<Value> = s
        .tasks
        .iter()
        .enumerate()
        .map(|(v, t)| {
            obj(vec![
                ("task", wf.task(v).name.as_str().into()),
                ("proc", t.proc.into()),
                ("start", t.start.into()),
                ("finish", t.finish.into()),
                ("evictions", t.evicted.len().into()),
            ])
        })
        .collect();
    obj(vec![
        ("workflow", wf.name.as_str().into()),
        ("algorithm", s.algorithm.label().into()),
        ("valid", s.valid.into()),
        ("makespan", s.makespan.into()),
        ("tasks", Value::Array(tasks)),
    ])
}

fn cmd_simulate(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let no_recompute = args.flag("no-recompute");
    let json = args.flag("json");
    args.finish()?;

    let schedule = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
    if !json {
        println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    }
    if !schedule.valid {
        if json {
            // Machine-readable error object on stdout *and* a non-zero
            // exit, so scripted consumers can't mistake it for a sim
            // object.
            use memsched::ser::json::obj;
            println!("{}", obj(vec![("error", "initial schedule invalid".into())]).to_string_compact());
            bail!("initial schedule invalid; execution not attempted");
        }
        println!("initial schedule invalid; execution not attempted");
        return Ok(());
    }
    let mode = if no_recompute { SimMode::FollowStatic } else { SimMode::Recompute };
    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
    // Through the scaffold-backed shim — the same replay core the
    // service's sweep path drives (scaffold build + one run).
    let out = simulate(&wf, &cluster, &schedule, &cfg);
    if json {
        // Exactly the `sim` object of a batch JSONL line — one shared
        // mapping + serializer (`SimResult`), so `ci.sh --smoke` can
        // byte-compare these against the replay engine's sweep output.
        println!("{}", SimResult::from_outcome(mode, &out).to_json().to_string_compact());
        return Ok(());
    }
    println!("mode:            {mode:?}");
    println!("completed:       {}", out.completed);
    println!("makespan:        {:.3}", out.makespan);
    println!("recomputations:  {}", out.recomputations);
    println!("tasks started:   {}/{}", out.started, wf.num_tasks());
    if let Some(f) = out.failure {
        println!("failure:         {f:?}");
    }
    Ok(())
}

/// Simulate one execution with event tracing on and render it as Chrome
/// trace-event JSON (`ui.perfetto.dev` / `chrome://tracing`): a process
/// track per processor with one slice per executed task, a per-processor
/// memory-waterline counter track, and recomputation instants.
fn cmd_trace(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let no_recompute = args.flag("no-recompute");
    let check = args.flag("check");
    let out = args.opt_val("out")?;
    args.finish()?;

    let schedule = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
    if !schedule.valid {
        bail!("initial schedule invalid; execution not attempted");
    }
    let mode = if no_recompute { SimMode::FollowStatic } else { SimMode::Recompute };
    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
    // Recording brackets exactly this simulation.
    memsched::obs::set_enabled(true);
    let outcome = simulate(&wf, &cluster, &schedule, &cfg);
    memsched::obs::set_enabled(false);
    let recs = memsched::obs::drain();
    let text = memsched::obs::chrome::render(&recs).to_string_compact();
    if check {
        // Round-trip through the parser: validates exactly the bytes a
        // consumer would load (the ci.sh trace smoke drives this).
        let parsed = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("rendered trace does not re-parse: {e}"))?;
        memsched::obs::chrome::validate(&parsed)
            .map_err(|e| anyhow::anyhow!("trace check failed: {e}"))?;
    }
    match &out {
        Some(path) => std::fs::write(path, text + "\n")
            .with_context(|| format!("writing trace to {path}"))?,
        None => println!("{text}"),
    }
    eprintln!(
        "trace: {} events ({} dropped), completed={} makespan={:.3} recomputations={}{}",
        recs.len(),
        memsched::obs::dropped(),
        outcome.completed,
        outcome.makespan,
        outcome.recomputations,
        if check { ", check passed" } else { "" }
    );
    Ok(())
}

/// §V: compute a schedule, apply a deviation, and retrace it — reporting
/// whether the schedule survives and the updated makespan.
fn cmd_retrace(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let lost: Vec<usize> = args
        .multi("lose-proc")
        .iter()
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --lose-proc `{s}`")))
        .collect::<Result<_>>()?;
    args.finish()?;

    let schedule = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        anyhow::bail!("initial schedule invalid; nothing to retrace");
    }
    let actual = DeviationModel::new(sigma, seed).deviate_workflow(&wf);
    let r = memsched::scheduler::retrace::retrace(
        &actual,
        &cluster,
        &schedule,
        EvictionPolicy::LargestFirst,
        &lost,
    );
    println!("deviation:       sigma={sigma} seed={seed} lost_procs={lost:?}");
    println!("still valid:     {}", r.valid);
    if r.valid {
        println!(
            "new makespan:    {:.3} ({:+.1}% vs plan)",
            r.makespan,
            100.0 * (r.makespan - schedule.makespan) / schedule.makespan
        );
    }
    if let Some(t) = r.failed_task {
        println!("first violation: task {t} (`{}`): {:?}", wf.task(t).name, r.failure);
        println!("(a dynamic run would recompute here: `memsched simulate ...`)");
    }
    Ok(())
}

/// `--jobs N` (clamped to ≥ 1), defaulting to all cores.
fn workers_arg(args: &mut Args) -> Result<usize> {
    Ok(match args.opt::<usize>("jobs")? {
        Some(n) => n.max(1),
        None => memsched::service::pool::default_workers(),
    })
}

/// `--score-threads N|auto`, defaulting to `auto`: serial below the
/// measured `cluster × fan-in` crossover, all cores above it —
/// schedules are byte-identical either way.
fn score_threads_arg(args: &mut Args) -> Result<ScoreThreadSpec> {
    args.opt_or("score-threads", ScoreThreadSpec::Auto)
}

/// The service configuration shared by `batch` and `experiment`:
/// `--jobs`, `--score-threads`, `--score-pools`, `--cache-bytes`,
/// `--cache-dir`, `--cache-dir-bytes`. `--score-pools N` spreads the
/// batch workers round-robin over `N` independent score pools (0/1 =
/// one shared pool) — output bytes are identical either way.
/// `--no-portfolio-prune` replays every portfolio candidate even when
/// the analytic bound already rules it out (the prune is on by default).
fn service_config_args(args: &mut Args) -> Result<ServiceConfig> {
    Ok(ServiceConfig {
        workers: workers_arg(args)?,
        score: score_threads_arg(args)?,
        score_pools: args.opt_or("score-pools", 1usize)?,
        cache_bytes: args.opt("cache-bytes")?,
        cache_dir: args.opt_val("cache-dir")?.map(std::path::PathBuf::from),
        cache_dir_bytes: args.opt("cache-dir-bytes")?,
        portfolio_prune: !args.flag("no-portfolio-prune"),
    })
}

/// `--metrics-json PATH`: turn crate-wide event tracing on for this run
/// (result bytes are unaffected — the obs layer is a side channel) and
/// return the output path for [`write_metrics_json`].
fn metrics_json_arg(args: &mut Args) -> Result<Option<String>> {
    let path = args.opt_val("metrics-json")?;
    if path.is_some() {
        memsched::obs::set_enabled(true);
    }
    Ok(path)
}

/// Drain every recorded event and write the aggregated metrics (one
/// versioned `counters` record + one span-histogram record per observed
/// span kind) as JSONL to `path`.
fn write_metrics_json(path: &str) -> Result<()> {
    memsched::obs::set_enabled(false);
    let recs = memsched::obs::drain();
    let mut out = String::new();
    for rec in memsched::obs::metrics_records(&recs) {
        out.push_str(&rec.to_string_compact());
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing metrics to {path}"))
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let figure = args.req_str("figure")?;
    let scale: SuiteScale = args.opt_or("scale", SuiteScale::Quick)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let cfg = service_config_args(args)?;
    let sigmas: Vec<f64> = args.list_of("sigmas")?;
    let markdown = args.flag("markdown");
    let metrics_json = metrics_json_arg(args)?;
    args.finish()?;

    let dynamic_figure = matches!(figure.as_str(), "fig8" | "validity");
    if !sigmas.is_empty() && !dynamic_figure {
        bail!("--sigmas only applies to the dynamic figures (fig8, validity)");
    }
    if figure == "fig9" && cfg.workers > 1 {
        eprintln!(
            "note: fig9 reports per-heuristic wall times; with --jobs {} they are \
             measured under pool contention — pass --jobs 1 for clean timings",
            cfg.workers
        );
    }

    // Every suite runs through the scheduling-service pool (serial
    // per-spec loops lived here before).
    let render = |t: &memsched::ser::csv::CsvWriter| -> String {
        if markdown {
            t.to_markdown()
        } else {
            t.to_csv()
        }
    };
    let out = match figure.as_str() {
        "fig1" | "fig2" | "fig3" | "fig4" => {
            let cluster = memsched::platform::presets::default_cluster();
            let results = experiments::run_static_suite(scale, seed, &cluster, &cfg)?;
            let table = match figure.as_str() {
                "fig1" => figures::success_rates(&results),
                "fig2" => figures::relative_makespans(&results),
                "fig3" => figures::memory_usage(&results, false),
                _ => figures::memory_usage(&results, true),
            };
            render(&table)
        }
        "fig5" | "fig6" | "fig7" | "fig9" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results = experiments::run_static_suite(scale, seed, &cluster, &cfg)?;
            let table = match figure.as_str() {
                "fig5" => figures::success_rates(&results),
                "fig6" => figures::relative_makespans(&results),
                "fig7" => figures::memory_usage(&results, false),
                _ => figures::heuristic_runtimes(&results),
            };
            render(&table)
        }
        "fig8" | "validity" => {
            // Headers only when --sigmas was passed: the legacy
            // single-sigma default keeps its pure-CSV stdout format.
            let sigma_headers = !sigmas.is_empty();
            let sigmas = if sigmas.is_empty() { vec![0.1] } else { sigmas };
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            // One replay-engine pass: each static schedule is computed
            // once and replayed at every sigma level.
            let per_sigma = experiments::run_dynamic_suite(scale, seed, &cluster, &sigmas, &cfg)?;
            // One self-contained `# sigma=…`-headed table per sigma, so
            // a multi-sigma run's output is byte-identical to the
            // per-sigma (`--sigmas <s>`) runs concatenated.
            let mut out = String::new();
            for (sigma, results) in sigmas.iter().zip(&per_sigma) {
                let table = if figure == "fig8" {
                    figures::dynamic_improvement(results)
                } else {
                    figures::dynamic_validity(results)
                };
                if sigma_headers {
                    out.push_str(&format!("# sigma={sigma}\n"));
                }
                out.push_str(&render(&table));
            }
            out
        }
        other => bail!("unknown figure `{other}`"),
    };
    print!("{out}");
    if let Some(path) = &metrics_json {
        write_metrics_json(path)?;
    }
    Ok(())
}

/// A batch submission: plain per-point jobs or replay sweeps. The two
/// emit byte-identical JSONL for equal flattened content; sweeps
/// additionally guarantee the schedule-once-replay-many execution shape.
enum Batch {
    Jobs(Vec<Job>),
    Sweeps(Vec<ReplaySweep>),
}

impl Batch {
    fn len(&self) -> usize {
        match self {
            Batch::Jobs(jobs) => jobs.len(),
            Batch::Sweeps(sweeps) => sweeps.iter().map(ReplaySweep::num_results).sum(),
        }
    }

    fn repeated(self, repeat: usize) -> Batch {
        match self {
            Batch::Jobs(base) => {
                let mut jobs = Vec::with_capacity(base.len() * repeat);
                for _ in 0..repeat {
                    jobs.extend(base.iter().cloned());
                }
                Batch::Jobs(jobs)
            }
            Batch::Sweeps(base) => {
                let mut sweeps = Vec::with_capacity(base.len() * repeat);
                for _ in 0..repeat {
                    sweeps.extend(base.iter().cloned());
                }
                Batch::Sweeps(sweeps)
            }
        }
    }
}

/// Run a batch of scheduling jobs (or replay sweeps) on the
/// multi-threaded service and stream the results as JSONL (stdout or
/// `--out`). Lines are emitted **incrementally**, in job order, as each
/// ordered slot completes — long batches show progress instead of
/// buffering until the end. The output bytes are identical for any
/// `--jobs`/`--score-threads` value and for warm/cold `--cache-dir`;
/// the run summary (human line + JSONL record) goes to stderr.
fn cmd_batch(args: &mut Args) -> Result<()> {
    let input = args.opt_val("input")?;
    let suite = args.opt_val("suite")?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let default_cluster = args.opt_val("cluster")?.unwrap_or_else(|| "default".into());
    let cfg = service_config_args(args)?;
    let sigmas: Vec<f64> = args.list_of("sigmas")?;
    let repeat: usize = args.opt_or("repeat", 1)?;
    if repeat == 0 {
        bail!("--repeat must be at least 1");
    }
    let out = args.opt_val("out")?;
    let metrics_json = metrics_json_arg(args)?;
    args.finish()?;

    let base: Batch = match (&input, &suite) {
        (Some(path), None) => {
            if !sigmas.is_empty() {
                bail!("--sigmas only applies to --suite batches; put a `sweep` array on the job lines instead");
            }
            parse_jobs_file(path, &ParseDefaults { cluster: default_cluster.clone(), seed })?
        }
        (None, Some(scale_str)) => {
            let scale: SuiteScale = scale_str.parse()?;
            let cluster = ClusterSpec::Named(default_cluster);
            if sigmas.is_empty() {
                Batch::Jobs(experiments::static_suite_jobs(scale, seed, &cluster))
            } else {
                // Dynamic replay sweeps: one static schedule per
                // (workload, algorithm), replayed at every sigma × mode.
                let specs = experiments::dynamic_suite_specs(scale, seed);
                Batch::Sweeps(experiments::dynamic_suite_sweeps(&specs, &cluster, &sigmas))
            }
        }
        _ => bail!("batch requires exactly one of --input <jobs.jsonl> or --suite <smoke|quick|full>"),
    };
    if base.len() == 0 {
        bail!("batch is empty");
    }
    let batch = base.repeated(repeat);

    let t0 = std::time::Instant::now();
    let service = cfg.build()?;

    // Stream each JSONL line the moment its ordered slot completes.
    // Per-line flush only for stdout (where incremental visibility is
    // the point); file output keeps BufWriter batching — the emitter
    // lock serializes this sink across pool workers, so a syscall per
    // line would throttle the whole pool.
    use std::io::Write as _;
    let flush_each_line = out.is_none();
    let mut writer: Box<dyn std::io::Write + Send> = match &out {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
        )),
        None => Box::new(std::io::stdout()),
    };
    let mut write_err: Option<std::io::Error> = None;
    let (mut emitted, mut dedup_hits, mut failed) = (0usize, 0usize, 0usize);
    {
        let sink = |r: memsched::service::JobResult| {
            emitted += 1;
            if r.cache_hit {
                dedup_hits += 1;
            }
            if r.error.is_some() {
                failed += 1;
            }
            if write_err.is_none() {
                let res = writer
                    .write_all(r.to_jsonl().as_bytes())
                    .and_then(|()| writer.write_all(b"\n"))
                    .and_then(|()| if flush_each_line { writer.flush() } else { Ok(()) });
                if let Err(e) = res {
                    write_err = Some(e);
                }
            }
        };
        match batch {
            Batch::Jobs(jobs) => service.run_batch_streaming(jobs, sink),
            Batch::Sweeps(sweeps) => service.run_replay_sweeps_streaming(sweeps, sink),
        }
    }
    let final_flush = writer.flush();
    if let Some(e) = write_err.or(final_flush.err()) {
        return Err(anyhow::Error::from(e)
            .context(format!("writing results to {}", out.as_deref().unwrap_or("stdout"))));
    }

    let stats = service.cache_stats();
    eprintln!(
        "batch: {emitted} jobs ({dedup_hits} deduped), {} schedules computed, {} cache hits \
         ({} from disk), {} worker(s), {} score thread(s), {}",
        stats.computed,
        stats.hits(),
        stats.disk_hits,
        service.workers(),
        service.score_threads(),
        memsched::bench::fmt_duration(t0.elapsed())
    );
    // Machine-readable summary record (stderr: the JSONL result stream
    // on stdout/--out must stay byte-identical across warm/cold caches).
    eprintln!("{}", service.summary_json(emitted, dedup_hits, failed).to_string_compact());
    if let Some(path) = &metrics_json {
        write_metrics_json(path)?;
    }
    if failed > 0 {
        bail!("{failed} of {emitted} jobs failed (see the `error` lines)");
    }
    Ok(())
}

/// Parse a JSONL job file (one JSON object per line; `#` comments and
/// blank lines ignored). `defaults` (the CLI's `--cluster`/`--seed`)
/// applies to lines that omit those fields. One parser serves this path
/// and the `serve` daemon's job frames ([`JobSpec::parse_line`]), so the
/// two accept exactly the same grammar. If any line carries a `sweep`
/// array the whole batch runs through the replay engine (plain lines
/// become one-point sweeps); the output bytes are identical either way.
fn parse_jobs_file(path: &str, defaults: &ParseDefaults) -> Result<Batch> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading job file {path}"))?;
    let mut parsed: Vec<JobSpec> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        parsed.push(
            JobSpec::parse_line(line, defaults)
                .with_context(|| format!("{path}:{} (job {})", lineno + 1, parsed.len() + 1))?,
        );
    }
    if parsed.iter().any(|spec| matches!(spec, JobSpec::Sweep(_))) {
        Ok(Batch::Sweeps(parsed.into_iter().map(JobSpec::into_sweep).collect()))
    } else {
        Ok(Batch::Jobs(
            parsed
                .into_iter()
                .map(|spec| match spec {
                    JobSpec::Single(job) => job,
                    JobSpec::Sweep(_) => unreachable!("sweep-free batch"),
                })
                .collect(),
        ))
    }
}

/// Compare a bench JSONL file (entries `{"id": ..., "throughput": ...,
/// "seconds": ...}`, as emitted by the benches under
/// `MEMSCHED_BENCH_JSON`) against a baseline file: fail when any shared
/// id's throughput regressed more than `--tolerance`× (default 2×, wide
/// enough to absorb machine noise but not an accidental serial path).
/// Ids present on only one side are reported and skipped — baselines
/// from differently-sized machines simply compare fewer entries.
fn cmd_bench_check(args: &mut Args) -> Result<()> {
    let current_path = args.req_str("current")?;
    let baseline_path = args.req_str("baseline")?;
    let tolerance: f64 = args.opt_or("tolerance", 2.0)?;
    args.finish()?;
    if tolerance.is_nan() || tolerance < 1.0 {
        bail!("--tolerance must be >= 1.0");
    }

    let load = |path: &str| -> Result<std::collections::BTreeMap<String, f64>> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading bench file {path}"))?;
        let mut entries = std::collections::BTreeMap::new();
        for v in memsched::ser::json::parse_jsonl(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        {
            let id = v.req_str("id").with_context(|| format!("bench entry in {path}"))?;
            let throughput =
                v.req_f64("throughput").with_context(|| format!("bench entry `{id}` in {path}"))?;
            if throughput.is_nan() || throughput <= 0.0 {
                bail!("bench entry `{id}` in {path} has non-positive throughput {throughput}");
            }
            entries.insert(id.to_string(), throughput);
        }
        Ok(entries)
    };
    let current = load(&current_path)?;
    let baseline = load(&baseline_path)?;

    let (mut compared, mut regressions) = (0usize, 0usize);
    for (id, base) in &baseline {
        match current.get(id) {
            None => println!("{id}: not in current run (skipped)"),
            Some(cur) => {
                compared += 1;
                let slowdown = base / cur;
                let verdict = if slowdown > tolerance {
                    regressions += 1;
                    "REGRESSED"
                } else {
                    "ok"
                };
                println!(
                    "{id}: baseline {base:.2}/s, current {cur:.2}/s ({slowdown:.2}x slowdown) {verdict}"
                );
            }
        }
    }
    for id in current.keys().filter(|id| !baseline.contains_key(*id)) {
        println!("{id}: new metric (no baseline)");
    }
    if compared == 0 {
        eprintln!("warning: no comparable bench entries between {current_path} and {baseline_path}");
    }
    if regressions > 0 {
        bail!("{regressions} bench metric(s) regressed more than {tolerance}x against {baseline_path}");
    }
    Ok(())
}

/// Run the persistent scheduler daemon (`memsched serve`): accept
/// clients on a Unix socket (or serve one client over stdio), execute
/// their job frames on the shared scheduling service, and stream result
/// frames back. Returns — with exit code 0 — after a graceful drain
/// (SIGTERM/SIGINT or a `{"ctl":"shutdown"}` frame); the per-client
/// summary record goes to stderr, like `batch`'s summary line.
fn cmd_serve(args: &mut Args) -> Result<()> {
    let socket = args.opt_val("socket")?;
    let stdio = args.flag("stdio");
    let seed: u64 = args.opt_or("seed", 42)?;
    let default_cluster = args.opt_val("cluster")?.unwrap_or_else(|| "default".into());
    let cfg = service_config_args(args)?;
    let max_frame_bytes: usize =
        args.opt_or("max-frame-bytes", memsched::ser::frame::DEFAULT_MAX_FRAME_BYTES)?;
    let max_queued_per_client: usize = args.opt_or("max-queued-per-client", 1024)?;
    let metrics_json = metrics_json_arg(args)?;
    args.finish()?;
    if max_frame_bytes == 0 {
        bail!("--max-frame-bytes must be at least 1");
    }
    if max_queued_per_client == 0 {
        bail!("--max-queued-per-client must be at least 1");
    }

    let opts = ServeOptions {
        max_frame_bytes,
        max_queued_per_client,
        defaults: ParseDefaults { cluster: default_cluster, seed },
    };
    let service = cfg.build()?;
    memsched::service::serve::install_signal_handlers();
    let t0 = std::time::Instant::now();
    let summary = match (&socket, stdio) {
        (Some(path), false) => {
            eprintln!("serve: listening on {path}");
            memsched::service::serve::serve_unix(&service, std::path::Path::new(path), &opts)?
        }
        (None, true) => memsched::service::serve::serve_stdio(&service, &opts)?,
        _ => bail!("serve requires exactly one of --socket <path> or --stdio"),
    };

    let stats = service.cache_stats();
    eprintln!(
        "serve: {} client(s), {} results ({} cache hits, {} failed), {} schedules computed, up {}",
        summary.clients.len(),
        summary.total_results(),
        summary.total_cache_hits(),
        summary.total_failed(),
        stats.computed,
        memsched::bench::fmt_duration(t0.elapsed())
    );
    // Machine-readable shutdown summary — the batch record plus a
    // per-client `clients` array (ci.sh asserts on these counters).
    eprintln!(
        "{}",
        service
            .summary_json_with_clients(
                summary.total_results(),
                summary.total_cache_hits(),
                summary.total_failed(),
                &summary.clients,
            )
            .to_string_compact()
    );
    if let Some(path) = &metrics_json {
        write_metrics_json(path)?;
    }
    Ok(())
}

/// Submit a JSONL job file to a running `memsched serve` daemon and
/// stream the result frames to stdout — byte-identical to `memsched
/// batch --input` on the same file. Requests are written from a helper
/// thread while this thread drains responses, so neither side can stall
/// on a full socket buffer; a final `{"ctl":"drain"}` barrier tells us
/// when every result has arrived.
fn cmd_client(args: &mut Args) -> Result<()> {
    use memsched::ser::frame::{read_frame, write_frame, DEFAULT_MAX_FRAME_BYTES};
    use std::io::{Read as _, Write as _};

    let socket = args.req_str("socket")?;
    let input = args.opt_val("input")?;
    let shutdown = args.flag("shutdown");
    let stats = args.flag("stats");
    args.finish()?;

    let text = match &input {
        Some(path) => {
            std::fs::read_to_string(path).with_context(|| format!("reading job file {path}"))?
        }
        // A stats-only probe: don't block on stdin when there is no job
        // input — the point is to ask a live daemon a question and exit.
        None if stats => String::new(),
        None => {
            let mut buf = String::new();
            std::io::stdin().read_to_string(&mut buf).context("reading jobs from stdin")?;
            buf
        }
    };
    // The same line discipline as `batch --input`: blank lines and `#`
    // comments are the file format's, not the wire's — skip them here.
    let lines: Vec<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    let submitted = lines.len();

    let stream = std::os::unix::net::UnixStream::connect(&socket)
        .with_context(|| format!("connecting to serve socket {socket}"))?;
    let mut reader = stream.try_clone().context("cloning socket handle")?;
    let mut writer = stream;
    let sender = std::thread::spawn(move || -> std::io::Result<()> {
        for line in &lines {
            write_frame(&mut writer, line.as_bytes())?;
        }
        write_frame(&mut writer, b"{\"ctl\":\"drain\"}")?;
        writer.flush()
    });

    let mut stdout = std::io::stdout();
    let (mut results, mut failed) = (0usize, 0usize);
    loop {
        let payload = match read_frame(&mut reader, DEFAULT_MAX_FRAME_BYTES)? {
            Some(p) => p,
            None => bail!("server closed the connection before acking the drain"),
        };
        let parsed = std::str::from_utf8(&payload).ok().and_then(|s| Value::parse(s).ok());
        let Some(v) = parsed else {
            bail!("malformed frame payload from server: {}", String::from_utf8_lossy(&payload));
        };
        if v.get("id").is_some() {
            // A result line: forward the exact payload bytes (this is
            // what makes `client` output comparable to `batch` output).
            results += 1;
            if v.get("error").is_some() {
                failed += 1;
            }
            stdout.write_all(&payload)?;
            stdout.write_all(b"\n")?;
            stdout.flush()?;
        } else if let Some(err) = v.get("error").and_then(Value::as_str) {
            // A rejected submission (parse error, backpressure, ...):
            // no result slot, so it only shows up in the failure count.
            failed += 1;
            eprintln!("serve error: {err}");
        } else if let Some(ok) = v.get("ok").and_then(Value::as_str) {
            if ok == "drained" {
                break;
            }
        } else {
            eprintln!("unrecognized frame from server: {}", String::from_utf8_lossy(&payload));
        }
    }
    sender
        .join()
        .map_err(|_| anyhow::anyhow!("request writer thread panicked"))?
        .context("sending job frames")?;

    if stats {
        let mut w = reader.try_clone().context("cloning socket handle")?;
        write_frame(&mut w, b"{\"ctl\":\"stats\"}")?;
        w.flush()?;
        match read_frame(&mut reader, DEFAULT_MAX_FRAME_BYTES)? {
            Some(payload) => {
                stdout.write_all(&payload)?;
                stdout.write_all(b"\n")?;
                stdout.flush()?;
            }
            None => bail!("server closed the connection before answering the stats request"),
        }
    }

    if shutdown {
        let mut w = reader.try_clone().context("cloning socket handle")?;
        write_frame(&mut w, b"{\"ctl\":\"shutdown\"}")?;
        w.flush()?;
        // Wait for the ack (or the daemon closing the socket) so the
        // drain request is known to have been admitted before we exit.
        let _ = read_frame(&mut reader, DEFAULT_MAX_FRAME_BYTES)?;
    }
    eprintln!("client: {submitted} submitted, {results} results, {failed} failed");
    if failed > 0 {
        bail!("{failed} submission(s)/result(s) failed (see the error lines)");
    }
    Ok(())
}
