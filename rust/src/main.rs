//! `memsched` — memory-aware adaptive workflow scheduling CLI.
//!
//! Subcommands:
//!
//! - `generate`      synthesize a workflow (model + size) to JSON
//! - `info`          print workflow statistics
//! - `cluster-info`  print a cluster configuration (Table II presets)
//! - `schedule`      compute a static schedule and report it
//! - `simulate`      run the dynamic runtime system on a schedule
//! - `experiment`    run an evaluation suite and print a figure's table
//!
//! Run `memsched help` for the full usage text.

use anyhow::{bail, Result};
use memsched::cli::Args;
use memsched::experiments::{self, figures, SuiteScale};
use memsched::platform::Cluster;
use memsched::scheduler::{compute_schedule, Algorithm, EvictionPolicy};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};
use memsched::workflow;

const USAGE: &str = "\
memsched — memory-aware adaptive scheduling of scientific workflows

USAGE:
  memsched <command> [options]

COMMANDS:
  generate      --model <name> [--tasks N] [--seed S] [--input 0..4] --out wf.json
  info          --workflow <file.json|.dot>
  cluster-info  [--cluster default|memory-constrained|file.json]
  schedule      --workflow <file> [--cluster C] [--algo heft|heftm-bl|heftm-blc|heftm-mm]
                [--eviction largest|smallest] [--scorer native|xla] [--out schedule.json]
  simulate      --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--no-recompute]
  retrace       --workflow <file> [--cluster C] [--algo A] [--sigma 0.1] [--seed S]
                [--lose-proc J]...   assess deviation impact on a schedule (§V)
  experiment    --figure fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|validity
                [--scale smoke|quick|full] [--seed S] [--markdown]
  help          print this text

Models: atacseq, bacass, chipseq, eager, methylseq.";

fn main() {
    // Die quietly when piped into `head` etc. (default SIGPIPE behaviour).
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.clone().as_deref() {
        Some("generate") => cmd_generate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("cluster-info") => cmd_cluster_info(&mut args),
        Some("schedule") => cmd_schedule(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("retrace") => cmd_retrace(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn load_workflow(args: &mut Args) -> Result<workflow::Workflow> {
    let path = args.req_str("workflow")?;
    workflow::io::load(std::path::Path::new(&path))
}

fn load_cluster(args: &mut Args) -> Result<Cluster> {
    Cluster::load(&args.opt_str("cluster").unwrap_or_else(|| "default".into()))
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let model_name = args.req_str("model")?;
    let model = memsched::generator::models::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model `{model_name}`"))?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let input: usize = args.opt_or("input", 2)?;
    let graph = match args.opt::<usize>("tasks")? {
        Some(n) => memsched::generator::scale_to(&model, n, seed)?,
        None => memsched::generator::expand(&model, 12)?,
    };
    let types = memsched::traces::task_types(&graph);
    let data = memsched::traces::HistoricalData::synthesize(
        &types,
        &memsched::traces::TraceConfig::default(),
        seed,
    );
    let wf = memsched::traces::bind_weights(&graph, &data, input);
    let out = args.req_str("out")?;
    args.finish()?;
    workflow::io::save(&wf, std::path::Path::new(&out))?;
    println!("wrote {} ({} tasks, {} edges)", out, wf.num_tasks(), wf.num_edges());
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    args.finish()?;
    let s = wf.stats();
    println!("workflow: {}", wf.name);
    println!("  tasks:        {}", s.tasks);
    println!("  edges:        {}", s.edges);
    println!("  sources:      {}", s.sources);
    println!("  sinks:        {}", s.sinks);
    println!("  depth:        {}", s.depth);
    println!("  max in/out:   {}/{}", s.max_in_degree, s.max_out_degree);
    println!("  total work:   {:.3e}", s.total_work);
    println!("  total data:   {:.3e} bytes", s.total_data);
    println!("  max r_u:      {:.3e} bytes", s.max_memory_requirement);
    println!("  size group:   {}", workflow::SizeGroup::of(s.tasks).label());
    Ok(())
}

fn cmd_cluster_info(args: &mut Args) -> Result<()> {
    let cluster = load_cluster(args)?;
    args.finish()?;
    println!(
        "cluster: {} ({} processors, β = {:.3e} B/s)",
        cluster.name,
        cluster.len(),
        cluster.bandwidth
    );
    // Aggregate per kind (Table II).
    let mut kinds: Vec<&str> = cluster.processors.iter().map(|p| p.kind.as_str()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    println!(
        "{:<8} {:>6} {:>12} {:>14} {:>14}",
        "kind", "count", "speed", "memory(GB)", "buffer(GB)"
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kind in kinds {
        let ps: Vec<_> = cluster.processors.iter().filter(|p| p.kind == kind).collect();
        println!(
            "{:<8} {:>6} {:>12.1} {:>14.1} {:>14.1}",
            kind,
            ps.len(),
            ps[0].speed,
            ps[0].memory / GB,
            ps[0].comm_buffer / GB
        );
    }
    Ok(())
}

fn cmd_schedule(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let policy: EvictionPolicy = args.opt_or("eviction", EvictionPolicy::LargestFirst)?;
    let scorer_kind = args.opt_str("scorer").unwrap_or_else(|| "native".into());
    let out = args.opt_str("out");
    args.finish()?;

    let t0 = std::time::Instant::now();
    let schedule = match scorer_kind.as_str() {
        "native" => compute_schedule(&wf, &cluster, algo, policy),
        "xla" => {
            let scorer = memsched::runtime::scorer::XlaScorer::load_default()?;
            let order = algo.rank_order(&wf, &cluster);
            memsched::scheduler::Engine::new(&wf, &cluster, algo, policy)
                .with_scorer(&scorer)
                .run(&order)
        }
        other => bail!("unknown scorer `{other}` (native, xla)"),
    };
    let dt = t0.elapsed();

    println!("algorithm:   {}", algo.label());
    println!("valid:       {}", schedule.valid);
    println!("makespan:    {:.3}", schedule.makespan);
    println!(
        "mem usage:   {:.1}% (mean peak over used processors)",
        100.0 * schedule.mean_mem_usage()
    );
    println!("procs used:  {}/{}", schedule.procs_used(), cluster.len());
    println!("evictions:   {}", schedule.tasks.iter().map(|t| t.evicted.len()).sum::<usize>());
    println!("sched time:  {}", memsched::bench::fmt_duration(dt));
    if !schedule.valid {
        println!(
            "failures:    {} (first: {:?})",
            schedule.failures.len(),
            schedule.failures.first()
        );
    }
    if let Some(path) = out {
        let json = schedule_json(&wf, &schedule);
        std::fs::write(&path, json.to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn schedule_json(
    wf: &workflow::Workflow,
    s: &memsched::scheduler::Schedule,
) -> memsched::ser::json::Value {
    use memsched::ser::json::{obj, Value};
    let tasks: Vec<Value> = s
        .tasks
        .iter()
        .enumerate()
        .map(|(v, t)| {
            obj(vec![
                ("task", wf.task(v).name.as_str().into()),
                ("proc", t.proc.into()),
                ("start", t.start.into()),
                ("finish", t.finish.into()),
                ("evictions", t.evicted.len().into()),
            ])
        })
        .collect();
    obj(vec![
        ("workflow", wf.name.as_str().into()),
        ("algorithm", s.algorithm.label().into()),
        ("valid", s.valid.into()),
        ("makespan", s.makespan.into()),
        ("tasks", Value::Array(tasks)),
    ])
}

fn cmd_simulate(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let no_recompute = args.flag("no-recompute");
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        println!("initial schedule invalid; execution not attempted");
        return Ok(());
    }
    let mode = if no_recompute { SimMode::FollowStatic } else { SimMode::Recompute };
    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
    let out = simulate(&wf, &cluster, &schedule, &cfg);
    println!("mode:            {mode:?}");
    println!("completed:       {}", out.completed);
    println!("makespan:        {:.3}", out.makespan);
    println!("recomputations:  {}", out.recomputations);
    println!("tasks started:   {}/{}", out.started, wf.num_tasks());
    if let Some(f) = out.failure {
        println!("failure:         {f:?}");
    }
    Ok(())
}

/// §V: compute a schedule, apply a deviation, and retrace it — reporting
/// whether the schedule survives and the updated makespan.
fn cmd_retrace(args: &mut Args) -> Result<()> {
    let wf = load_workflow(args)?;
    let cluster = load_cluster(args)?;
    let algo: Algorithm = args.opt_or("algo", Algorithm::HeftmBl)?;
    let sigma: f64 = args.opt_or("sigma", 0.1)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let lost: Vec<usize> = args
        .multi("lose-proc")
        .iter()
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --lose-proc `{s}`")))
        .collect::<Result<_>>()?;
    args.finish()?;

    let schedule = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
    println!("static schedule: valid={} makespan={:.3}", schedule.valid, schedule.makespan);
    if !schedule.valid {
        anyhow::bail!("initial schedule invalid; nothing to retrace");
    }
    let actual = DeviationModel::new(sigma, seed).deviate_workflow(&wf);
    let r = memsched::scheduler::retrace::retrace(
        &actual,
        &cluster,
        &schedule,
        EvictionPolicy::LargestFirst,
        &lost,
    );
    println!("deviation:       sigma={sigma} seed={seed} lost_procs={lost:?}");
    println!("still valid:     {}", r.valid);
    if r.valid {
        println!(
            "new makespan:    {:.3} ({:+.1}% vs plan)",
            r.makespan,
            100.0 * (r.makespan - schedule.makespan) / schedule.makespan
        );
    }
    if let Some(t) = r.failed_task {
        println!("first violation: task {t} (`{}`): {:?}", wf.task(t).name, r.failure);
        println!("(a dynamic run would recompute here: `memsched simulate ...`)");
    }
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let figure = args.req_str("figure")?;
    let scale: SuiteScale = args.opt_or("scale", SuiteScale::Quick)?;
    let seed: u64 = args.opt_or("seed", 42)?;
    let markdown = args.flag("markdown");
    args.finish()?;

    let table = match figure.as_str() {
        "fig1" | "fig2" | "fig3" | "fig4" => {
            let cluster = memsched::platform::presets::default_cluster();
            let results = run_static_suite(scale, seed, &cluster)?;
            match figure.as_str() {
                "fig1" => figures::success_rates(&results),
                "fig2" => figures::relative_makespans(&results),
                "fig3" => figures::memory_usage(&results, false),
                _ => figures::memory_usage(&results, true),
            }
        }
        "fig5" | "fig6" | "fig7" | "fig9" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results = run_static_suite(scale, seed, &cluster)?;
            match figure.as_str() {
                "fig5" => figures::success_rates(&results),
                "fig6" => figures::relative_makespans(&results),
                "fig7" => figures::memory_usage(&results, false),
                _ => figures::heuristic_runtimes(&results),
            }
        }
        "fig8" | "validity" => {
            let cluster = memsched::platform::presets::memory_constrained_cluster();
            let results = run_dynamic_suite(scale, seed, &cluster)?;
            if figure == "fig8" {
                figures::dynamic_improvement(&results)
            } else {
                figures::dynamic_validity(&results)
            }
        }
        other => bail!("unknown figure `{other}`"),
    };
    print!("{}", if markdown { table.to_markdown() } else { table.to_csv() });
    Ok(())
}

/// Run the static suite (all four algorithms on every workload).
fn run_static_suite(
    scale: SuiteScale,
    seed: u64,
    cluster: &Cluster,
) -> Result<Vec<experiments::StaticResult>> {
    let specs = experiments::suite(scale, seed);
    let mut results = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        eprintln!("[{}/{}] {}", i + 1, specs.len(), spec.id());
        results.extend(experiments::run_static(spec, cluster)?);
    }
    Ok(results)
}

/// Run the dynamic suite (sizes ≤ 2000, as in the paper's Fig 8).
fn run_dynamic_suite(
    scale: SuiteScale,
    seed: u64,
    cluster: &Cluster,
) -> Result<Vec<experiments::DynamicResult>> {
    let specs: Vec<_> = experiments::suite(scale, seed)
        .into_iter()
        .filter(|s| s.size.is_none_or(|n| n <= 2000))
        .collect();
    let mut results = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        eprintln!("[{}/{}] {}", i + 1, specs.len(), spec.id());
        for algo in Algorithm::all() {
            results.push(experiments::run_dynamic(spec, cluster, algo, 0.1)?);
        }
    }
    Ok(results)
}
