//! MemDag: minimum peak-memory sequential traversal (paper §III-B, [19]).
//!
//! HEFTM-MM ranks tasks in the order produced by the MemDag algorithm of
//! Kayaaslan et al. [19]: transform the workflow into a series-parallel
//! (SP) structure and find the traversal that minimizes peak memory.
//!
//! This reimplementation:
//!
//! 1. adds a virtual source/sink and attempts an exact two-terminal SP
//!    (TTSP) reduction ([`sptree`]), recording the decomposition tree;
//! 2. on success, orders parallel branches bottom-up by Liu's criterion —
//!    non-increasing `(peak − residual)` — which is optimal for
//!    single-hill memory profiles (the full segment-interleaving variant
//!    of [19] is approximated by this single-segment composition;
//!    documented in DESIGN.md);
//! 3. on non-SP graphs, falls back to a greedy ready-set traversal that
//!    picks the ready task with the smallest instantaneous memory peak
//!    (ties: largest freed input volume). This is also the slow path that
//!    gives HEFTM-MM its characteristic cost on large graphs (Fig 9).
//!
//! The sequential memory model matches the scheduler's accounting: during
//! `u`, resident = (files produced but not yet consumed) + `m_u` + outputs
//! of `u`; inputs of `u` are freed when it completes.

pub mod sptree;

use crate::workflow::{TaskId, Workflow};

/// Result of a min-memory traversal.
#[derive(Debug, Clone)]
pub struct Traversal {
    /// Topological order of all tasks.
    pub order: Vec<TaskId>,
    /// Peak resident memory of executing `order` sequentially.
    pub peak: f64,
    /// Whether the exact SP decomposition was used (vs greedy fallback).
    pub used_sp: bool,
}

/// Compute a memory-minimizing topological traversal (MemDag).
pub fn min_memory_traversal(wf: &Workflow) -> Traversal {
    let order = match sptree::decompose(wf) {
        Some(tree) => {
            let mut order = Vec::with_capacity(wf.num_tasks());
            emit(&tree, wf, &mut order);
            debug_assert!(wf.is_topological_order(&order));
            // The SP order is provably topological for TTSP graphs; fall
            // back defensively if the reduction produced something odd.
            if wf.is_topological_order(&order) {
                return Traversal { peak: peak_memory(wf, &order), order, used_sp: true };
            }
            greedy_min_peak(wf)
        }
        None => greedy_min_peak(wf),
    };
    Traversal { peak: peak_memory(wf, &order), order, used_sp: false }
}

/// Memory profile of a subtraversal: the maximum resident memory reached
/// (`peak`) and the net change after completion (`resid`, may be negative).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Profile {
    pub peak: f64,
    pub resid: f64,
}

impl Profile {
    pub const EMPTY: Profile = Profile { peak: 0.0, resid: 0.0 };

    /// Sequential composition: `self` then `other`.
    pub fn then(self, other: Profile) -> Profile {
        Profile { peak: self.peak.max(self.resid + other.peak), resid: self.resid + other.resid }
    }
}

/// Footprint of a single task in the sequential model.
fn task_profile(wf: &Workflow, u: TaskId) -> Profile {
    let inp = wf.total_in_data(u);
    let out = wf.total_out_data(u);
    // Inputs are resident before u starts (produced by earlier tasks in the
    // same subgraph); the subtraversal containing u starts *after* they are
    // produced, so from the branch's local perspective executing u adds
    // m_u + out on top of what is already resident and then frees inp.
    Profile { peak: wf.task(u).memory + out, resid: out - inp }
}

/// Bottom-up Liu composition over the SP tree; sorts parallel branches in
/// place by non-increasing (peak − resid) and returns the node's profile.
fn compose(node: &mut sptree::SpNode, wf: &Workflow) -> Profile {
    use sptree::SpNode::*;
    match node {
        Empty => Profile::EMPTY,
        Vertex(v) => task_profile(wf, *v),
        Series(children) => {
            let mut acc = Profile::EMPTY;
            for c in children.iter_mut() {
                acc = acc.then(compose(c, wf));
            }
            acc
        }
        Parallel(children) => {
            let mut profiled: Vec<(Profile, sptree::SpNode)> = std::mem::take(children)
                .into_iter()
                .map(|mut c| {
                    let p = compose(&mut c, wf);
                    (p, c)
                })
                .collect();
            // Liu's ordering: non-increasing (peak - resid).
            profiled.sort_by(|a, b| {
                let ka = a.0.peak - a.0.resid;
                let kb = b.0.peak - b.0.resid;
                kb.partial_cmp(&ka).unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut acc = Profile::EMPTY;
            for (p, c) in profiled.iter() {
                acc = acc.then(*p);
                let _ = c;
            }
            *children = profiled.into_iter().map(|(_, c)| c).collect();
            acc
        }
    }
}

fn emit(tree: &sptree::SpTree, wf: &Workflow, out: &mut Vec<TaskId>) {
    let mut root = tree.root.clone();
    compose(&mut root, wf);
    walk(&root, out);
}

fn walk(node: &sptree::SpNode, out: &mut Vec<TaskId>) {
    use sptree::SpNode::*;
    match node {
        Empty => {}
        Vertex(v) => out.push(*v),
        Series(cs) | Parallel(cs) => {
            for c in cs {
                walk(c, out);
            }
        }
    }
}

/// Peak resident memory of a *sequential* execution in the given order.
///
/// Resident set: produced-but-unconsumed files. While `u` runs, usage =
/// resident + `m_u` + out(u); inputs of `u` are freed at completion.
/// Panics in debug builds if `order` is not topological.
pub fn peak_memory(wf: &Workflow, order: &[TaskId]) -> f64 {
    debug_assert!(wf.is_topological_order(order), "peak_memory needs a topological order");
    let mut resident = 0.0f64;
    let mut peak = 0.0f64;
    for &u in order {
        let inp = wf.total_in_data(u);
        let out = wf.total_out_data(u);
        // Inputs are already part of `resident`.
        let during = resident + wf.task(u).memory + out;
        peak = peak.max(during);
        resident += out - inp;
    }
    peak
}

/// Greedy fallback: repeatedly execute the ready task with the smallest
/// instantaneous peak (resident + m_u + out); ties broken by the largest
/// freed input volume, then by task id (determinism).
pub fn greedy_min_peak(wf: &Workflow) -> Vec<TaskId> {
    let n = wf.num_tasks();
    let mut indeg: Vec<usize> = (0..n).map(|u| wf.in_degree(u)).collect();
    let mut ready: Vec<TaskId> = (0..n).filter(|&u| indeg[u] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut resident = 0.0f64;
    while let Some((idx, _)) = ready
        .iter()
        .enumerate()
        .map(|(i, &u)| {
            let during = wf.task(u).memory + wf.total_out_data(u);
            let freed = wf.total_in_data(u);
            (i, (during, -freed, u))
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
    {
        let u = ready.swap_remove(idx);
        order.push(u);
        resident += wf.total_out_data(u) - wf.total_in_data(u);
        let _ = resident;
        for (v, _) in wf.children(u) {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                ready.push(v);
            }
        }
    }
    debug_assert_eq!(order.len(), n);
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::WorkflowBuilder;

    /// Chain a -> b -> c with given memories and unit edges.
    fn chain() -> Workflow {
        let mut b = WorkflowBuilder::new("chain");
        let t0 = b.task("a", "t", 1.0, 10.0);
        let t1 = b.task("b", "t", 1.0, 20.0);
        let t2 = b.task("c", "t", 1.0, 5.0);
        b.edge(t0, t1, 2.0);
        b.edge(t1, t2, 3.0);
        b.build().unwrap()
    }

    /// Two parallel chains between source and sink with different peaks.
    fn two_branches() -> Workflow {
        let mut b = WorkflowBuilder::new("par");
        let s = b.task("s", "t", 1.0, 1.0);
        // Heavy branch: peak 100.
        let h = b.task("h", "t", 1.0, 100.0);
        // Light branch: peak 10 but large residual output.
        let l = b.task("l", "t", 1.0, 10.0);
        let t = b.task("t", "t", 1.0, 1.0);
        b.edge(s, h, 1.0);
        b.edge(s, l, 1.0);
        b.edge(h, t, 1.0);
        b.edge(l, t, 50.0);
        b.build().unwrap()
    }

    #[test]
    fn chain_traversal_trivial() {
        let wf = chain();
        let tr = min_memory_traversal(&wf);
        assert_eq!(tr.order, vec![0, 1, 2]);
        assert!(tr.used_sp);
        // Peak: while b runs, resident = edge(a,b)=2 + m_b=20 + out=3 -> 25.
        assert_eq!(tr.peak, 25.0);
    }

    #[test]
    fn parallel_branch_ordering_prefers_heavy_first() {
        let wf = two_branches();
        let tr = min_memory_traversal(&wf);
        assert!(wf.is_topological_order(&tr.order));
        // Heavy branch (peak 100, resid 0) must run before the light one
        // that leaves 50 resident: doing it after would make 100 + 50.
        let pos_h = tr.order.iter().position(|&u| wf.task(u).name == "h").unwrap();
        let pos_l = tr.order.iter().position(|&u| wf.task(u).name == "l").unwrap();
        assert!(pos_h < pos_l, "order: {:?}", tr.order);
        // And the achieved peak beats the bad order.
        let bad = vec![0usize, 2, 1, 3];
        assert!(wf.is_topological_order(&bad));
        assert!(tr.peak <= peak_memory(&wf, &bad));
    }

    #[test]
    fn non_sp_graph_uses_fallback() {
        // N-graph: a->c, a->d, b->d (plus isolated structure) is not TTSP.
        let mut b = WorkflowBuilder::new("n");
        let a = b.task("a", "t", 1.0, 1.0);
        let bb = b.task("b", "t", 1.0, 1.0);
        let c = b.task("c", "t", 1.0, 1.0);
        let d = b.task("d", "t", 1.0, 1.0);
        b.edge(a, c, 1.0);
        b.edge(a, d, 1.0);
        b.edge(bb, d, 1.0);
        let wf = b.build().unwrap();
        let tr = min_memory_traversal(&wf);
        assert!(!tr.used_sp);
        assert!(wf.is_topological_order(&tr.order));
    }

    #[test]
    fn traversal_always_topological_on_models() {
        for model in crate::generator::models::all_models() {
            let wf = crate::generator::expand(&model, 6).unwrap();
            let tr = min_memory_traversal(&wf);
            assert!(wf.is_topological_order(&tr.order), "{}", model.name);
            assert_eq!(tr.order.len(), wf.num_tasks());
        }
    }

    #[test]
    fn peak_memory_accounts_frees() {
        let wf = chain();
        // Natural order: peaks are a: 0+10+2=12, b: 2+20+3=25, c: 3+5=8.
        assert_eq!(peak_memory(&wf, &[0, 1, 2]), 25.0);
    }

    #[test]
    fn profile_composition() {
        let a = Profile { peak: 10.0, resid: 4.0 };
        let b = Profile { peak: 3.0, resid: -2.0 };
        let ab = a.then(b);
        assert_eq!(ab.peak, 10.0); // 4 + 3 = 7 < 10
        assert_eq!(ab.resid, 2.0);
        let ba = b.then(a);
        assert_eq!(ba.peak, 8.0); // max(3, -2 + 10)
        assert_eq!(ba.resid, 2.0);
    }

    #[test]
    fn min_traversal_no_worse_than_default_order_on_random_sp() {
        // Generated SP-ish model workflows: MemDag order should not exceed
        // the peak of the plain topological order.
        for samples in [2usize, 5, 9] {
            let model = crate::generator::models::methylseq();
            let wf = crate::generator::expand(&model, samples).unwrap();
            let wf = crate::traces::bind_weights(
                &wf,
                &crate::traces::HistoricalData::synthesize(
                    &crate::traces::task_types(&wf),
                    &crate::traces::TraceConfig { missing_fraction: 0.2, ..Default::default() },
                    42,
                ),
                2,
            );
            let tr = min_memory_traversal(&wf);
            let default_peak = peak_memory(&wf, &wf.topological_order());
            assert!(
                tr.peak <= default_peak * 1.0001,
                "samples={samples}: {} vs {default_peak}",
                tr.peak
            );
        }
    }
}
