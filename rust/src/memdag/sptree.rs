//! Two-terminal series-parallel (TTSP) recognition and decomposition.
//!
//! A virtual source/sink is attached to the workflow, then the classic
//! reduction procedure runs to a fixed point:
//!
//! - **series**: an internal vertex `v` with in-degree = out-degree = 1 is
//!   spliced out, `(u→v) + (v→w)  ⇒  (u→w)` recording `Series(A, v, B)`;
//! - **parallel**: two edges with identical endpoints merge into one,
//!   recording `Parallel(A, B)`.
//!
//! The graph is TTSP iff the fixed point is a single `src→sink` edge; its
//! recorded [`SpNode`] is the decomposition tree. The reduction is
//! worklist-driven and runs in near-linear time.

use crate::workflow::{TaskId, Workflow};
use std::collections::HashMap;

/// Decomposition-tree node. `Vertex` leaves carry the tasks; edges that
/// never swallowed a vertex are `Empty`.
#[derive(Debug, Clone, PartialEq)]
pub enum SpNode {
    Empty,
    Vertex(TaskId),
    Series(Vec<SpNode>),
    Parallel(Vec<SpNode>),
}

impl SpNode {
    /// Number of `Vertex` leaves.
    pub fn num_vertices(&self) -> usize {
        match self {
            SpNode::Empty => 0,
            SpNode::Vertex(_) => 1,
            SpNode::Series(cs) | SpNode::Parallel(cs) => {
                cs.iter().map(SpNode::num_vertices).sum()
            }
        }
    }

    fn series(a: SpNode, v: TaskId, b: SpNode) -> SpNode {
        let mut parts = Vec::new();
        match a {
            SpNode::Empty => {}
            SpNode::Series(mut cs) => parts.append(&mut cs),
            other => parts.push(other),
        }
        parts.push(SpNode::Vertex(v));
        match b {
            SpNode::Empty => {}
            SpNode::Series(mut cs) => parts.append(&mut cs),
            other => parts.push(other),
        }
        if parts.len() == 1 {
            parts.pop().unwrap()
        } else {
            SpNode::Series(parts)
        }
    }

    fn parallel(a: SpNode, b: SpNode) -> SpNode {
        let mut parts = Vec::new();
        for x in [a, b] {
            match x {
                SpNode::Parallel(mut cs) => parts.append(&mut cs),
                other => parts.push(other),
            }
        }
        SpNode::Parallel(parts)
    }
}

/// A successful decomposition.
#[derive(Debug, Clone)]
pub struct SpTree {
    pub root: SpNode,
}

struct EdgeRec {
    from: usize,
    to: usize,
    node: SpNode,
    alive: bool,
}

/// Attempt the TTSP decomposition of `wf` (with virtual terminals).
/// Returns `None` if the graph is not series-parallel.
pub fn decompose(wf: &Workflow) -> Option<SpTree> {
    let n = wf.num_tasks();
    let src = n;
    let sink = n + 1;

    let mut edges: Vec<EdgeRec> = Vec::with_capacity(wf.num_edges() + 2 * n);
    // live edge per endpoint pair (the parallel-merge invariant).
    let mut by_pair: HashMap<(usize, usize), usize> = HashMap::new();
    let mut in_deg = vec![0usize; n + 2];
    let mut out_deg = vec![0usize; n + 2];
    // Incident live-edge lookup: for series reduction we need *the* single
    // in/out edge of a vertex; store per-vertex edge lists, lazily pruned.
    let mut in_edges: Vec<Vec<usize>> = vec![Vec::new(); n + 2];
    let mut out_edges: Vec<Vec<usize>> = vec![Vec::new(); n + 2];

    let add_edge = |edges: &mut Vec<EdgeRec>,
                        by_pair: &mut HashMap<(usize, usize), usize>,
                        in_deg: &mut Vec<usize>,
                        out_deg: &mut Vec<usize>,
                        in_edges: &mut Vec<Vec<usize>>,
                        out_edges: &mut Vec<Vec<usize>>,
                        from: usize,
                        to: usize,
                        node: SpNode|
     -> usize {
        if let Some(&eid) = by_pair.get(&(from, to)) {
            if edges[eid].alive {
                // Merge as parallel into the existing live edge.
                let old = std::mem::replace(&mut edges[eid].node, SpNode::Empty);
                edges[eid].node = SpNode::parallel(old, node);
                return eid;
            }
        }
        let eid = edges.len();
        edges.push(EdgeRec { from, to, node, alive: true });
        by_pair.insert((from, to), eid);
        in_deg[to] += 1;
        out_deg[from] += 1;
        in_edges[to].push(eid);
        out_edges[from].push(eid);
        eid
    };

    for e in wf.edges() {
        add_edge(
            &mut edges,
            &mut by_pair,
            &mut in_deg,
            &mut out_deg,
            &mut in_edges,
            &mut out_edges,
            e.src,
            e.dst,
            SpNode::Empty,
        );
    }
    for u in 0..n {
        if wf.in_degree(u) == 0 {
            add_edge(
                &mut edges,
                &mut by_pair,
                &mut in_deg,
                &mut out_deg,
                &mut in_edges,
                &mut out_edges,
                src,
                u,
                SpNode::Empty,
            );
        }
        if wf.out_degree(u) == 0 {
            add_edge(
                &mut edges,
                &mut by_pair,
                &mut in_deg,
                &mut out_deg,
                &mut in_edges,
                &mut out_edges,
                u,
                sink,
                SpNode::Empty,
            );
        }
    }

    // Worklist of vertices to try for series reduction.
    let mut work: Vec<usize> = (0..n).collect();
    let live_edge = |list: &mut Vec<usize>, edges: &[EdgeRec]| -> Option<usize> {
        list.retain(|&e| edges[e].alive);
        if list.len() == 1 {
            Some(list[0])
        } else {
            None
        }
    };

    while let Some(v) = work.pop() {
        if v >= n || in_deg[v] != 1 || out_deg[v] != 1 {
            continue;
        }
        let (Some(ein), Some(eout)) = (
            live_edge(&mut in_edges[v], &edges),
            live_edge(&mut out_edges[v], &edges),
        ) else {
            continue;
        };
        let u = edges[ein].from;
        let w = edges[eout].to;
        if u == w {
            // Would create a self-loop; only possible on non-DAG input.
            return None;
        }
        // Kill both edges.
        edges[ein].alive = false;
        edges[eout].alive = false;
        if by_pair.get(&(u, v)) == Some(&ein) {
            by_pair.remove(&(u, v));
        }
        if by_pair.get(&(v, w)) == Some(&eout) {
            by_pair.remove(&(v, w));
        }
        in_deg[v] = 0;
        out_deg[v] = 0;
        out_deg[u] -= 1;
        in_deg[w] -= 1;
        let a = std::mem::replace(&mut edges[ein].node, SpNode::Empty);
        let b = std::mem::replace(&mut edges[eout].node, SpNode::Empty);
        let merged = SpNode::series(a, v, b);
        let had_parallel = by_pair.contains_key(&(u, w))
            && edges[by_pair[&(u, w)]].alive;
        add_edge(
            &mut edges,
            &mut by_pair,
            &mut in_deg,
            &mut out_deg,
            &mut in_edges,
            &mut out_edges,
            u,
            w,
            merged,
        );
        if had_parallel {
            // Degrees shrank at u/w; they may now be series-reducible.
            work.push(u);
            work.push(w);
        }
        // u and w might have become reducible regardless (degree changed).
        work.push(u);
        work.push(w);
    }

    // TTSP iff exactly one live edge remains: src -> sink.
    let mut live = edges.iter().filter(|e| e.alive);
    let (first, second) = (live.next(), live.next());
    match (first, second) {
        (Some(e), None) if e.from == src && e.to == sink => {
            debug_assert_eq!(e.node.num_vertices(), n);
            Some(SpTree { root: e.node.clone() })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::WorkflowBuilder;

    fn wf(edges: &[(usize, usize)], n: usize) -> Workflow {
        let mut b = WorkflowBuilder::new("t");
        for i in 0..n {
            b.task(format!("t{i}"), "t", 1.0, 1.0);
        }
        for &(s, d) in edges {
            b.edge(s, d, 1.0);
        }
        b.build().unwrap()
    }

    #[test]
    fn single_task() {
        let tree = decompose(&wf(&[], 1)).unwrap();
        assert_eq!(tree.root, SpNode::Vertex(0));
    }

    #[test]
    fn chain_is_series() {
        let tree = decompose(&wf(&[(0, 1), (1, 2)], 3)).unwrap();
        assert_eq!(
            tree.root,
            SpNode::Series(vec![SpNode::Vertex(0), SpNode::Vertex(1), SpNode::Vertex(2)])
        );
    }

    #[test]
    fn diamond_is_sp() {
        let tree = decompose(&wf(&[(0, 1), (0, 2), (1, 3), (2, 3)], 4)).unwrap();
        assert_eq!(tree.root.num_vertices(), 4);
        // Root should be Series(0, Parallel(1, 2), 3).
        match &tree.root {
            SpNode::Series(cs) => {
                assert_eq!(cs.len(), 3);
                assert_eq!(cs[0], SpNode::Vertex(0));
                assert!(matches!(cs[1], SpNode::Parallel(_)));
                assert_eq!(cs[2], SpNode::Vertex(3));
            }
            other => panic!("unexpected root {other:?}"),
        }
    }

    #[test]
    fn independent_tasks_are_parallel() {
        let tree = decompose(&wf(&[], 3)).unwrap();
        match &tree.root {
            SpNode::Parallel(cs) => assert_eq!(cs.len(), 3),
            other => panic!("unexpected root {other:?}"),
        }
    }

    #[test]
    fn n_graph_is_not_sp() {
        // a->c, a->d, b->d: the classic non-SP "N".
        assert!(decompose(&wf(&[(0, 2), (0, 3), (1, 3)], 4)).is_none());
    }

    #[test]
    fn crossing_bipartite_not_sp() {
        // K_{2,2} minus nothing is SP (parallel of ...) — actually
        // 0->{2,3}, 1->{2,3} is NOT SP (it contains the N as a minor).
        assert!(decompose(&wf(&[(0, 2), (0, 3), (1, 2), (1, 3)], 4)).is_none());
    }

    #[test]
    fn nested_sp() {
        // 0 -> (1 -> (2 || 3) -> 4 || 5) -> 6
        let tree = decompose(&wf(
            &[(0, 1), (1, 2), (1, 3), (2, 4), (3, 4), (0, 5), (4, 6), (5, 6)],
            7,
        ))
        .unwrap();
        assert_eq!(tree.root.num_vertices(), 7);
    }

    #[test]
    fn all_generator_models_are_sp() {
        for model in crate::generator::models::all_models() {
            for samples in [1, 4, 9] {
                let wf = crate::generator::expand(&model, samples).unwrap();
                let tree = decompose(&wf);
                assert!(tree.is_some(), "{} samples={samples}", model.name);
                assert_eq!(tree.unwrap().root.num_vertices(), wf.num_tasks());
            }
        }
    }

    #[test]
    fn fan_out_fan_in_wide() {
        // Star: 0 -> 1..=20 -> 21.
        let mut edges = Vec::new();
        for i in 1..=20 {
            edges.push((0, i));
            edges.push((i, 21));
        }
        let tree = decompose(&wf(&edges, 22)).unwrap();
        assert_eq!(tree.root.num_vertices(), 22);
    }
}
