//! Aggregation helpers for the experimental evaluation (§VI): success
//! rates, relative makespans, and memory usage, grouped by workflow size
//! as in the paper's figures.

use crate::workflow::SizeGroup;
use std::collections::BTreeMap;

/// Accumulates (group, label) → values and reports means/rates.
#[derive(Debug, Default, Clone)]
pub struct GroupedStat {
    values: BTreeMap<(SizeGroup, String), Vec<f64>>,
}

impl GroupedStat {
    pub fn add(&mut self, group: SizeGroup, label: &str, value: f64) {
        self.values.entry((group, label.to_string())).or_default().push(value);
    }

    pub fn mean(&self, group: SizeGroup, label: &str) -> Option<f64> {
        let xs = self.values.get(&(group, label.to_string()))?;
        if xs.is_empty() {
            return None;
        }
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }

    pub fn count(&self, group: SizeGroup, label: &str) -> usize {
        self.values.get(&(group, label.to_string())).map_or(0, Vec::len)
    }

    /// All labels seen (sorted).
    pub fn labels(&self) -> Vec<String> {
        let mut l: Vec<String> = self.values.keys().map(|(_, s)| s.clone()).collect();
        l.sort();
        l.dedup();
        l
    }
}

/// Success-rate tracker: (group, label) → (successes, total).
#[derive(Debug, Default, Clone)]
pub struct SuccessRate {
    counts: BTreeMap<(SizeGroup, String), (usize, usize)>,
}

impl SuccessRate {
    pub fn add(&mut self, group: SizeGroup, label: &str, success: bool) {
        let e = self.counts.entry((group, label.to_string())).or_insert((0, 0));
        e.1 += 1;
        if success {
            e.0 += 1;
        }
    }

    /// Success rate in percent; None if no samples.
    pub fn rate(&self, group: SizeGroup, label: &str) -> Option<f64> {
        let &(s, t) = self.counts.get(&(group, label.to_string()))?;
        if t == 0 {
            return None;
        }
        Some(100.0 * s as f64 / t as f64)
    }

    /// Overall success rate across all groups for a label, in percent.
    pub fn overall(&self, label: &str) -> Option<f64> {
        let (mut s, mut t) = (0usize, 0usize);
        for ((_, l), &(cs, ct)) in &self.counts {
            if l == label {
                s += cs;
                t += ct;
            }
        }
        if t == 0 {
            None
        } else {
            Some(100.0 * s as f64 / t as f64)
        }
    }

    pub fn totals(&self, label: &str) -> (usize, usize) {
        let (mut s, mut t) = (0usize, 0usize);
        for ((_, l), &(cs, ct)) in &self.counts {
            if l == label {
                s += cs;
                t += ct;
            }
        }
        (s, t)
    }
}

/// Format an optional mean/rate for a report cell.
pub fn cell(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.1}"),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_means() {
        let mut g = GroupedStat::default();
        g.add(SizeGroup::Tiny, "a", 1.0);
        g.add(SizeGroup::Tiny, "a", 3.0);
        g.add(SizeGroup::Big, "a", 10.0);
        assert_eq!(g.mean(SizeGroup::Tiny, "a"), Some(2.0));
        assert_eq!(g.mean(SizeGroup::Big, "a"), Some(10.0));
        assert_eq!(g.mean(SizeGroup::Small, "a"), None);
        assert_eq!(g.count(SizeGroup::Tiny, "a"), 2);
        assert_eq!(g.labels(), vec!["a".to_string()]);
    }

    #[test]
    fn success_rates() {
        let mut s = SuccessRate::default();
        s.add(SizeGroup::Tiny, "heft", true);
        s.add(SizeGroup::Tiny, "heft", false);
        s.add(SizeGroup::Small, "heft", false);
        assert_eq!(s.rate(SizeGroup::Tiny, "heft"), Some(50.0));
        assert_eq!(s.rate(SizeGroup::Small, "heft"), Some(0.0));
        assert!((s.overall("heft").unwrap() - 33.3).abs() < 0.1);
        assert_eq!(s.totals("heft"), (1, 3));
        assert_eq!(s.rate(SizeGroup::Big, "heft"), None);
    }

    #[test]
    fn cell_formatting() {
        assert_eq!(cell(Some(12.34)), "12.3");
        assert_eq!(cell(None), "-");
    }
}
