//! Chrome trace-event rendering (`memsched trace`).
//!
//! Maps drained simulator events onto the Chrome/Perfetto trace-event
//! JSON format (load the output in `ui.perfetto.dev` or
//! `chrome://tracing`):
//!
//! - one **process track per processor** (`pid` = processor id, named
//!   via `process_name` metadata): each simulated task execution is a
//!   complete (`ph:"X"`) slice from its actual start for its actual
//!   duration;
//! - a **memory-waterline counter track** per processor (`ph:"C"`,
//!   name `memory`): resident bytes after every residency change;
//! - recomputations as global instant events (`ph:"i"`, scope `g`).
//!
//! Timestamps are the *simulated* clock converted to microseconds (the
//! trace format's native unit), so slice lengths are simulated task
//! durations, not host wall time.

use super::event::Event;
use super::sink::Rec;
use crate::ser::json::{obj, Value};

/// Simulated seconds → trace microseconds.
fn us(t: f64) -> Value {
    Value::Number(t * 1e6)
}

/// Render drained records as one Chrome trace-event JSON document.
/// Non-simulator records are ignored — the caller typically enables
/// tracing around exactly one simulation.
pub fn render(recs: &[Rec]) -> Value {
    // (ts, rendered event): record order is event-loop order, but a task's
    // actual start can exceed the loop time that scheduled it (input
    // arrival), so a stable ts sort is needed for a monotone timeline.
    let mut timeline: Vec<(f64, Value)> = Vec::new();
    let mut procs: Vec<u32> = Vec::new();
    let mut seen_proc = |p: u32, procs: &mut Vec<u32>| {
        if !procs.contains(&p) {
            procs.push(p);
        }
    };
    for r in recs {
        match r.ev {
            Event::TaskStart { task, proc, t, dur } => {
                seen_proc(proc, &mut procs);
                timeline.push((t, obj(vec![
                    ("name", format!("task {task}").into()),
                    ("cat", "task".into()),
                    ("ph", "X".into()),
                    ("ts", us(t)),
                    ("dur", us(dur)),
                    ("pid", proc.into()),
                    ("tid", 0u64.into()),
                    ("args", obj(vec![("task", task.into())])),
                ])));
            }
            Event::TaskFinish { .. } => {
                // The start slice already carries the duration; finishes
                // exist for metrics/counters, not the timeline.
            }
            Event::MemLevel { proc, t, used } => {
                seen_proc(proc, &mut procs);
                timeline.push((t, obj(vec![
                    ("name", "memory".into()),
                    ("ph", "C".into()),
                    ("ts", us(t)),
                    ("pid", proc.into()),
                    ("args", obj(vec![("used_bytes", used.into())])),
                ])));
            }
            Event::RecomputeTriggered { t } => {
                timeline.push((t, obj(vec![
                    ("name", "recompute".into()),
                    ("cat", "scheduler".into()),
                    ("ph", "i".into()),
                    ("ts", us(t)),
                    ("pid", 0u64.into()),
                    ("tid", 0u64.into()),
                    ("s", "g".into()),
                ])));
            }
            _ => {}
        }
    }
    // Stable: equal timestamps keep record (event-loop) order.
    timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut events: Vec<Value> = timeline.into_iter().map(|(_, v)| v).collect();
    // Metadata after the fact (ts-less; viewers accept any position, and
    // keeping the event list itself ts-ordered simplifies validation).
    procs.sort_unstable();
    for p in &procs {
        events.push(obj(vec![
            ("name", "process_name".into()),
            ("ph", "M".into()),
            ("pid", (*p).into()),
            ("args", obj(vec![("name", format!("proc {p}").into())])),
        ]));
    }
    obj(vec![
        ("traceEvents", Value::Array(events)),
        ("displayTimeUnit", "ms".into()),
    ])
}

/// Validate a (re-parsed) trace document: `traceEvents` exists, every
/// named processor track carries at least one task slice, and the
/// timestamps of timeline events are monotone non-decreasing in emission
/// order. Backs `memsched trace --check` (and through it the CI smoke).
pub fn validate(trace: &Value) -> Result<(), String> {
    let events = match trace.get("traceEvents") {
        Some(Value::Array(evs)) => evs,
        _ => return Err("missing traceEvents array".into()),
    };
    let field_f64 = |v: &Value, key: &str| -> Option<f64> {
        match v.get(key) {
            Some(Value::Number(n)) => Some(*n),
            _ => None,
        }
    };
    let field_str = |v: &Value, key: &str| -> Option<String> {
        match v.get(key) {
            Some(Value::String(s)) => Some(s.clone()),
            _ => None,
        }
    };
    let mut named_procs: Vec<i64> = Vec::new();
    let mut sliced_procs: Vec<i64> = Vec::new();
    let mut last_ts = f64::NEG_INFINITY;
    let mut slices = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = field_str(ev, "ph").ok_or_else(|| format!("event {i}: missing ph"))?;
        let pid = field_f64(ev, "pid").ok_or_else(|| format!("event {i}: missing pid"))? as i64;
        if ph == "M" {
            named_procs.push(pid);
            continue;
        }
        let ts = field_f64(ev, "ts").ok_or_else(|| format!("event {i}: missing ts"))?;
        if ts < last_ts {
            return Err(format!("event {i}: ts {ts} < previous {last_ts} (not monotone)"));
        }
        last_ts = ts;
        if ph == "X" {
            slices += 1;
            if field_f64(ev, "dur").is_none_or(|d| d < 0.0) {
                return Err(format!("event {i}: X slice without a non-negative dur"));
            }
            if !sliced_procs.contains(&pid) {
                sliced_procs.push(pid);
            }
        }
    }
    if slices == 0 {
        return Err("no task slices in the trace".into());
    }
    for p in &named_procs {
        if !sliced_procs.contains(p) {
            return Err(format!("processor track pid={p} has no task slice"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::Event;

    fn rec(seq: u64, ev: Event) -> Rec {
        Rec { seq, tid: 0, wall_us: seq, ev }
    }

    #[test]
    fn render_round_trips_and_validates() {
        let recs = vec![
            rec(0, Event::TaskStart { task: 0, proc: 0, t: 0.0, dur: 1.5 }),
            rec(1, Event::MemLevel { proc: 0, t: 0.0, used: 64.0 }),
            rec(2, Event::RecomputeTriggered { t: 0.5 }),
            rec(3, Event::TaskStart { task: 1, proc: 1, t: 1.5, dur: 2.0 }),
            rec(4, Event::MemLevel { proc: 1, t: 1.5, used: 32.0 }),
            rec(5, Event::TaskFinish { task: 1, proc: 1, t: 3.5 }),
        ];
        let trace = render(&recs);
        let text = trace.to_string_compact();
        let parsed = Value::parse(&text).expect("rendered trace must re-parse");
        validate(&parsed).expect("rendered trace must validate");
        assert!(text.contains("\"ph\":\"X\""), "{text}");
        assert!(text.contains("\"ph\":\"C\""), "{text}");
        assert!(text.contains("\"process_name\""), "{text}");
    }

    #[test]
    fn render_sorts_out_of_order_starts() {
        // Simulator record order is event-loop order, not start order: a
        // task can start later than the loop time that scheduled it. The
        // rendered timeline must still be ts-monotone.
        let recs = vec![
            rec(0, Event::TaskStart { task: 0, proc: 0, t: 2.0, dur: 1.0 }),
            rec(1, Event::TaskStart { task: 1, proc: 0, t: 1.0, dur: 1.0 }),
        ];
        validate(&render(&recs)).expect("render must sort the timeline");
    }

    #[test]
    fn validate_rejects_non_monotone_and_empty_tracks() {
        // A hand-built trace with descending timestamps (render() sorts,
        // so a malformed document has to be constructed directly).
        let slice = |task: u64, ts: f64| {
            obj(vec![
                ("name", format!("task {task}").into()),
                ("ph", "X".into()),
                ("ts", Value::Number(ts)),
                ("dur", Value::Number(1.0)),
                ("pid", 0u64.into()),
            ])
        };
        let bad = obj(vec![(
            "traceEvents",
            Value::Array(vec![slice(0, 2e6), slice(1, 1e6)]),
        )]);
        assert!(validate(&bad).unwrap_err().contains("monotone"));
        assert!(validate(&Value::Null).is_err());
        // A processor named by metadata but carrying only counter events
        // fails the ≥1-slice-per-track requirement.
        let sliceless = vec![
            rec(0, Event::TaskStart { task: 0, proc: 0, t: 0.0, dur: 1.0 }),
            rec(1, Event::MemLevel { proc: 1, t: 0.5, used: 8.0 }),
        ];
        assert!(validate(&render(&sliceless)).unwrap_err().contains("no task slice"));
    }
}
