//! Typed observability events.
//!
//! Every variant is `Copy` with no heap payload: events are written into
//! fixed-capacity per-thread rings from hot loops, so constructing one
//! must never allocate. Identifiers are the scheduler's/simulator's own
//! `usize` indices narrowed to `u32`; simulated timestamps are `f64`
//! seconds (the simulator's clock); wall-clock quantities are integer
//! microseconds since the process-wide epoch ([`super::sink::wall_us`]).

/// What a wall-clock timing span measured. [`name`](SpanKind::name) is
/// the stable string used in metrics output — treat renames as schema
/// changes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// One schedule computation inside the cache's compute closure.
    ScheduleCompute,
    /// Service phase 1: materialize workflows + fingerprints.
    Materialize,
    /// Service phases 2–4: group, execute on the pool, drain in order.
    Stream,
    /// One unique job's execution (schedule lookup + optional sim).
    Execute,
    /// One replay point through the thread-local `SimRun` arena.
    Simulate,
    /// One `pool::run_ordered` job on a worker (worker utilization: the
    /// per-`tid` share of total span time is that worker's busy time).
    WorkerJob,
    /// One dispatched queue item in the serve daemon.
    Dispatch,
    /// One mid-run schedule recomputation (`SimRun::recompute`): platform
    /// snapshot + engine resume + queue rebuild.
    Recompute,
}

impl SpanKind {
    /// Every kind, in the stable order metrics records are emitted in.
    pub const ALL: [SpanKind; 8] = [
        SpanKind::ScheduleCompute,
        SpanKind::Materialize,
        SpanKind::Stream,
        SpanKind::Execute,
        SpanKind::Simulate,
        SpanKind::WorkerJob,
        SpanKind::Dispatch,
        SpanKind::Recompute,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::ScheduleCompute => "schedule_compute",
            SpanKind::Materialize => "materialize",
            SpanKind::Stream => "stream",
            SpanKind::Execute => "execute",
            SpanKind::Simulate => "simulate",
            SpanKind::WorkerJob => "worker_job",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Recompute => "recompute",
        }
    }
}

/// One recorded observation. Scheduler/service events carry wall-clock
/// context via their [`Rec`](super::sink::Rec) wrapper; simulator events
/// (`TaskStart`/`TaskFinish`/`MemLevel`/`RecomputeTriggered`) carry the
/// *simulated* clock `t` in their payload — those are what the Chrome
/// trace renders.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A schedule computation began (cache miss on both layers).
    ScheduleStart { tasks: u32 },
    /// The computation finished after `micros` of wall time.
    ScheduleEnd { tasks: u32, micros: u64 },
    /// The engine chose processor `proc` for task `task` (recorded once
    /// per assignment, at the winning tentative — not per candidate).
    TaskScored { task: u32, proc: u32 },
    /// Committing `task` on `proc` evicted file (edge) `edge` into the
    /// communication buffer.
    EvictionChosen { task: u32, proc: u32, edge: u32 },
    /// The simulated runtime warned the scheduler and a recomputation of
    /// all unstarted placements ran at simulated time `t`.
    RecomputeTriggered { t: f64 },
    /// Schedule served from the in-memory cache layer.
    CacheHitMem,
    /// Schedule loaded from the disk cache layer (`--cache-dir`).
    CacheHitDisk,
    /// A `SimScaffold` was constructed (one per sweep / plain sim job).
    ScaffoldBuilt { tasks: u32 },
    /// One replay point executed on a `SimRun` arena.
    PointReplayed,
    /// A portfolio job committed the candidate at index `algo` of
    /// `Algorithm::all()` (after σ=0 replay-scoring every candidate).
    PortfolioCommitted { algo: u32 },
    /// The serve daemon admitted a job frame into client `client`'s queue.
    FrameAdmitted { client: u32 },
    /// The daemon rejected a frame (backpressure or shutdown).
    FrameRejected { client: u32 },
    /// The fair-share dispatcher picked client `client`'s queue head.
    DispatchPick { client: u32 },
    /// Simulated execution of `task` started on `proc` at sim time `t`
    /// and will run for `dur` (actual, post-deviation duration).
    TaskStart { task: u32, proc: u32, t: f64, dur: f64 },
    /// Simulated execution of `task` finished on `proc` at sim time `t`.
    TaskFinish { task: u32, proc: u32, t: f64 },
    /// Memory waterline: `used` bytes resident on `proc` at sim time `t`
    /// (capacity minus available; recorded after each residency change).
    MemLevel { proc: u32, t: f64, used: f64 },
    /// A completed timing span (recorded at guard drop; `start_us` +
    /// `dur_us` nest naturally on the wall-clock timeline).
    Span { kind: SpanKind, start_us: u64, dur_us: u64 },
}

impl Event {
    /// Stable snake_case key for counter aggregation (`None` for spans,
    /// which aggregate into histograms instead).
    pub fn counter_key(&self) -> Option<&'static str> {
        Some(match self {
            Event::ScheduleStart { .. } => "schedule_starts",
            Event::ScheduleEnd { .. } => "schedule_ends",
            Event::TaskScored { .. } => "tasks_scored",
            Event::EvictionChosen { .. } => "evictions_chosen",
            Event::RecomputeTriggered { .. } => "recomputes_triggered",
            Event::CacheHitMem => "cache_hits_mem",
            Event::CacheHitDisk => "cache_hits_disk",
            Event::ScaffoldBuilt { .. } => "scaffolds_built",
            Event::PointReplayed => "points_replayed",
            Event::PortfolioCommitted { .. } => "portfolio_commits",
            Event::FrameAdmitted { .. } => "frames_admitted",
            Event::FrameRejected { .. } => "frames_rejected",
            Event::DispatchPick { .. } => "dispatch_picks",
            Event::TaskStart { .. } => "sim_task_starts",
            Event::TaskFinish { .. } => "sim_task_finishes",
            Event::MemLevel { .. } => "sim_mem_levels",
            Event::Span { .. } => return None,
        })
    }
}
