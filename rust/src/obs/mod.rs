//! Crate-wide observability: typed events, timing spans, per-thread
//! ring-buffer recording, and exporters.
//!
//! The subsystem is a **side channel**: it never touches result bytes.
//! Every JSONL result stream the crate produces is byte-identical with
//! tracing on or off (asserted by the determinism integration tests);
//! traces, metrics and counters flow only to stderr summaries, the
//! `--metrics-json` file, the `memsched trace` output, and the serve
//! daemon's `{"ctl":"stats"}` reply.
//!
//! Layout:
//!
//! - [`event`] — the [`Event`] taxonomy and [`SpanKind`]s; all `Copy`,
//!   no heap payloads.
//! - [`sink`] — the process-global enable flag, per-thread rings,
//!   [`drain`], the [`Counters`] summary object, and
//!   [`metrics_records`] aggregation.
//! - [`span`] — the [`Span`] drop-guard timer.
//! - [`chrome`] — Chrome/Perfetto trace-event rendering + validation
//!   for `memsched trace`.
//!
//! Hot-path contract: call sites are written
//! `if obs::enabled() { obs::record(...) }` so the disabled path is a
//! single relaxed load and a branch — no event is even constructed.

pub mod chrome;
pub mod event;
pub mod sink;
pub mod span;

pub use event::{Event, SpanKind};
pub use sink::{
    drain, dropped, enabled, metrics_records, record, set_enabled, wall_us, Counters, Rec,
    SCHEMA_VERSION,
};
pub use span::{span, Span};
