//! The event sink: a process-global enable flag, per-thread ring
//! buffers, and drain/aggregation.
//!
//! **Zero-cost-when-disabled contract.** [`enabled`] is a single relaxed
//! atomic load; every record site in the crate is written
//! `if obs::enabled() { obs::record(...) }`, so with tracing off the hot
//! paths (engine scoring loop, `SimRun` replay loop) execute a couple of
//! branch instructions and allocate nothing — pinned by the arena
//! pointer-stability and determinism-under-tracing tests.
//!
//! **Recording** is lock-cheap, not lock-free: each thread owns one
//! fixed-capacity `Vec<Rec>` behind a `Mutex` that only [`drain`] ever
//! contends on (an uncontended lock is a few atomic ops). A global
//! sequence counter orders records across threads; rings that fill up
//! drop further records (counted in [`dropped`]) rather than growing or
//! blocking.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::event::{Event, SpanKind};
use crate::ser::json::{obj, Value};

/// Schema version of every metrics record ([`metrics_records`]) and of
/// the summary records built around [`Counters`]. Bump on any field
/// rename/reorder; external tooling keys off it.
///
/// v2: `counters` gained `portfolio_commits`; result rows gained
/// `lower_bound` / `optimality_gap` (and `portfolio` on portfolio jobs).
///
/// v3: `counters` gained `replays_pruned` (portfolio replays skipped by
/// the analytic-bound prune); span records may carry the new `recompute`
/// kind (mid-run rescheduling latency); portfolio candidate rows gained
/// `pruned`.
pub const SCHEMA_VERSION: u64 = 3;

/// Per-thread ring capacity (records). A smoke-scale trace is a few
/// thousand records; production sweeps that overflow this drop the
/// excess (counted) instead of growing without bound.
const RING_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SEQ: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

/// Whether event recording is on. Relaxed load — the only thing hot
/// paths pay when tracing is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip event recording (process-global). `memsched trace` and
/// `--metrics-json` turn it on; it is off by default.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process-wide tracing epoch (first use).
pub fn wall_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// One recorded event with its cross-thread ordering context.
#[derive(Clone, Copy, Debug)]
pub struct Rec {
    /// Global sequence number: drain order across all threads.
    pub seq: u64,
    /// Small dense id of the recording thread (assignment order).
    pub tid: u32,
    /// Wall-clock record time ([`wall_us`]).
    pub wall_us: u64,
    pub ev: Event,
}

type Ring = Arc<Mutex<Vec<Rec>>>;

fn registry() -> &'static Mutex<Vec<Ring>> {
    static REGISTRY: Mutex<Vec<Ring>> = Mutex::new(Vec::new());
    &REGISTRY
}

thread_local! {
    static LOCAL: (Ring, u32) = {
        let ring: Ring = Arc::new(Mutex::new(Vec::with_capacity(RING_CAPACITY)));
        registry().lock().unwrap().push(ring.clone());
        (ring, NEXT_TID.fetch_add(1, Ordering::Relaxed))
    };
}

/// Record one event into this thread's ring. Callers on hot paths guard
/// with [`enabled`] *before* constructing the event; the internal check
/// here only covers stragglers racing a [`set_enabled`]`(false)`.
#[inline]
pub fn record(ev: Event) {
    if !enabled() {
        return;
    }
    record_always(ev);
}

#[cold]
fn record_always(ev: Event) {
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let wall = wall_us();
    // `try_with`: a TLS key is inaccessible during thread teardown, and
    // observability must never take the process down — drop the record.
    let stored = LOCAL.try_with(|(ring, tid)| {
        let mut g = ring.lock().unwrap();
        if g.len() < RING_CAPACITY {
            g.push(Rec { seq, tid: *tid, wall_us: wall, ev });
            true
        } else {
            false
        }
    });
    if !stored.unwrap_or(false) {
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Take every buffered record from every thread's ring, ordered by the
/// global sequence number. Rings are emptied (their capacity is kept);
/// recording may continue concurrently — records racing the drain land
/// in the next one.
pub fn drain() -> Vec<Rec> {
    let rings: Vec<Ring> = registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in rings {
        out.append(&mut ring.lock().unwrap());
    }
    out.sort_unstable_by_key(|r| r.seq);
    out
}

/// Records dropped on full rings since the last call (resets to 0).
pub fn dropped() -> u64 {
    DROPPED.swap(0, Ordering::Relaxed)
}

/// The canonical counter sub-object of the run summaries: one stable
/// name and nesting for the reuse counters that batch and serve records
/// previously reported with drifting shapes. Filled by the service from
/// its cache statistics — the counters are *always* present in
/// summaries, whether or not event tracing is enabled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// Schedule lookups (one per prepared unique job + deduped jobs).
    pub schedule_requests: u64,
    /// Schedules actually computed (miss on every cache layer).
    pub schedules_computed: u64,
    /// Requests satisfied without computing (memory hits, batch dedupe,
    /// disk loads together).
    pub schedule_reuse_hits: u64,
    /// Schedules loaded from the disk layer (`--cache-dir`).
    pub disk_hits: u64,
    /// `SimScaffold`s constructed (one per sweep that simulates, plus
    /// one per portfolio candidate replay).
    pub scaffolds_built: u64,
    /// Portfolio decisions committed (`--algo portfolio` jobs executed).
    pub portfolio_commits: u64,
    /// Portfolio candidate replays skipped because the candidate's
    /// analytic makespan already exceeded the incumbent's simulated one.
    pub replays_pruned: u64,
}

impl Counters {
    /// The `counters` object, fields in declaration order (stable —
    /// part of the versioned summary schema).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("schedule_requests", self.schedule_requests.into()),
            ("schedules_computed", self.schedules_computed.into()),
            ("schedule_reuse_hits", self.schedule_reuse_hits.into()),
            ("disk_hits", self.disk_hits.into()),
            ("scaffolds_built", self.scaffolds_built.into()),
            ("portfolio_commits", self.portfolio_commits.into()),
            ("replays_pruned", self.replays_pruned.into()),
        ])
    }
}

/// Aggregate drained records into versioned metrics JSONL values: one
/// `kind:"counters"` record (event counts by stable key, plus records
/// dropped on full rings), then one `kind:"span"` record per span kind
/// observed, in [`SpanKind::ALL`] order, each a duration histogram
/// summary in microseconds.
pub fn metrics_records(recs: &[Rec]) -> Vec<Value> {
    use std::collections::BTreeMap;
    let mut counts: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut spans: BTreeMap<SpanKind, Vec<u64>> = BTreeMap::new();
    for r in recs {
        match r.ev {
            Event::Span { kind, dur_us, .. } => spans.entry(kind).or_default().push(dur_us),
            ev => {
                if let Some(key) = ev.counter_key() {
                    *counts.entry(key).or_insert(0) += 1;
                }
            }
        }
    }
    let mut out = Vec::with_capacity(1 + spans.len());
    let count_fields: Vec<(&str, Value)> =
        counts.into_iter().map(|(k, v)| (k, v.into())).collect();
    out.push(obj(vec![
        ("schema", SCHEMA_VERSION.into()),
        ("kind", "counters".into()),
        ("events", recs.len().into()),
        ("events_dropped", dropped().into()),
        ("counts", obj(count_fields)),
    ]));
    for kind in SpanKind::ALL {
        let Some(mut durs) = spans.remove(&kind) else { continue };
        durs.sort_unstable();
        let total: u64 = durs.iter().sum();
        let pct = |p: f64| -> u64 {
            let idx = ((durs.len() - 1) as f64 * p).round() as usize;
            durs[idx]
        };
        out.push(obj(vec![
            ("schema", SCHEMA_VERSION.into()),
            ("kind", "span".into()),
            ("name", kind.name().into()),
            ("count", durs.len().into()),
            ("total_us", total.into()),
            ("min_us", durs[0].into()),
            ("p50_us", pct(0.5).into()),
            ("p90_us", pct(0.9).into()),
            ("max_us", (*durs.last().unwrap()).into()),
        ]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enable flag and the rings are process-global; tests that flip
    /// or drain them must not interleave (the test harness runs threads).
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = test_lock();
        set_enabled(false);
        record(Event::PointReplayed);
        assert!(!enabled());
    }

    #[test]
    fn drain_orders_across_threads_and_aggregates() {
        let _g = test_lock();
        set_enabled(true);
        let h = std::thread::spawn(|| {
            for _ in 0..5 {
                record(Event::CacheHitMem);
            }
        });
        for _ in 0..5 {
            record(Event::CacheHitDisk);
        }
        record(Event::Span { kind: SpanKind::Execute, start_us: 1, dur_us: 10 });
        record(Event::Span { kind: SpanKind::Execute, start_us: 2, dur_us: 30 });
        h.join().unwrap();
        set_enabled(false);
        let recs = drain();
        assert!(recs.windows(2).all(|w| w[0].seq < w[1].seq), "drain must be seq-ordered");
        let metrics = metrics_records(&recs);
        let line = metrics[0].to_string_compact();
        assert!(line.contains("\"kind\":\"counters\""), "{line}");
        assert!(line.contains("\"cache_hits_mem\":"), "{line}");
        assert!(line.contains("\"cache_hits_disk\":"), "{line}");
        let span_line = metrics
            .iter()
            .map(Value::to_string_compact)
            .find(|l| l.contains("\"name\":\"execute\""))
            .expect("execute span record");
        assert!(span_line.contains("\"schema\":3"), "{span_line}");
        assert!(span_line.contains("\"min_us\":10"), "{span_line}");
        assert!(span_line.contains("\"max_us\":30"), "{span_line}");
    }

    #[test]
    fn counters_object_has_stable_field_order() {
        let c = Counters {
            schedule_requests: 9,
            schedules_computed: 3,
            schedule_reuse_hits: 6,
            disk_hits: 2,
            scaffolds_built: 1,
            portfolio_commits: 4,
            replays_pruned: 5,
        };
        assert_eq!(
            c.to_json().to_string_compact(),
            "{\"schedule_requests\":9,\"schedules_computed\":3,\
             \"schedule_reuse_hits\":6,\"disk_hits\":2,\"scaffolds_built\":1,\
             \"portfolio_commits\":4,\"replays_pruned\":5}"
        );
    }
}
