//! Drop-guard timing spans.
//!
//! [`span`] returns a guard that records one [`Event::Span`] when it
//! drops; nesting guards on one thread nests the recorded intervals on
//! the wall-clock timeline. With tracing disabled the guard is inert —
//! no clock read, no event, nothing allocated.

use super::event::{Event, SpanKind};
use super::sink::{enabled, record, wall_us};

/// An in-flight timing span; the measurement is recorded on drop.
#[must_use = "a span guard measures until it drops — bind it to a variable"]
#[derive(Debug)]
pub struct Span {
    kind: SpanKind,
    start_us: u64,
    armed: bool,
}

/// Start timing `kind`. Returns an inert guard when tracing is disabled
/// (the disabled path is one relaxed load and a struct literal).
#[inline]
pub fn span(kind: SpanKind) -> Span {
    if enabled() {
        Span { kind, start_us: wall_us(), armed: true }
    } else {
        Span { kind, start_us: 0, armed: false }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        // `enabled` re-checked so spans crossing a set_enabled(false)
        // don't record into a drained world.
        if self.armed && enabled() {
            let dur_us = wall_us().saturating_sub(self.start_us);
            record(Event::Span { kind: self.kind, start_us: self.start_us, dur_us });
        }
    }
}
