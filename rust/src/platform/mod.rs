//! Heterogeneous target platform (paper §III-B, Table II).
//!
//! A [`Cluster`] is a set of processors, each with an individual speed
//! `s_j`, memory `M_j`, and communication buffer `MC_j`; all pairs are
//! connected with a uniform bandwidth `β`. The two paper configurations
//! (default and memory-constrained) are provided as presets.

pub mod presets;

use crate::ser::json::{obj, Value};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Index of a processor within its [`Cluster`].
pub type ProcId = usize;

/// One processor `p_j`.
#[derive(Debug, Clone, PartialEq)]
pub struct Processor {
    /// Human-readable name, e.g. `C2-03`.
    pub name: String,
    /// Machine kind (Table II row), e.g. `C2`.
    pub kind: String,
    /// Speed `s_j` in normalized operations per second (Table II: GHz).
    pub speed: f64,
    /// Memory size `M_j` in bytes.
    pub memory: f64,
    /// Communication buffer size `MC_j` in bytes.
    pub comm_buffer: f64,
}

/// A heterogeneous cluster `S` with `k` processors and uniform bandwidth β.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    pub name: String,
    pub processors: Vec<Processor>,
    /// Interconnect bandwidth β in bytes per second.
    pub bandwidth: f64,
}

impl Cluster {
    /// Validate invariants (non-empty, positive speeds/memories/bandwidth).
    pub fn validate(&self) -> Result<()> {
        if self.processors.is_empty() {
            bail!("cluster `{}` has no processors", self.name);
        }
        if !(self.bandwidth.is_finite() && self.bandwidth > 0.0) {
            bail!("cluster `{}` has invalid bandwidth {}", self.name, self.bandwidth);
        }
        for p in &self.processors {
            if !(p.speed.is_finite() && p.speed > 0.0) {
                bail!("processor `{}` has invalid speed {}", p.name, p.speed);
            }
            if !(p.memory.is_finite() && p.memory > 0.0) {
                bail!("processor `{}` has invalid memory {}", p.name, p.memory);
            }
            if !(p.comm_buffer.is_finite() && p.comm_buffer >= 0.0) {
                bail!("processor `{}` has invalid comm buffer {}", p.name, p.comm_buffer);
            }
        }
        Ok(())
    }

    /// Number of processors `k`.
    pub fn len(&self) -> usize {
        self.processors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.processors.is_empty()
    }

    pub fn proc(&self, j: ProcId) -> &Processor {
        &self.processors[j]
    }

    /// Execution time of `work` operations on processor `j`.
    pub fn exec_time(&self, work: f64, j: ProcId) -> f64 {
        work / self.processors[j].speed
    }

    /// Transfer time of `data` bytes between two distinct processors.
    /// Same-processor transfers are free.
    pub fn comm_time(&self, data: f64, from: ProcId, to: ProcId) -> f64 {
        if from == to {
            0.0
        } else {
            data / self.bandwidth
        }
    }

    /// Largest processor memory (used for schedulability screening).
    pub fn max_memory(&self) -> f64 {
        self.processors.iter().map(|p| p.memory).fold(0.0, f64::max)
    }

    /// Mean processor speed (used by rank computations that average costs).
    pub fn mean_speed(&self) -> f64 {
        self.processors.iter().map(|p| p.speed).sum::<f64>() / self.len() as f64
    }

    /// Derive a memory-scaled variant: memories (and buffers) ×`factor`.
    /// The paper's memory-constrained cluster uses `factor = 0.1`.
    pub fn scale_memory(&self, factor: f64, name: &str) -> Cluster {
        let mut c = self.clone();
        c.name = name.to_string();
        for p in &mut c.processors {
            p.memory *= factor;
            p.comm_buffer *= factor;
        }
        c
    }

    pub fn to_json(&self) -> Value {
        let procs: Vec<Value> = self
            .processors
            .iter()
            .map(|p| {
                obj(vec![
                    ("name", p.name.as_str().into()),
                    ("kind", p.kind.as_str().into()),
                    ("speed", p.speed.into()),
                    ("memory", p.memory.into()),
                    ("comm_buffer", p.comm_buffer.into()),
                ])
            })
            .collect();
        obj(vec![
            ("name", self.name.as_str().into()),
            ("bandwidth", self.bandwidth.into()),
            ("processors", Value::Array(procs)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Cluster> {
        let name = v.req_str("name")?.to_string();
        let bandwidth = v.req_f64("bandwidth")?;
        let mut processors = Vec::new();
        for (i, p) in v.req_array("processors")?.iter().enumerate() {
            let pname = p.req_str("name").with_context(|| format!("processor #{i}"))?;
            processors.push(Processor {
                name: pname.to_string(),
                kind: p.get("kind").and_then(Value::as_str).unwrap_or(pname).to_string(),
                speed: p.req_f64("speed")?,
                memory: p.req_f64("memory")?,
                comm_buffer: p.req_f64("comm_buffer")?,
            });
        }
        let c = Cluster { name, processors, bandwidth };
        c.validate()?;
        Ok(c)
    }

    /// Load a cluster from a JSON file or a preset name
    /// (`default`, `memory-constrained`).
    pub fn load(spec: &str) -> Result<Cluster> {
        match spec {
            "default" => Ok(presets::default_cluster()),
            "memory-constrained" | "constrained" => Ok(presets::memory_constrained_cluster()),
            path => {
                let text = std::fs::read_to_string(Path::new(path))
                    .with_context(|| format!("reading cluster file {path}"))?;
                let v = Value::parse(&text)
                    .map_err(|e| anyhow::anyhow!("parsing cluster file {path}: {e}"))?;
                Cluster::from_json(&v)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cluster {
        Cluster {
            name: "tiny".into(),
            processors: vec![
                Processor {
                    name: "p0".into(),
                    kind: "A".into(),
                    speed: 2.0,
                    memory: 100.0,
                    comm_buffer: 1000.0,
                },
                Processor {
                    name: "p1".into(),
                    kind: "B".into(),
                    speed: 4.0,
                    memory: 50.0,
                    comm_buffer: 500.0,
                },
            ],
            bandwidth: 10.0,
        }
    }

    #[test]
    fn exec_and_comm_times() {
        let c = tiny();
        assert_eq!(c.exec_time(8.0, 0), 4.0);
        assert_eq!(c.exec_time(8.0, 1), 2.0);
        assert_eq!(c.comm_time(20.0, 0, 1), 2.0);
        assert_eq!(c.comm_time(20.0, 1, 1), 0.0);
    }

    #[test]
    fn memory_scaling() {
        let c = tiny().scale_memory(0.1, "scaled");
        assert_eq!(c.name, "scaled");
        assert_eq!(c.proc(0).memory, 10.0);
        assert_eq!(c.proc(0).comm_buffer, 100.0);
        assert_eq!(c.proc(0).speed, 2.0); // speeds unchanged
    }

    #[test]
    fn validation_rejects_bad_clusters() {
        let mut c = tiny();
        c.processors.clear();
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.bandwidth = 0.0;
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.processors[0].speed = -1.0;
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.processors[1].memory = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = tiny();
        let c2 = Cluster::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn load_presets() {
        let d = Cluster::load("default").unwrap();
        let m = Cluster::load("memory-constrained").unwrap();
        assert_eq!(d.len(), 72);
        assert_eq!(m.len(), 72);
        assert!(Cluster::load("/nonexistent/file.json").is_err());
    }
}
