//! Paper cluster presets (Table II, §VI-A-2).
//!
//! Six machine kinds, 12 nodes each (72 processors). Speeds are the
//! normalized CPU speeds from Table II; memories are in bytes. The
//! communication buffer is 10× the node memory (§VI-A-2). The
//! memory-constrained cluster divides every memory (and buffer) by 10.

use super::{Cluster, Processor};

/// Gigabyte in bytes.
pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;
/// Megabyte in bytes.
pub const MB: f64 = 1024.0 * 1024.0;
/// Kilobyte in bytes.
pub const KB: f64 = 1024.0;

/// Table II rows: (kind, speed, memory in GB).
pub const MACHINE_KINDS: [(&str, f64, f64); 6] = [
    ("local", 4.0, 16.0),
    ("A1", 32.0, 32.0),
    ("A2", 6.0, 64.0),
    ("N1", 12.0, 16.0),
    ("N2", 8.0, 8.0),
    ("C2", 32.0, 192.0),
];

/// Nodes of each kind in the paper's clusters.
pub const NODES_PER_KIND: usize = 12;

/// Communication buffer factor: `MC_j = 10 × M_j` (§VI-A-2).
pub const COMM_BUFFER_FACTOR: f64 = 10.0;

/// Interconnect bandwidth β. The paper does not publish its value; we use
/// 1 GB/s (a typical cluster Ethernet/IB-FDR effective rate) and expose it
/// via cluster JSON for sensitivity studies.
pub const DEFAULT_BANDWIDTH: f64 = 1.0 * GB;

/// Build a cluster with `nodes_per_kind` nodes of each Table II kind.
pub fn cluster_with(nodes_per_kind: usize, name: &str) -> Cluster {
    let mut processors = Vec::with_capacity(MACHINE_KINDS.len() * nodes_per_kind);
    for (kind, speed, mem_gb) in MACHINE_KINDS {
        for i in 0..nodes_per_kind {
            processors.push(Processor {
                name: format!("{kind}-{i:02}"),
                kind: kind.to_string(),
                speed,
                memory: mem_gb * GB,
                comm_buffer: COMM_BUFFER_FACTOR * mem_gb * GB,
            });
        }
    }
    let c = Cluster { name: name.to_string(), processors, bandwidth: DEFAULT_BANDWIDTH };
    debug_assert!(c.validate().is_ok());
    c
}

/// The default cluster: 72 nodes, Table II memories.
pub fn default_cluster() -> Cluster {
    cluster_with(NODES_PER_KIND, "default")
}

/// The memory-constrained cluster: same 72 nodes with 10× less memory
/// (buffers scale along, keeping `MC = 10 × M`).
pub fn memory_constrained_cluster() -> Cluster {
    default_cluster().scale_memory(0.1, "memory-constrained")
}

/// A small cluster for unit tests and the quickstart example: one node of
/// each kind (6 processors).
pub fn small_cluster() -> Cluster {
    cluster_with(1, "small")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cluster_matches_table_ii() {
        let c = default_cluster();
        assert_eq!(c.len(), 72);
        // 12 of each kind.
        for (kind, speed, mem_gb) in MACHINE_KINDS {
            let nodes: Vec<_> = c.processors.iter().filter(|p| p.kind == kind).collect();
            assert_eq!(nodes.len(), 12, "{kind}");
            for p in nodes {
                assert_eq!(p.speed, speed);
                assert_eq!(p.memory, mem_gb * GB);
                assert_eq!(p.comm_buffer, 10.0 * mem_gb * GB);
            }
        }
    }

    #[test]
    fn constrained_cluster_is_tenth() {
        let d = default_cluster();
        let m = memory_constrained_cluster();
        assert_eq!(m.len(), d.len());
        for (pd, pm) in d.processors.iter().zip(&m.processors) {
            assert!((pm.memory - pd.memory / 10.0).abs() < 1.0);
            assert_eq!(pm.speed, pd.speed);
        }
        // C2 goes from 192 GB to 19.2 GB (Table II).
        let c2 = m.processors.iter().find(|p| p.kind == "C2").unwrap();
        assert!((c2.memory - 19.2 * GB).abs() < 1.0);
    }

    #[test]
    fn unique_names() {
        let c = default_cluster();
        let mut names: Vec<&str> = c.processors.iter().map(|p| p.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 72);
    }
}
