//! PJRT runtime bridge: loads the AOT-compiled XLA artifacts produced by
//! `make artifacts` (`python/compile/aot.py` → `artifacts/*.hlo.txt`) and
//! executes them from the Rust request path.
//!
//! Python never runs at schedule time: the artifacts are compiled once and
//! the `xla` crate's PJRT CPU client executes them. Two computations are
//! exported:
//!
//! - `eft_score`: batched tentative-assignment scoring — for one task and
//!   all processors at once, the earliest finish time and memory residual
//!   (Steps 2–3 of §IV-B) as a fused XLA computation whose inner kernels
//!   are Pallas (see `python/compile/kernels/`);
//! - `predictor`: the online resource-estimate refiner (§V): a ridge
//!   regression mapping (estimate, observed deviation statistics) to a
//!   corrected estimate.

pub mod predictor;
pub mod scorer;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve an artifact path: explicit dir via `MEMSCHED_ARTIFACTS`, else
/// `./artifacts`.
pub fn artifact_path(name: &str) -> PathBuf {
    let dir = std::env::var("MEMSCHED_ARTIFACTS").unwrap_or_else(|_| ARTIFACTS_DIR.to_string());
    Path::new(&dir).join(name)
}

/// A compiled XLA computation on the PJRT CPU client.
pub struct Computation {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl Computation {
    /// Load HLO text and compile it on a fresh CPU client.
    pub fn load(path: &Path) -> Result<Computation> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO module")?;
        Ok(Computation { client, exe })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with f32 vector inputs of the given shapes; returns the
    /// flattened f32 outputs of the result tuple.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshaping input to {shape:?}"))?;
            literals.push(lit);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = result.decompose_tuple().context("decomposing result tuple")?;
        elems
            .into_iter()
            .map(|lit| {
                let lit = lit.convert(xla::PrimitiveType::F32)?;
                lit.to_vec::<f32>().context("reading f32 output")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_present() -> bool {
        artifact_path("eft_score.hlo.txt").exists()
    }

    #[test]
    fn artifact_path_env_override() {
        std::env::set_var("MEMSCHED_ARTIFACTS", "/tmp/xyz");
        assert_eq!(artifact_path("a.txt"), PathBuf::from("/tmp/xyz/a.txt"));
        std::env::remove_var("MEMSCHED_ARTIFACTS");
        assert_eq!(artifact_path("a.txt"), PathBuf::from("artifacts/a.txt"));
    }

    #[test]
    fn load_missing_artifact_errors() {
        assert!(Computation::load(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }

    #[test]
    fn execute_eft_artifact_if_built() {
        // Full numeric check lives in rust/tests/pjrt_integration.rs; this
        // is a smoke test that only runs when artifacts exist.
        if !artifacts_present() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let c = Computation::load(&artifact_path("eft_score.hlo.txt")).unwrap();
        assert_eq!(c.platform(), "cpu");
    }
}
