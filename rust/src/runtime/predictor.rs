//! Online resource predictor (§V): the AOT-compiled ridge model refining
//! task resource estimates from observed deviations.
//!
//! The runtime system aggregates, per task type, the ratio of actual to
//! estimated work/memory over finished tasks, and queries the predictor
//! for corrected multipliers applied to the estimates of not-yet-started
//! tasks of the same type. This mirrors the online prediction methods the
//! paper cites ([5], [24], [32]): cold-start error ~15%, reduced by up to
//! a third online.

use super::Computation;
use anyhow::Result;
use std::collections::HashMap;

/// Feature count (must match `python/compile/model.py`).
pub const FEATURES: usize = 4;

/// The compiled predictor.
pub struct Predictor {
    comp: Computation,
}

impl Predictor {
    pub fn load_default() -> Result<Predictor> {
        Self::load(&super::artifact_path("predictor.hlo.txt"))
    }

    pub fn load(path: &std::path::Path) -> Result<Predictor> {
        Ok(Predictor { comp: Computation::load(path)? })
    }

    /// Corrected (work_ratio, memory_ratio) multipliers.
    ///
    /// `obs_work_ratio` / `obs_mem_ratio`: mean observed actual/estimate
    /// ratios for the task type; `est_work`: the estimate (for the scale
    /// feature).
    pub fn correct(
        &self,
        obs_work_ratio: f64,
        obs_mem_ratio: f64,
        est_work: f64,
    ) -> Result<(f64, f64)> {
        let features = [
            1.0f32,
            obs_work_ratio as f32,
            obs_mem_ratio as f32,
            (est_work.max(1e-6)).log10() as f32,
        ];
        let outs = self.comp.run_f32(&[(&features, &[FEATURES])])?;
        anyhow::ensure!(outs.len() == 1 && outs[0].len() == 2, "unexpected predictor output");
        Ok((outs[0][0] as f64, outs[0][1] as f64))
    }
}

/// Accumulates observed deviation ratios per task type (runtime side).
#[derive(Debug, Default, Clone)]
pub struct DeviationStats {
    sums: HashMap<String, (f64, f64, usize)>,
}

impl DeviationStats {
    /// Record a finished task's actual/estimated ratios.
    pub fn observe(&mut self, task_type: &str, work_ratio: f64, mem_ratio: f64) {
        let e = self.sums.entry(task_type.to_string()).or_insert((0.0, 0.0, 0));
        e.0 += work_ratio;
        e.1 += mem_ratio;
        e.2 += 1;
    }

    /// Mean observed ratios for a type, if any observations exist.
    pub fn mean(&self, task_type: &str) -> Option<(f64, f64)> {
        let &(w, m, n) = self.sums.get(task_type)?;
        if n == 0 {
            return None;
        }
        Some((w / n as f64, m / n as f64))
    }

    pub fn observations(&self, task_type: &str) -> usize {
        self.sums.get(task_type).map_or(0, |e| e.2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_stats_accumulate() {
        let mut s = DeviationStats::default();
        assert_eq!(s.mean("x"), None);
        s.observe("x", 1.2, 0.9);
        s.observe("x", 0.8, 1.1);
        let (w, m) = s.mean("x").unwrap();
        assert!((w - 1.0).abs() < 1e-12);
        assert!((m - 1.0).abs() < 1e-12);
        assert_eq!(s.observations("x"), 2);
        assert_eq!(s.observations("y"), 0);
    }

    #[test]
    fn predictor_runs_if_artifact_built() {
        let path = crate::runtime::artifact_path("predictor.hlo.txt");
        if !path.exists() {
            eprintln!("artifact missing; skipping");
            return;
        }
        let p = Predictor::load(&path).unwrap();
        let (w, m) = p.correct(1.1, 0.95, 100.0).unwrap();
        // Ridge shrinks toward the observation; outputs stay in a sane band.
        assert!((0.5..1.5).contains(&w), "w = {w}");
        assert!((0.5..1.5).contains(&m), "m = {m}");
        // More deviated observation → more deviated correction.
        let (w2, _) = p.correct(1.4, 1.0, 100.0).unwrap();
        assert!(w2 > w);
    }
}
