//! Batched tentative-assignment scoring (the scheduler's inner loop) —
//! native Rust reference and the XLA/PJRT-accelerated implementation.
//!
//! For one task `v` and all processors at once, compute:
//!
//! - `ft[j]`  — the Step-3 finish time of `v` on `p_j`;
//! - `res[j]` — the Step-2 memory residual (before eviction).
//!
//! Queries arrive as [`ScoreQuery`] views borrowing the engine's
//! [`ScoreBuffers`](crate::scheduler::ScoreBuffers) arena, and results
//! are written into caller-provided slices from the same arena — the
//! scoring hot loop performs no per-task allocation on either side.
//!
//! The XLA path executes the AOT artifact `eft_score.hlo.txt`, whose inner
//! kernel is a Pallas kernel (`python/compile/kernels/eft.py`) lowered in
//! interpret mode. Shapes are fixed at export time (`PAD_PROCS` ×
//! `PAD_PARENTS`); queries are padded.
//!
//! The engine consumes either implementation through
//! [`crate::scheduler::engine::EftScorer`]: scores order the processors;
//! exact Rust bookkeeping (Step 1, eviction, commit) then validates the
//! winner, so f32 rounding in the XLA path can only affect tie-breaks.

use super::Computation;
use crate::scheduler::engine::{EftScorer, ScoreQuery};
use anyhow::Result;
use std::cell::RefCell;

/// Padded processor-axis length of the AOT artifact.
pub const PAD_PROCS: usize = 128;
/// Padded parent-axis length of the AOT artifact.
pub const PAD_PARENTS: usize = 32;

/// Pure-Rust scorer (the default hot path; also the parity oracle).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeScorer;

impl EftScorer for NativeScorer {
    fn score(&self, q: &ScoreQuery<'_>, ft: &mut [f64], res: &mut [f64]) {
        let k = q.num_procs();
        debug_assert!(ft.len() == k && res.len() == k);
        for j in 0..k {
            let mut st = q.proc_ready[j];
            let mut remote_in = 0.0f64;
            for (p, par) in q.parents.iter().enumerate() {
                if par.proc != j {
                    let arrival = par.finish.max(q.comm[p * k + j]) + par.data / q.bandwidth;
                    st = st.max(arrival);
                    remote_in += par.data;
                }
            }
            ft[j] = st + q.work / q.speeds[j];
            res[j] = q.avail_mem[j] - q.memory - remote_in - q.out_total;
        }
    }
}

/// XLA-backed scorer executing the PJRT artifact.
pub struct XlaScorer {
    comp: Computation,
    /// Scratch buffers (the scorer is used single-threaded in the engine).
    scratch: RefCell<Scratch>,
}

struct Scratch {
    ready: Vec<f32>,
    speed: Vec<f32>,
    avail: Vec<f32>,
    pft: Vec<f32>,
    pc: Vec<f32>,
    comm: Vec<f32>,
    mask: Vec<f32>,
    scalars: Vec<f32>,
}

impl XlaScorer {
    /// Load `eft_score.hlo.txt` from the artifacts directory.
    pub fn load_default() -> Result<XlaScorer> {
        Self::load(&super::artifact_path("eft_score.hlo.txt"))
    }

    pub fn load(path: &std::path::Path) -> Result<XlaScorer> {
        Ok(XlaScorer {
            comp: Computation::load(path)?,
            scratch: RefCell::new(Scratch {
                ready: vec![0.0; PAD_PROCS],
                speed: vec![1.0; PAD_PROCS],
                avail: vec![0.0; PAD_PROCS],
                pft: vec![0.0; PAD_PARENTS],
                pc: vec![0.0; PAD_PARENTS],
                comm: vec![0.0; PAD_PARENTS * PAD_PROCS],
                mask: vec![0.0; PAD_PARENTS * PAD_PROCS],
                scalars: vec![0.0; 4],
            }),
        })
    }

    fn fill(&self, q: &ScoreQuery<'_>) -> Result<()> {
        let k = q.num_procs();
        anyhow::ensure!(k <= PAD_PROCS, "cluster too large for artifact ({k} > {PAD_PROCS})");
        anyhow::ensure!(
            q.parents.len() <= PAD_PARENTS,
            "too many parents for artifact ({} > {PAD_PARENTS})",
            q.parents.len()
        );
        let mut s = self.scratch.borrow_mut();
        // Padded processors get an enormous ready time so they never win.
        for j in 0..PAD_PROCS {
            s.ready[j] = if j < k { q.proc_ready[j] as f32 } else { 1e30 };
            s.speed[j] = if j < k { q.speeds[j] as f32 } else { 1.0 };
            s.avail[j] = if j < k { q.avail_mem[j] as f32 } else { -1e30 };
        }
        for p in 0..PAD_PARENTS {
            if let Some(par) = q.parents.get(p) {
                s.pft[p] = par.finish as f32;
                s.pc[p] = par.data as f32;
                let row = q.comm_row(p);
                for j in 0..PAD_PROCS {
                    let idx = p * PAD_PROCS + j;
                    if j < k {
                        s.comm[idx] = row[j] as f32;
                        s.mask[idx] = if par.proc == j { 0.0 } else { 1.0 };
                    } else {
                        s.comm[idx] = 0.0;
                        s.mask[idx] = 0.0;
                    }
                }
            } else {
                s.pft[p] = 0.0;
                s.pc[p] = 0.0;
                for j in 0..PAD_PROCS {
                    let idx = p * PAD_PROCS + j;
                    s.comm[idx] = 0.0;
                    s.mask[idx] = 0.0;
                }
            }
        }
        s.scalars[0] = q.work as f32;
        s.scalars[1] = q.memory as f32;
        s.scalars[2] = q.out_total as f32;
        s.scalars[3] = (1.0 / q.bandwidth) as f32;
        Ok(())
    }

    /// Raw padded scores (used by tests and benches).
    pub fn score_padded(&self, q: &ScoreQuery<'_>) -> Result<(Vec<f32>, Vec<f32>)> {
        self.fill(q)?;
        let s = self.scratch.borrow();
        let outs = self.comp.run_f32(&[
            (&s.ready, &[PAD_PROCS]),
            (&s.speed, &[PAD_PROCS]),
            (&s.avail, &[PAD_PROCS]),
            (&s.pft, &[PAD_PARENTS]),
            (&s.pc, &[PAD_PARENTS]),
            (&s.comm, &[PAD_PARENTS, PAD_PROCS]),
            (&s.mask, &[PAD_PARENTS, PAD_PROCS]),
            (&s.scalars, &[4]),
        ])?;
        anyhow::ensure!(outs.len() == 2, "expected (ft, res) outputs");
        Ok((outs[0].clone(), outs[1].clone()))
    }
}

impl EftScorer for XlaScorer {
    fn score(&self, q: &ScoreQuery<'_>, ft: &mut [f64], res: &mut [f64]) {
        let k = q.num_procs();
        match self.score_padded(q) {
            Ok((xft, xres)) => {
                for j in 0..k {
                    ft[j] = xft[j] as f64;
                    res[j] = xres[j] as f64;
                }
            }
            Err(e) => {
                // Defensive: fall back to the native scorer rather than
                // aborting a schedule mid-flight.
                log::warn!("XLA scorer failed ({e}); falling back to native");
                NativeScorer.score(q, ft, res);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::engine::ParentInfo;
    use crate::scheduler::ScoreBuffers;

    fn buffers() -> ScoreBuffers {
        ScoreBuffers {
            proc_ready: vec![0.0, 5.0, 2.0],
            speeds: vec![1.0, 2.0, 4.0],
            avail_mem: vec![100.0, 50.0, 10.0],
            parents: vec![
                ParentInfo { finish: 3.0, data: 10.0, proc: 0 },
                ParentInfo { finish: 4.0, data: 20.0, proc: 1 },
            ],
            // Row-major parents × procs.
            comm: vec![0.0, 1.0, 0.0, 2.0, 0.0, 6.0],
            work: 8.0,
            memory: 30.0,
            out_total: 5.0,
            bandwidth: 10.0,
            ..Default::default()
        }
    }

    #[test]
    fn native_scorer_matches_hand_computation() {
        let b = buffers();
        let (mut ft, mut res) = (vec![0.0; 3], vec![0.0; 3]);
        NativeScorer.score(&b.query(), &mut ft, &mut res);
        // Proc 0: remote parent 1 (on proc 1): arrival = max(4, 2) + 2 = 6;
        // st = max(0, 6) = 6; ft = 6 + 8/1 = 14.
        assert!((ft[0] - 14.0).abs() < 1e-9);
        // res[0] = 100 - 30 - 20 - 5 = 45.
        assert!((res[0] - 45.0).abs() < 1e-9);
        // Proc 1: remote parent 0 (on 0): arrival = max(3, 1) + 1 = 4;
        // st = max(5, 4) = 5; ft = 5 + 4 = 9. res = 50 - 30 - 10 - 5 = 5.
        assert!((ft[1] - 9.0).abs() < 1e-9);
        assert!((res[1] - 5.0).abs() < 1e-9);
        // Proc 2: both parents remote: arrivals max(3,0)+1=4, max(4,6)+2=8;
        // st = max(2, 8) = 8; ft = 8 + 2 = 10. res = 10 - 30 - 30 - 5 = -55.
        assert!((ft[2] - 10.0).abs() < 1e-9);
        assert!((res[2] + 55.0).abs() < 1e-9);
    }

    #[test]
    fn score_with_reuses_the_arena() {
        let mut b = buffers();
        b.score_with(&NativeScorer);
        assert_eq!(b.ft.len(), 3);
        assert!((b.ft[1] - 9.0).abs() < 1e-9);
        let cap = b.ft.capacity();
        b.score_with(&NativeScorer);
        assert_eq!(b.ft.capacity(), cap, "outputs must not reallocate");
    }

    #[test]
    fn xla_scorer_parity_if_artifact_built() {
        let path = crate::runtime::artifact_path("eft_score.hlo.txt");
        if !path.exists() {
            eprintln!("artifact missing; skipping XLA parity test");
            return;
        }
        let xs = XlaScorer::load(&path).unwrap();
        let b = buffers();
        let (mut nft, mut nres) = (vec![0.0; 3], vec![0.0; 3]);
        NativeScorer.score(&b.query(), &mut nft, &mut nres);
        let (mut xft, mut xres) = (vec![0.0; 3], vec![0.0; 3]);
        xs.score(&b.query(), &mut xft, &mut xres);
        for j in 0..3 {
            assert!((nft[j] - xft[j]).abs() < 1e-3, "ft[{j}]: {} vs {}", nft[j], xft[j]);
            assert!((nres[j] - xres[j]).abs() < 1e-3, "res[{j}]");
        }
    }
}
