//! Phase 2 of HEFT/HEFTM: greedy task-to-processor assignment with memory
//! bookkeeping and eviction (paper §IV-A, §IV-B).
//!
//! The [`Engine`] walks tasks in rank order. For each task it *tentatively*
//! assigns it to every processor (Steps 1–3 of §IV-B), keeps the
//! assignment minimizing the finish time, and *commits* it, updating the
//! platform state (ready times, memories, pending-data sets, channel
//! ready times).
//!
//! Structurally the engine is split into two layers:
//!
//! - a **scoring layer** ([`ScoringCtx`]) — a borrowed, read-only,
//!   `Send + Sync` view over the platform state, the workflow, and the
//!   committed placements. `ScoringCtx::tentative` is a pure function of
//!   that view, so per-processor scoring can fan out across the workers
//!   of a shared [`ScorePool`] ([`Engine::with_parallel_scoring`]); the
//!   winner is picked by a deterministic serial reduction (minimum finish
//!   time, ties to the lowest [`ProcId`]), which keeps schedules
//!   byte-identical for any worker count;
//! - a **commit layer** (`Engine::commit`) — the only mutating phase,
//!   always single-threaded, which also invalidates the per-processor
//!   eviction-candidate caches ([`EvictCache`]) the scoring layer reads.
//!
//! The same engine serves four roles:
//! - the HEFT baseline (`memory_aware = false`): memory feasibility is
//!   *tracked* but never enforced, so the schedule may overcommit —
//!   exactly the paper's invalid-schedule measurements (Figs 1, 3);
//! - the HEFTM variants (`memory_aware = true`): Steps 1–3 enforced;
//! - suffix rescheduling in the dynamic scenario (constructed via
//!   [`Engine::resume`] from a mid-execution platform state);
//! - as the oracle inside [`super::retrace`].

use super::ranking;
use super::state::{EvictCache, EvictionPolicy, PlatformState};
use super::Algorithm;
use crate::obs;
use crate::platform::{Cluster, ProcId};
use crate::service::pool::ScorePool;
use crate::workflow::{EdgeId, TaskId, Workflow};
use std::sync::Mutex;

/// One parent's data for batched EFT scoring.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParentInfo {
    pub finish: f64,
    pub data: f64,
    pub proc: ProcId,
}

/// Inputs for scoring one task against every processor at once (the
/// engine's inner loop, offloadable to the XLA runtime — see
/// `runtime::scorer`).
///
/// All array fields are slices into a reusable [`ScoreBuffers`] arena:
/// building a query allocates nothing once the arena is warm.
#[derive(Debug, Clone, Copy)]
pub struct ScoreQuery<'a> {
    pub proc_ready: &'a [f64],
    pub speeds: &'a [f64],
    pub avail_mem: &'a [f64],
    pub parents: &'a [ParentInfo],
    /// Row-major `parents.len() × num_procs` channel ready times
    /// `rt_{proc(u), j}` (the old per-parent `Vec<Vec<f64>>`, flattened).
    pub comm: &'a [f64],
    pub work: f64,
    pub memory: f64,
    pub out_total: f64,
    pub bandwidth: f64,
}

impl<'a> ScoreQuery<'a> {
    pub fn num_procs(&self) -> usize {
        self.proc_ready.len()
    }

    /// Channel ready times of parent `p` toward every processor.
    pub fn comm_row(&self, p: usize) -> &[f64] {
        let k = self.proc_ready.len();
        &self.comm[p * k..(p + 1) * k]
    }
}

/// Reusable SoA arena backing [`ScoreQuery`] plus the scorer's output
/// slots. One arena lives in each [`Engine`]; refilling it per task
/// replaces the former per-task `ScoreQuery` allocations (four `Vec`s
/// plus an O(parents) `Vec<Vec<f64>>`) with amortized-zero allocation.
#[derive(Debug, Default, Clone)]
pub struct ScoreBuffers {
    pub proc_ready: Vec<f64>,
    pub speeds: Vec<f64>,
    pub avail_mem: Vec<f64>,
    pub parents: Vec<ParentInfo>,
    /// Row-major `parents × procs` channel ready times.
    pub comm: Vec<f64>,
    pub work: f64,
    pub memory: f64,
    pub out_total: f64,
    pub bandwidth: f64,
    /// Output: per-processor finish times (filled by [`score_with`]).
    ///
    /// [`score_with`]: ScoreBuffers::score_with
    pub ft: Vec<f64>,
    /// Output: per-processor memory residuals.
    pub res: Vec<f64>,
}

impl ScoreBuffers {
    /// The borrowed query over the arena's current contents.
    pub fn query(&self) -> ScoreQuery<'_> {
        ScoreQuery {
            proc_ready: &self.proc_ready,
            speeds: &self.speeds,
            avail_mem: &self.avail_mem,
            parents: &self.parents,
            comm: &self.comm,
            work: self.work,
            memory: self.memory,
            out_total: self.out_total,
            bandwidth: self.bandwidth,
        }
    }

    /// Run `scorer` over the arena's query, writing into the arena's
    /// `ft`/`res` output slots (resized to the processor count).
    pub fn score_with(&mut self, scorer: &dyn EftScorer) {
        let k = self.proc_ready.len();
        let mut ft = std::mem::take(&mut self.ft);
        let mut res = std::mem::take(&mut self.res);
        ft.clear();
        ft.resize(k, 0.0);
        res.clear();
        res.resize(k, 0.0);
        scorer.score(&self.query(), &mut ft, &mut res);
        self.ft = ft;
        self.res = res;
    }
}

/// Batched EFT scorer: finish times and memory residuals per processor,
/// written into caller-provided slices (borrowed from [`ScoreBuffers`]).
/// Implemented natively (`runtime::scorer::NativeScorer`) and via the AOT
/// XLA artifact (`runtime::scorer::XlaScorer`).
pub trait EftScorer {
    /// Fill `ft[j]` / `res[j]` for every `j < q.num_procs()`. Both output
    /// slices are exactly `q.num_procs()` long.
    fn score(&self, q: &ScoreQuery<'_>, ft: &mut [f64], res: &mut [f64]);
}

/// Committed placement of one task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSchedule {
    pub proc: ProcId,
    pub start: f64,
    pub finish: f64,
    /// Files evicted from memory into the comm buffer to fit this task.
    pub evicted: Vec<EdgeId>,
    /// Whether `Res ≥ 0` held *without* eviction (needed by retrace §V).
    pub res_nonneg: bool,
}

/// Why a schedule is invalid.
#[derive(Debug, Clone, PartialEq)]
pub enum Failure {
    /// No processor could satisfy the memory constraint for `task`.
    OutOfMemory { task: TaskId },
    /// Memory constraint violated on the chosen processor (baseline HEFT
    /// tracking: `Res < 0` at `task` on `proc`).
    Overcommit { task: TaskId, proc: ProcId },
    /// `task` was committed to `proc`, which has since been lost
    /// (schedule retracing, §V).
    ProcessorLost { task: TaskId, proc: ProcId },
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Failure::OutOfMemory { task } => {
                write!(f, "out of memory: no processor fits task {task}")
            }
            Failure::Overcommit { task, proc } => {
                write!(f, "overcommit: task {task} exceeds memory on processor {proc}")
            }
            Failure::ProcessorLost { task, proc } => {
                write!(f, "processor lost: task {task} was placed on lost processor {proc}")
            }
        }
    }
}

/// A complete (possibly invalid) schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub algorithm: Algorithm,
    pub policy: EvictionPolicy,
    /// The rank order used for assignment (topological).
    pub rank_order: Vec<TaskId>,
    /// Per-task placements (indexed by task id).
    pub tasks: Vec<TaskSchedule>,
    /// True iff every task was placed without violating memory/buffers.
    pub valid: bool,
    /// All recorded violations (empty iff `valid`).
    pub failures: Vec<Failure>,
    /// Total execution time (max finish time).
    pub makespan: f64,
    /// Per-processor peak memory usage as a fraction of its capacity
    /// (can exceed 1.0 for the HEFT baseline).
    pub mem_peak_frac: Vec<f64>,
}

impl Schedule {
    /// Mean peak memory usage over processors that received ≥1 task.
    pub fn mean_mem_usage(&self) -> f64 {
        let mut used: Vec<bool> = vec![false; self.mem_peak_frac.len()];
        for t in &self.tasks {
            used[t.proc] = true;
        }
        let (sum, cnt) = self
            .mem_peak_frac
            .iter()
            .zip(&used)
            .filter(|(_, &u)| u)
            .fold((0.0, 0usize), |(s, c), (f, _)| (s + f, c + 1));
        if cnt == 0 {
            0.0
        } else {
            sum / cnt as f64
        }
    }

    /// Number of distinct processors used.
    pub fn procs_used(&self) -> usize {
        let mut used: Vec<bool> = vec![false; self.mem_peak_frac.len()];
        for t in &self.tasks {
            used[t.proc] = true;
        }
        used.iter().filter(|&&u| u).count()
    }

    /// Approximate heap footprint in bytes (for cache accounting).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Schedule>()
            + self.rank_order.len() * std::mem::size_of::<TaskId>()
            + self.tasks.len() * std::mem::size_of::<TaskSchedule>()
            + self
                .tasks
                .iter()
                .map(|t| t.evicted.len() * std::mem::size_of::<EdgeId>())
                .sum::<usize>()
            + self.failures.len() * std::mem::size_of::<Failure>()
            + self.mem_peak_frac.len() * std::mem::size_of::<f64>()
    }
}

/// Result of a tentative assignment (Steps 1–3). Pure output of the
/// scoring layer; consumed by the commit layer.
#[derive(Debug, Clone)]
pub struct Tentative {
    pub start: f64,
    pub finish: f64,
    pub evictions: Vec<(EdgeId, f64)>,
    /// `Res` before eviction (memory slack; negative → eviction needed).
    pub res: f64,
    /// Absolute memory usage during execution, bytes (post-eviction).
    pub used: f64,
}

/// Borrowed, read-only view over everything tentative scoring needs
/// (Steps 1–3 of §IV-B): the workflow, the cluster, the platform state,
/// and the placements committed so far.
///
/// `ScoringCtx` is `Send + Sync` by construction — no `Rc`, no `RefCell`;
/// the only interior mutability is the `OnceLock` cells of the shared
/// [`EvictCache`] — so [`Engine::assign`] can evaluate
/// [`tentative`](ScoringCtx::tentative) for disjoint processors on
/// [`ScorePool`] workers concurrently. All mutation happens afterwards,
/// in the engine's single-threaded commit layer.
#[derive(Clone, Copy)]
pub struct ScoringCtx<'a> {
    wf: &'a Workflow,
    cluster: &'a Cluster,
    state: &'a PlatformState,
    placed: &'a [Option<TaskSchedule>],
    evict_cache: &'a EvictCache,
    memory_aware: bool,
    policy: EvictionPolicy,
}

impl<'a> ScoringCtx<'a> {
    /// Finish time of an already-placed task (must exist).
    fn ft(&self, u: TaskId) -> f64 {
        self.placed[u].as_ref().expect("rank order is topological").finish
    }

    fn proc_of(&self, u: TaskId) -> ProcId {
        self.placed[u].as_ref().expect("rank order is topological").proc
    }

    /// Steps 1–3 (§IV-B): tentatively assign `v` to `p_j`.
    /// Returns `None` if the placement is invalid (memory or buffer).
    pub fn tentative(&self, v: TaskId, j: ProcId) -> Option<Tentative> {
        let ps = &self.state.procs[j];
        let mem_j = self.cluster.proc(j).memory;

        // CSR in-edge ids are ascending (counting sort by destination
        // preserves edge-id order), so membership checks below can
        // binary-search the slice directly — no per-call allocation, and
        // no quadratic scan for high-fan-in tasks.
        let inputs = self.wf.in_edge_ids(v);
        debug_assert!(inputs.windows(2).all(|w| w[0] < w[1]), "CSR in-edges must be sorted");

        // Partition v's inputs into same-proc and remote.
        let mut local_in_pending = 0.0f64; // v's inputs resident in PD_j
        let mut remote_in = 0.0f64;
        for &e in inputs {
            let edge = self.wf.edge(e);
            if self.proc_of(edge.src) == j {
                // Step 1: the file must still be pending in p_j's memory.
                if self.memory_aware && !ps.pending.contains(e) {
                    return None;
                }
                local_in_pending += edge.data;
            } else {
                remote_in += edge.data;
            }
        }
        let out: f64 = self.wf.total_out_data(v);
        let m_v = self.wf.task(v).memory;

        // Step 2: memory residual.
        let res = ps.avail_mem - m_v - remote_in - out;
        let mut evictions: Vec<(EdgeId, f64)> = Vec::new();
        let mut avail_after_evict = ps.avail_mem;
        if res < 0.0 {
            if self.memory_aware {
                // Fast infeasibility bounds before touching the sorted
                // candidate list: the evictable volume excludes v's own
                // inputs, and whatever is evicted must fit in the buffer.
                let need = -res;
                let max_evictable = ps.pending.total_size() - local_in_pending;
                if need > max_evictable + 1e-9 || need > ps.avail_buf + 1e-9 {
                    return None;
                }
                // Evict pending files (largest/smallest first) until the
                // deficit is covered; the task's own inputs are not
                // candidates (a pending file of p_j that is also an input
                // of v necessarily has its producer on p_j, so the sorted
                // `inputs` slice is the exact skip set), and everything
                // must fit in the comm buffer.
                let mut need = need;
                let mut buf_left = ps.avail_buf;
                for &(e, size) in self.evict_cache.sorted(j, &ps.pending, self.policy) {
                    if need <= 0.0 {
                        break;
                    }
                    if inputs.binary_search(&e).is_ok() {
                        continue;
                    }
                    if size > buf_left {
                        // Buffer exceeded while evicting: invalid (§IV-B).
                        return None;
                    }
                    buf_left -= size;
                    need -= size;
                    avail_after_evict += size;
                    evictions.push((e, size));
                }
                if need > 0.0 {
                    return None; // not enough evictable data
                }
            }
            // Baseline HEFT: tracked but not enforced.
        }

        // Step 3: start/finish times.
        let mut st = ps.ready_time;
        for &e in inputs {
            let edge = self.wf.edge(e);
            let pu = self.proc_of(edge.src);
            if pu != j {
                let arrival = self.ft(edge.src).max(self.state.comm_ready(pu, j))
                    + edge.data / self.cluster.bandwidth;
                st = st.max(arrival);
            }
        }
        let ft = st + self.cluster.exec_time(self.wf.task(v).work, j);
        let used = mem_j - (avail_after_evict - m_v - remote_in - out);
        Some(Tentative { start: st, finish: ft, evictions, res, used })
    }

    /// Lookahead selection key: the worst (max) estimated EFT over `v`'s
    /// children, assuming `v` runs on `j` as `t` says. Each child's EFT
    /// is optimistically minimized over processors, with its start
    /// bounded by the processor ready time (adjusted for `v` occupying
    /// `j`), `v`'s data arrival, and every *already placed* parent's
    /// arrival; unplaced parents other than `v` are ignored (one-level
    /// lookahead — they will be ranked after `v` anyway). Childless
    /// tasks fall back to `t.finish`, i.e. plain HEFT.
    fn lookahead_key(&self, v: TaskId, j: ProcId, t: &Tentative) -> f64 {
        let k = self.cluster.len();
        let beta = self.cluster.bandwidth;
        let mut worst = t.finish;
        for (c, data) in self.wf.children(v) {
            let mut best_eft = f64::INFINITY;
            for q in 0..k {
                let ready =
                    if q == j { t.finish } else { self.state.procs[q].ready_time };
                let arrival_v = if q == j { t.finish } else { t.finish + data / beta };
                let mut st = ready.max(arrival_v);
                for (p, pdata) in self.wf.parents(c) {
                    if p == v {
                        continue;
                    }
                    if let Some(ps) = self.placed[p].as_ref() {
                        let arr = if ps.proc == q {
                            ps.finish
                        } else {
                            ps.finish + pdata / beta
                        };
                        st = st.max(arr);
                    }
                }
                let eft = st + self.cluster.exec_time(self.wf.task(c).work, q);
                if eft < best_eft {
                    best_eft = eft;
                }
            }
            if best_eft > worst {
                worst = best_eft;
            }
        }
        worst
    }

    /// Fill the batched-scoring arena for task `v` (see [`ScoreQuery`]).
    pub fn fill_query(&self, v: TaskId, buf: &mut ScoreBuffers) {
        let k = self.cluster.len();
        buf.proc_ready.clear();
        buf.proc_ready.extend(self.state.procs.iter().map(|p| p.ready_time));
        buf.speeds.clear();
        buf.speeds.extend(self.cluster.processors.iter().map(|p| p.speed));
        buf.avail_mem.clear();
        buf.avail_mem.extend(self.state.procs.iter().map(|p| p.avail_mem));
        buf.parents.clear();
        for &e in self.wf.in_edge_ids(v) {
            let edge = self.wf.edge(e);
            buf.parents.push(ParentInfo {
                finish: self.ft(edge.src),
                data: edge.data,
                proc: self.proc_of(edge.src),
            });
        }
        buf.comm.clear();
        buf.comm.reserve(buf.parents.len() * k);
        for p in &buf.parents {
            for j in 0..k {
                buf.comm.push(self.state.comm_ready(p.proc, j));
            }
        }
        buf.work = self.wf.task(v).work;
        buf.memory = self.wf.task(v).memory;
        buf.out_total = self.wf.total_out_data(v);
        buf.bandwidth = self.cluster.bandwidth;
    }
}

/// Immutable per-(workflow, cluster, algorithm) selector inputs: PEFT's
/// `n × k` optimistic cost table and DLS's static levels. Built once via
/// [`SelectorState::build`] and *borrowed* by every engine that shares
/// the triple — most importantly the adaptive-recompute path, where
/// `SimScaffold` hoists one `SelectorState` over all recompute triggers
/// instead of rebuilding the table per trigger (the dominant per-trigger
/// cost for PEFT at scale).
///
/// Hoisting is bit-identical by construction: a resumed engine consults
/// selector rows only for *unstarted* tasks, whose every strict
/// descendant is also unstarted (a task arrives only after all parents
/// finished) and therefore still carries its estimated parameters — so
/// the estimate-built OCT rows equal the rows a per-trigger rebuild
/// would produce. DLS static levels are defined over the scaffold's
/// estimates as the algorithm's fixed priority baseline.
#[derive(Debug, Default)]
pub struct SelectorState {
    /// PEFT: row-major `n × k` OCT table ([`ranking::oct_table`]).
    oct: Option<Vec<f64>>,
    /// DLS: static levels `SL(v)` ([`ranking::static_levels`]).
    static_levels: Option<Vec<f64>>,
}

impl SelectorState {
    /// Build the selector inputs `algo` needs (empty for the min-finish
    /// family — HEFT/HEFTM and Lookahead carry no precomputed tables).
    pub fn build(algo: Algorithm, wf: &Workflow, cluster: &Cluster) -> SelectorState {
        match algo {
            Algorithm::Peft => SelectorState {
                oct: Some(ranking::oct_table(wf, cluster)),
                static_levels: None,
            },
            Algorithm::Dls => SelectorState {
                oct: None,
                static_levels: Some(ranking::static_levels(wf, cluster)),
            },
            _ => SelectorState::default(),
        }
    }

    fn oct(&self) -> &[f64] {
        self.oct.as_deref().expect("PEFT selector state carries the OCT table")
    }

    fn static_levels(&self) -> &[f64] {
        self.static_levels.as_deref().expect("DLS selector state carries static levels")
    }
}

/// An engine's view of its [`SelectorState`]: owned on the fresh-build
/// constructors, borrowed on the hoisted resume path.
enum SelectorSource<'a> {
    Owned(SelectorState),
    Shared(&'a SelectorState),
}

impl SelectorSource<'_> {
    fn get(&self) -> &SelectorState {
        match self {
            SelectorSource::Owned(s) => s,
            SelectorSource::Shared(s) => s,
        }
    }
}

/// Reusable resources handed back by [`Engine::run_into_plan`]: the
/// platform snapshot, the fixed-placement buffer (now all `Some`), and
/// the scoring arena. The simulator's `ResumeArena` carries them across
/// recompute triggers so each resume resets in place instead of
/// reallocating.
pub struct ResumeParts {
    pub state: PlatformState,
    pub fixed: Vec<Option<TaskSchedule>>,
    pub buffers: ScoreBuffers,
}

/// The assignment engine. See module docs.
pub struct Engine<'a> {
    wf: &'a Workflow,
    cluster: &'a Cluster,
    pub state: PlatformState,
    memory_aware: bool,
    policy: EvictionPolicy,
    algorithm: Algorithm,
    /// Placements (None = not yet assigned).
    placed: Vec<Option<TaskSchedule>>,
    failures: Vec<Failure>,
    /// Optional batched scorer: pre-orders processors by finish time so
    /// the exact per-processor check can stop at the first feasible one.
    scorer: Option<&'a dyn EftScorer>,
    /// Optional shared pool for parallel tentative scoring.
    score_pool: Option<&'a ScorePool>,
    /// Per-processor eviction-candidate caches (scoring layer reads,
    /// commit layer invalidates).
    evict_cache: EvictCache,
    /// Reusable query arena for the batched-scorer path.
    buffers: ScoreBuffers,
    /// Per-processor result slots for the parallel scoring phase (reused
    /// across tasks; reduced serially for determinism).
    slots: Vec<Mutex<Option<Tentative>>>,
    /// Selector inputs (PEFT's OCT table, DLS's static levels) — owned
    /// by fresh engines, borrowed on the hoisted resume path.
    selector: SelectorSource<'a>,
    /// First index of `run`'s order that can still be unplaced; resumed
    /// engines skip the fixed prefix ([`Engine::with_fixed_prefix`]).
    resume_from: usize,
}

impl<'a> Engine<'a> {
    /// Fresh engine over an idle platform.
    pub fn new(
        wf: &'a Workflow,
        cluster: &'a Cluster,
        algorithm: Algorithm,
        policy: EvictionPolicy,
    ) -> Engine<'a> {
        Engine {
            wf,
            cluster,
            state: PlatformState::new(cluster),
            memory_aware: algorithm.memory_aware(),
            policy,
            algorithm,
            placed: vec![None; wf.num_tasks()],
            failures: Vec::new(),
            scorer: None,
            score_pool: None,
            evict_cache: EvictCache::new(cluster.len()),
            buffers: ScoreBuffers::default(),
            slots: (0..cluster.len()).map(|_| Mutex::new(None)).collect(),
            selector: SelectorSource::Owned(SelectorState::build(algorithm, wf, cluster)),
            resume_from: 0,
        }
    }

    /// Attach a batched EFT scorer (e.g. the XLA/PJRT artifact).
    pub fn with_scorer(mut self, scorer: &'a dyn EftScorer) -> Engine<'a> {
        self.scorer = Some(scorer);
        self
    }

    /// Fan tentative scoring out across `pool`'s workers. Schedules are
    /// byte-identical to serial scoring for any thread count: every
    /// processor's tentative is computed independently and the winner is
    /// picked by a serial reduction (min finish time, ties to the lowest
    /// `ProcId` — exactly the serial loop's order). Ignored while a
    /// batched [`EftScorer`] is attached (that path is already ordered).
    pub fn with_parallel_scoring(mut self, pool: &'a ScorePool) -> Engine<'a> {
        self.score_pool = Some(pool);
        self
    }

    /// Resume from a mid-execution platform state with some tasks already
    /// placed (dynamic rescheduling, §V). `fixed` entries are kept as-is.
    ///
    /// Builds the selector state fresh from `wf`; the adaptive fast path
    /// uses [`Engine::resume_with`] to borrow a hoisted one instead.
    pub fn resume(
        wf: &'a Workflow,
        cluster: &'a Cluster,
        algorithm: Algorithm,
        policy: EvictionPolicy,
        state: PlatformState,
        fixed: Vec<Option<TaskSchedule>>,
    ) -> Engine<'a> {
        let selector = SelectorState::build(algorithm, wf, cluster);
        let mut e = Engine::resume_with(
            wf,
            cluster,
            algorithm,
            policy,
            state,
            fixed,
            ScoreBuffers::default(),
        );
        e.selector = SelectorSource::Owned(selector);
        e
    }

    /// [`Engine::resume`] with every reusable resource supplied by the
    /// caller: the arena-backed recompute path passes a reset
    /// `PlatformState`, a refilled fixed-placement buffer, and a warm
    /// [`ScoreBuffers`] arena ([`Engine::run_into_plan`] hands them
    /// back), then swaps the default empty selector for a scaffold-
    /// hoisted one via [`Engine::with_selector_state`].
    pub fn resume_with(
        wf: &'a Workflow,
        cluster: &'a Cluster,
        algorithm: Algorithm,
        policy: EvictionPolicy,
        state: PlatformState,
        fixed: Vec<Option<TaskSchedule>>,
        buffers: ScoreBuffers,
    ) -> Engine<'a> {
        assert_eq!(fixed.len(), wf.num_tasks());
        Engine {
            wf,
            cluster,
            state,
            memory_aware: algorithm.memory_aware(),
            policy,
            algorithm,
            placed: fixed,
            failures: Vec::new(),
            scorer: None,
            score_pool: None,
            evict_cache: EvictCache::new(cluster.len()),
            buffers,
            slots: (0..cluster.len()).map(|_| Mutex::new(None)).collect(),
            selector: SelectorSource::Owned(SelectorState::default()),
            resume_from: 0,
        }
    }

    /// Borrow a prebuilt [`SelectorState`] instead of the engine's own —
    /// the hoisted-selector half of the adaptive recompute fast path.
    /// The state must have been built for this engine's (workflow
    /// estimates, cluster, algorithm) triple.
    pub fn with_selector_state(mut self, selector: &'a SelectorState) -> Engine<'a> {
        self.selector = SelectorSource::Shared(selector);
        self
    }

    /// Declare that every task of `run`'s order before `first_unfixed`
    /// is already placed, so the placement loop starts there instead of
    /// re-scanning the fixed prefix. No-op for DLS (its driver scans the
    /// ready frontier, never the order).
    pub fn with_fixed_prefix(mut self, first_unfixed: usize) -> Engine<'a> {
        self.resume_from = first_unfixed;
        self
    }

    /// The read-only scoring view over the engine's current state.
    pub fn scoring_ctx(&self) -> ScoringCtx<'_> {
        ScoringCtx {
            wf: self.wf,
            cluster: self.cluster,
            state: &self.state,
            placed: &self.placed,
            evict_cache: &self.evict_cache,
            memory_aware: self.memory_aware,
            policy: self.policy,
        }
    }

    /// Current placements (None = not yet assigned).
    pub fn placements(&self) -> &[Option<TaskSchedule>] {
        &self.placed
    }

    fn proc_of(&self, u: TaskId) -> ProcId {
        self.placed[u].as_ref().expect("rank order is topological").proc
    }

    fn tentative(&self, v: TaskId, j: ProcId) -> Option<Tentative> {
        self.scoring_ctx().tentative(v, j)
    }

    #[cfg(test)]
    fn reset_evict_cache(&mut self) {
        self.evict_cache = EvictCache::new(self.cluster.len());
    }

    /// Commit `v` on `j` (the paper's "assignment of task v" bullets).
    fn commit(&mut self, v: TaskId, j: ProcId, t: Tentative) {
        // Pending sets change below: drop the sorted-candidate caches of
        // every touched processor (j plus all remote parents' hosts).
        self.evict_cache.invalidate(j);
        for &e in self.wf.in_edge_ids(v) {
            let pu = self.placed[self.wf.edge(e).src]
                .as_ref()
                .expect("rank order is topological")
                .proc;
            self.evict_cache.invalidate(pu);
        }
        // 1. Evict files into the communication buffer.
        let mut evicted_ids = Vec::with_capacity(t.evictions.len());
        for &(e, size) in &t.evictions {
            let removed = self.state.procs[j].pending.remove(e);
            debug_assert_eq!(removed, Some(size));
            self.state.procs[j].avail_mem += size;
            self.state.procs[j].buffered.insert(e, size);
            self.state.procs[j].avail_buf -= size;
            if obs::enabled() {
                obs::record(obs::Event::EvictionChosen {
                    task: v as u32,
                    proc: j as u32,
                    edge: e as u32,
                });
            }
            evicted_ids.push(e);
        }

        // 2. Record the transient usage high-water mark.
        self.state.note_usage(j, t.used);

        // 3. Inputs: same-proc files leave PD_j (freed once v completes);
        //    remote files are consumed on their producer's side, and the
        //    channel ready time advances.
        for &e in self.wf.in_edge_ids(v) {
            let edge = self.wf.edge(e);
            let pu = self.proc_of(edge.src);
            if pu == j {
                if let Some(size) = self.state.procs[j].pending.remove(e) {
                    self.state.procs[j].avail_mem += size;
                }
            } else {
                self.state.consume_remote(pu, e);
                self.state.push_comm(pu, j, edge.data / self.cluster.bandwidth);
            }
        }

        // 4. Outputs join PD_j, reducing available memory.
        for &e in self.wf.out_edge_ids(v) {
            let size = self.wf.edge(e).data;
            self.state.procs[j].pending.insert(e, size);
            self.state.procs[j].avail_mem -= size;
        }

        // 5. Processor busy until v finishes.
        self.state.procs[j].ready_time = t.finish;

        self.placed[v] = Some(TaskSchedule {
            proc: j,
            start: t.start,
            finish: t.finish,
            evicted: evicted_ids,
            res_nonneg: t.res >= 0.0,
        });
    }

    /// The algorithm's selection key for a feasible tentative — smaller
    /// is better. HEFT/HEFTM reduce on the finish time; PEFT adds the
    /// optimistic cost table entry; Lookahead estimates the worst child
    /// EFT. Always evaluated in the serial reduction (never on pool
    /// workers), so parallel scoring stays byte-identical to serial for
    /// every selector.
    fn selection_key(&self, ctx: &ScoringCtx<'_>, v: TaskId, j: ProcId, t: &Tentative) -> f64 {
        match self.algorithm {
            Algorithm::Peft => t.finish + self.selector.get().oct()[v * self.cluster.len() + j],
            Algorithm::Lookahead => ctx.lookahead_key(v, j, t),
            _ => t.finish,
        }
    }

    /// Score `v` against every processor and return the winner —
    /// deterministic minimum selection key, ties to the smaller finish
    /// time, then to the lowest `ProcId`. (For `MinFinish` the key *is*
    /// the finish time, so this is exactly the original reduction.)
    ///
    /// With a [`ScorePool`] attached the per-processor tentatives run on
    /// the pool's workers (each writes its own slot; no shared mutable
    /// state), and only the reduction below is serial.
    fn best_tentative(&self, v: TaskId) -> Option<(ProcId, Tentative)> {
        let k = self.cluster.len();
        let ctx = self.scoring_ctx();
        let parallel = self
            .score_pool
            .filter(|p| p.threads() > 1 && k > 1);
        if let Some(pool) = parallel {
            let slots = &self.slots;
            let chunks = pool.threads().min(k);
            pool.scoped_for(chunks, &|c| {
                // Contiguous chunk per worker: cache-friendly and free of
                // false sharing on the slot locks.
                let (lo, hi) = (c * k / chunks, (c + 1) * k / chunks);
                for j in lo..hi {
                    *slots[j].lock().unwrap() = ctx.tentative(v, j);
                }
            });
        }
        let mut best: Option<(ProcId, Tentative)> = None;
        let mut best_key = f64::INFINITY;
        for j in 0..k {
            let t = if parallel.is_some() {
                self.slots[j].lock().unwrap().take()
            } else {
                ctx.tentative(v, j)
            };
            if let Some(t) = t {
                let key = self.selection_key(&ctx, v, j, &t);
                let better = match &best {
                    None => true,
                    Some((_, bt)) => key < best_key || (key == best_key && t.finish < bt.finish),
                };
                if better {
                    best_key = key;
                    best = Some((j, t));
                }
            }
        }
        best
    }

    /// Assign one task: try all processors, commit the best.
    /// Returns false if no feasible processor existed (memory-aware mode);
    /// in that case a memory-oblivious fallback placement is committed so
    /// the (invalid) schedule is still complete for reporting.
    pub fn assign(&mut self, v: TaskId) -> bool {
        debug_assert!(self.placed[v].is_none());
        let k = self.cluster.len();
        let mut best: Option<(ProcId, Tentative)> = None;
        // The batched-scorer shortcut assumes the selection key *is* the
        // finish time; PEFT/Lookahead selectors take the exact reduction.
        let batched = self
            .scorer
            .filter(|_| !matches!(self.algorithm, Algorithm::Peft | Algorithm::Lookahead));
        if let Some(scorer) = batched {
            // Accelerated path: one batched scoring call orders the
            // processors; the exact check stops at the first feasible one
            // (the scores are the Step-3 finish times, so the first
            // feasible processor in score order is the argmin).
            let mut bufs = std::mem::take(&mut self.buffers);
            self.scoring_ctx().fill_query(v, &mut bufs);
            bufs.score_with(scorer);
            let mut order: Vec<ProcId> = (0..k).collect();
            order.sort_by(|&a, &b| {
                bufs.ft[a].partial_cmp(&bufs.ft[b]).unwrap_or(std::cmp::Ordering::Equal)
            });
            for j in order {
                if let Some(t) = self.tentative(v, j) {
                    best = Some((j, t));
                    break;
                }
            }
            self.buffers = bufs;
        } else {
            best = self.best_tentative(v);
        }
        match best {
            Some((j, t)) => {
                if t.res < 0.0 && !self.memory_aware {
                    // Baseline HEFT exceeded the memory: record and go on.
                    self.failures.push(Failure::Overcommit { task: v, proc: j });
                }
                if obs::enabled() {
                    obs::record(obs::Event::TaskScored { task: v as u32, proc: j as u32 });
                }
                self.commit(v, j, t);
                true
            }
            None => {
                // Memory-aware and no processor fits: invalid schedule.
                self.failures.push(Failure::OutOfMemory { task: v });
                // Fallback: place memory-obliviously to complete the
                // schedule (reported makespans of invalid schedules).
                let saved = self.memory_aware;
                self.memory_aware = false;
                let fallback = self.best_tentative(v);
                self.memory_aware = saved;
                let (bj, t) = fallback.expect("memory-oblivious tentative always succeeds");
                self.commit(v, bj, t);
                false
            }
        }
    }

    /// Force `v` onto processor `j` (schedule retracing, §V). With
    /// `allow_new_eviction = false`, a placement that *newly* requires
    /// eviction (`Res < 0`) is rejected — the paper's rule that an
    /// originally-nonnegative residual must stay nonnegative.
    /// Returns the committed placement or the failure.
    pub fn place_forced(
        &mut self,
        v: TaskId,
        j: ProcId,
        allow_new_eviction: bool,
    ) -> Result<TaskSchedule, Failure> {
        match self.tentative(v, j) {
            Some(t) if t.res >= 0.0 || allow_new_eviction => {
                self.commit(v, j, t);
                Ok(self.placed[v].clone().unwrap())
            }
            _ => Err(Failure::OutOfMemory { task: v }),
        }
    }

    /// Run phase 2 over the given rank order and produce the schedule.
    /// DLS ignores the static order and re-ranks per step (see
    /// [`Engine::run_dynamic_level`]) — dispatched here so the resume
    /// path (`Engine::resume(..).run(..)`) re-plans DLS schedules with
    /// DLS semantics too.
    pub fn run(mut self, order: &[TaskId]) -> Schedule {
        let rank_order = self.place_all(order).unwrap_or_else(|| order.to_vec());
        self.into_schedule(rank_order)
    }

    /// The placement driver shared by [`Engine::run`] and
    /// [`Engine::run_into_plan`]. Returns `Some(rank order)` when the
    /// algorithm derives its own (DLS), `None` when the caller's order
    /// is the schedule's.
    fn place_all(&mut self, order: &[TaskId]) -> Option<Vec<TaskId>> {
        debug_assert!(self.wf.is_topological_order(order));
        if self.algorithm == Algorithm::Dls {
            return Some(self.run_dynamic_level(order));
        }
        debug_assert!(
            order[..self.resume_from].iter().all(|&v| self.placed[v].is_some()),
            "fixed prefix must already be placed"
        );
        for &v in &order[self.resume_from..] {
            if self.placed[v].is_none() {
                self.assign(v);
            }
        }
        None
    }

    /// Run placement and write the resulting plan into `plan` in place
    /// (same placements as `run(order).tasks`, bit for bit), handing the
    /// engine's reusable resources back for the next resume. `plan`'s
    /// eviction buffers are recycled by swapping rather than cloning —
    /// the adaptive fast path allocates nothing here once warm.
    pub fn run_into_plan(mut self, order: &[TaskId], plan: &mut [TaskSchedule]) -> ResumeParts {
        let _ = self.place_all(order);
        assert_eq!(plan.len(), self.placed.len());
        for (d, p) in plan.iter_mut().zip(self.placed.iter_mut()) {
            let s = p.as_mut().expect("all tasks placed");
            d.proc = s.proc;
            d.start = s.start;
            d.finish = s.finish;
            d.res_nonneg = s.res_nonneg;
            std::mem::swap(&mut d.evicted, &mut s.evicted);
        }
        ResumeParts { state: self.state, fixed: self.placed, buffers: self.buffers }
    }

    /// DLS (Sih & Lee): every step commits the feasible (ready task,
    /// processor) pair maximizing the dynamic level
    /// `DL(v, j) = SL(v) − start(v, j) + Δ(v, j)` with the speed
    /// adjustment `Δ(v, j) = w_v/s̄ − w_v/s_j`; ties break to the lowest
    /// task id, then the lowest processor id, so the commit sequence is
    /// deterministic (and independent of any score pool — the per-step
    /// sweep is serial by construction). Memory feasibility runs through
    /// the same `tentative` machinery as the HEFTM family; when *no*
    /// (task, processor) pair is feasible, the max-SL ready task goes
    /// through [`Engine::assign`]'s memory-oblivious fallback, recording
    /// the out-of-memory failure exactly like the static algorithms.
    ///
    /// Fresh runs record the actual commit order as the schedule's
    /// `rank_order` (returned here); resumed runs (some tasks pre-placed)
    /// keep the caller's full order, since a partial commit order is not
    /// a complete task permutation.
    fn run_dynamic_level(&mut self, order: &[TaskId]) -> Vec<TaskId> {
        let n = self.wf.num_tasks();
        // Borrow the static levels from the (possibly hoisted) selector
        // state; moved out for the loop so commits can take `&mut self`.
        let selector =
            std::mem::replace(&mut self.selector, SelectorSource::Owned(SelectorState::default()));
        let sl = selector.get().static_levels();
        let s_mean = self.cluster.mean_speed();
        let resumed = self.placed.iter().any(|p| p.is_some());
        // Unplaced-parent counts; pre-placed tasks (resume) count as done.
        let mut missing: Vec<usize> = (0..n)
            .map(|v| self.wf.parents(v).filter(|&(p, _)| self.placed[p].is_none()).count())
            .collect();
        // Ascending task ids: the tie-break scan below prefers lower ids.
        let mut ready: Vec<TaskId> =
            (0..n).filter(|&v| self.placed[v].is_none() && missing[v] == 0).collect();
        let mut committed: Vec<TaskId> = Vec::with_capacity(n);
        while !ready.is_empty() {
            let mut pick: Option<(usize, ProcId, Tentative)> = None; // (ready idx, proc, t)
            let mut pick_dl = f64::NEG_INFINITY;
            {
                let ctx = self.scoring_ctx();
                for (i, &v) in ready.iter().enumerate() {
                    let mean_exec = self.wf.task(v).work / s_mean;
                    for j in 0..self.cluster.len() {
                        if let Some(t) = ctx.tentative(v, j) {
                            let delta = mean_exec - self.cluster.exec_time(self.wf.task(v).work, j);
                            let dl = sl[v] - t.start + delta;
                            // Strict `>` keeps the first (lowest task id,
                            // lowest proc id) maximizer on ties.
                            if pick.is_none() || dl > pick_dl {
                                pick_dl = dl;
                                pick = Some((i, j, t));
                            }
                        }
                    }
                }
            }
            let v = match pick {
                Some((i, j, t)) => {
                    let v = ready[i];
                    if t.res < 0.0 && !self.memory_aware {
                        self.failures.push(Failure::Overcommit { task: v, proc: j });
                    }
                    if obs::enabled() {
                        obs::record(obs::Event::TaskScored { task: v as u32, proc: j as u32 });
                    }
                    self.commit(v, j, t);
                    ready.remove(i);
                    v
                }
                None => {
                    // No feasible pair at all: the max-SL ready task takes
                    // the standard infeasibility path (failure recorded,
                    // memory-oblivious fallback placement). Strict `>`
                    // keeps the lowest task id on SL ties.
                    let mut i = 0;
                    for idx in 1..ready.len() {
                        if sl[ready[idx]] > sl[ready[i]] {
                            i = idx;
                        }
                    }
                    let v = ready[i];
                    self.assign(v);
                    ready.remove(i);
                    v
                }
            };
            committed.push(v);
            for (c, _) in self.wf.children(v) {
                missing[c] -= 1;
                if missing[c] == 0 && self.placed[c].is_none() {
                    let at = ready.partition_point(|&r| r < c);
                    ready.insert(at, c);
                }
            }
        }
        self.selector = selector;
        if resumed {
            order.to_vec()
        } else {
            committed
        }
    }

    /// Finalize into a [`Schedule`].
    pub fn into_schedule(self, rank_order: Vec<TaskId>) -> Schedule {
        let tasks: Vec<TaskSchedule> = self
            .placed
            .into_iter()
            .map(|p| p.expect("all tasks placed"))
            .collect();
        let makespan = tasks.iter().map(|t| t.finish).fold(0.0, f64::max);
        let mem_peak_frac = self
            .state
            .procs
            .iter()
            .enumerate()
            .map(|(j, ps)| ps.peak_used / self.cluster.proc(j).memory)
            .collect();
        Schedule {
            algorithm: self.algorithm,
            policy: self.policy,
            rank_order,
            valid: self.failures.is_empty(),
            failures: self.failures,
            makespan,
            tasks,
            mem_peak_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::{small_cluster, GB};
    use crate::platform::Processor;
    use crate::scheduler::{Algorithm, ScheduleRequest};
    use crate::workflow::WorkflowBuilder;

    fn two_proc_cluster(mem0: f64, mem1: f64, buf_factor: f64) -> Cluster {
        Cluster {
            name: "2p".into(),
            processors: vec![
                Processor {
                    name: "p0".into(),
                    kind: "a".into(),
                    speed: 1.0,
                    memory: mem0,
                    comm_buffer: buf_factor * mem0,
                },
                Processor {
                    name: "p1".into(),
                    kind: "b".into(),
                    speed: 2.0,
                    memory: mem1,
                    comm_buffer: buf_factor * mem1,
                },
            ],
            bandwidth: 10.0,
        }
    }

    fn chain3(work: f64, mem: f64, data: f64) -> Workflow {
        let mut b = WorkflowBuilder::new("c3");
        let a = b.task("a", "t", work, mem);
        let c = b.task("c", "t", work, mem);
        let d = b.task("d", "t", work, mem);
        b.edge(a, c, data);
        b.edge(c, d, data);
        b.build().unwrap()
    }

    #[test]
    fn heft_prefers_fast_processor() {
        let cluster = two_proc_cluster(1e9, 1e9, 10.0);
        let wf = chain3(10.0, 100.0, 1.0);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        // All three tasks on the fast processor (no comm needed, speed 2).
        assert!(s.tasks.iter().all(|t| t.proc == 1), "{:?}", s.tasks);
        assert_eq!(s.makespan, 15.0); // 3 × 10/2
    }

    #[test]
    fn dependence_times_respected() {
        let cluster = two_proc_cluster(1e9, 1e9, 10.0);
        let wf = chain3(10.0, 100.0, 1.0);
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            // Child starts after parent finishes (+ comm if cross-proc).
            for e in wf.edges() {
                let (ts, td) = (&s.tasks[e.src], &s.tasks[e.dst]);
                let comm = cluster.comm_time(e.data, ts.proc, td.proc);
                assert!(
                    td.start + 1e-9 >= ts.finish + comm,
                    "{algo:?}: edge ({},{})",
                    e.src,
                    e.dst
                );
            }
            // Processor exclusivity: tasks on one proc don't overlap.
            let mut by_proc: std::collections::HashMap<usize, Vec<(f64, f64)>> =
                Default::default();
            for t in &s.tasks {
                by_proc.entry(t.proc).or_default().push((t.start, t.finish));
            }
            for (_, mut iv) in by_proc {
                iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for w in iv.windows(2) {
                    assert!(w[0].1 <= w[1].0 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn heft_overcommits_and_is_flagged_invalid() {
        // Tasks of 600 MB memory on processors with 1 GB: two concurrent
        // outputs + task memory exceed capacity quickly.
        let cluster = two_proc_cluster(1.0 * GB, 1.0 * GB, 10.0);
        let mut b = WorkflowBuilder::new("heavy");
        let src = b.task("src", "t", 1.0, 0.5 * GB);
        for i in 0..6 {
            let t = b.task(format!("x{i}"), "t", 10.0, 0.8 * GB);
            b.edge(src, t, 0.3 * GB);
        }
        let wf = b.build().unwrap();
        let heft = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
        assert!(!heft.valid, "HEFT should overcommit");
        assert!(heft.mem_peak_frac.iter().cloned().fold(0.0, f64::max) > 1.0);
    }

    #[test]
    fn heftm_respects_memory_where_heft_fails() {
        let cluster = two_proc_cluster(1.0 * GB, 1.0 * GB, 10.0);
        let mut b = WorkflowBuilder::new("heavy");
        let src = b.task("src", "t", 1.0, 0.5 * GB);
        for i in 0..6 {
            let t = b.task(format!("x{i}"), "t", 10.0, 0.8 * GB);
            b.edge(src, t, 0.03 * GB);
        }
        let wf = b.build().unwrap();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid, "failures: {:?}", s.failures);
        assert!(s.mem_peak_frac.iter().all(|&f| f <= 1.0 + 1e-9), "{:?}", s.mem_peak_frac);
    }

    #[test]
    fn heftm_evicts_to_buffer_when_tight() {
        // One processor; outputs accumulate; a later big task forces
        // evicting an earlier task's output destined for... same proc —
        // eviction would break Step 1, so instead build a case where the
        // evicted file feeds a *remote* consumer.
        let cluster = two_proc_cluster(1000.0, 10.0, 10.0); // p1 tiny memory
        let mut b = WorkflowBuilder::new("evict");
        // a produces a large file for c (contender for eviction) and a
        // small one for d; then big task e must fit on p0.
        let a = b.task("a", "t", 1.0, 100.0);
        let c = b.task("c", "t", 100.0, 1.0); // will run late
        let d = b.task("d", "t", 1.0, 10.0);
        let e = b.task("e", "t", 1.0, 900.0); // forces eviction on p0
        b.edge(a, c, 400.0);
        b.edge(a, d, 10.0);
        b.edge(d, e, 5.0);
        let wf = b.build().unwrap();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        // Schedule must be valid; task e (id 3) must have evicted the
        // 400-byte file if placed on p0 while it was still pending.
        assert!(s.valid, "failures: {:?}", s.failures);
        let total_evictions: usize = s.tasks.iter().map(|t| t.evicted.len()).sum();
        // (e ends up wherever EFT is minimal; if on p0 with the 400-file
        // still resident, an eviction is mandatory.)
        if s.tasks[3].proc == 0 && s.tasks[1].proc != 0 {
            assert!(total_evictions > 0);
        }
    }

    #[test]
    fn infeasible_task_marks_schedule_invalid() {
        // Task memory exceeds every processor: even HEFTM cannot place it.
        let cluster = two_proc_cluster(100.0, 100.0, 10.0);
        let mut b = WorkflowBuilder::new("huge");
        b.task("a", "t", 1.0, 500.0);
        let wf = b.build().unwrap();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(!s.valid);
        assert!(matches!(s.failures[0], Failure::OutOfMemory { task: 0 }));
        // Fallback still placed it (schedule complete).
        assert_eq!(s.tasks.len(), 1);
    }

    #[test]
    fn makespan_monotone_under_memory_constraint() {
        // HEFTM's makespan is ≥ HEFT's on the same instance (less freedom).
        let cluster = small_cluster();
        let model = crate::generator::models::chipseq();
        let wf = crate::generator::expand(&model, 8).unwrap();
        let data = crate::traces::HistoricalData::synthesize(
            &crate::traces::task_types(&wf),
            &crate::traces::TraceConfig::default(),
            9,
        );
        let wf = crate::traces::bind_weights(&wf, &data, 1);
        let heft = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmBlc] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if s.valid {
                assert!(
                    s.makespan + 1e-6 >= heft.makespan * 0.999,
                    "{algo:?}: {} vs {}",
                    s.makespan,
                    heft.makespan
                );
            }
        }
    }

    #[test]
    fn schedule_stats_helpers() {
        let cluster = two_proc_cluster(1e9, 1e9, 10.0);
        let wf = chain3(10.0, 100.0, 1.0);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.procs_used() >= 1);
        assert!(s.mean_mem_usage() >= 0.0);
        assert!(s.approx_bytes() > 0);
    }

    #[test]
    fn scoring_ctx_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ScoringCtx<'static>>();
    }

    /// An eviction-heavy instance: a sized-down generated workflow on a
    /// memory-scaled small cluster, so every code path (Step-1 rejection,
    /// eviction, fallback) is exercised.
    fn eviction_heavy_instance() -> (Workflow, Cluster) {
        let spec = crate::experiments::WorkloadSpec {
            family: "chipseq".into(),
            size: Some(300),
            input: 3,
            seed: 7,
        };
        let wf = spec.build().unwrap();
        let cluster = small_cluster().scale_memory(0.02, "tight-small");
        (wf, cluster)
    }

    #[test]
    fn evict_cache_matches_uncached_scoring() {
        // The per-processor candidate cache must be behaviorally
        // invisible: resetting it before every assignment (i.e. always
        // sorting fresh, the pre-cache behavior) must give the identical
        // schedule.
        let (wf, cluster) = eviction_heavy_instance();
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
            for policy in [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst] {
                let order = algo.rank_order(&wf, &cluster);
                let cached = Engine::new(&wf, &cluster, algo, policy).run(&order);
                let mut fresh_engine = Engine::new(&wf, &cluster, algo, policy);
                for &v in &order {
                    fresh_engine.reset_evict_cache();
                    fresh_engine.assign(v);
                }
                let fresh = fresh_engine.into_schedule(order.clone());
                assert_eq!(cached.valid, fresh.valid, "{algo:?}/{policy:?}");
                assert_eq!(cached.tasks, fresh.tasks, "{algo:?}/{policy:?}");
                assert_eq!(
                    cached.makespan.to_bits(),
                    fresh.makespan.to_bits(),
                    "{algo:?}/{policy:?}"
                );
            }
        }
    }

    #[test]
    fn shared_selector_state_matches_owned() {
        // A hoisted (borrowed) SelectorState must be observationally
        // identical to the one each engine builds for itself — the
        // bit-identity contract of the adaptive recompute fast path.
        let (wf, cluster) = eviction_heavy_instance();
        let policy = EvictionPolicy::LargestFirst;
        for algo in [Algorithm::Peft, Algorithm::Dls, Algorithm::HeftmBl, Algorithm::Lookahead] {
            let order = algo.rank_order(&wf, &cluster);
            let owned = Engine::new(&wf, &cluster, algo, policy).run(&order);
            let hoisted = SelectorState::build(algo, &wf, &cluster);
            let shared = Engine::resume_with(
                &wf,
                &cluster,
                algo,
                policy,
                PlatformState::new(&cluster),
                vec![None; wf.num_tasks()],
                ScoreBuffers::default(),
            )
            .with_selector_state(&hoisted)
            .run(&order);
            assert_eq!(owned.tasks, shared.tasks, "{algo:?}");
            assert_eq!(owned.failures, shared.failures, "{algo:?}");
            assert_eq!(owned.makespan.to_bits(), shared.makespan.to_bits(), "{algo:?}");
        }
    }

    #[test]
    fn run_into_plan_matches_run() {
        // The arena-returning finisher must write the same placements
        // `run` would return, recycle the caller's eviction buffers, and
        // hand back a fully-placed fixed buffer.
        let (wf, cluster) = eviction_heavy_instance();
        let policy = EvictionPolicy::LargestFirst;
        for algo in [Algorithm::HeftmBl, Algorithm::Peft, Algorithm::Dls] {
            let order = algo.rank_order(&wf, &cluster);
            let byrun = Engine::new(&wf, &cluster, algo, policy).run(&order);
            let hoisted = SelectorState::build(algo, &wf, &cluster);
            let mut plan: Vec<TaskSchedule> = (0..wf.num_tasks())
                .map(|_| TaskSchedule {
                    proc: 0,
                    start: 0.0,
                    finish: 0.0,
                    evicted: Vec::new(),
                    res_nonneg: false,
                })
                .collect();
            let parts = Engine::resume_with(
                &wf,
                &cluster,
                algo,
                policy,
                PlatformState::new(&cluster),
                vec![None; wf.num_tasks()],
                ScoreBuffers::default(),
            )
            .with_selector_state(&hoisted)
            .with_fixed_prefix(0)
            .run_into_plan(&order, &mut plan);
            assert_eq!(plan, byrun.tasks, "{algo:?}");
            assert!(parts.fixed.iter().all(|p| p.is_some()), "{algo:?}");
        }
    }

    #[test]
    fn parallel_scoring_matches_serial_exactly() {
        let (wf, cluster) = eviction_heavy_instance();
        for threads in [2, 3, 8] {
            let pool = ScorePool::new(threads);
            for &algo in Algorithm::all() {
                let order = algo.rank_order(&wf, &cluster);
                let policy = EvictionPolicy::LargestFirst;
                let serial = Engine::new(&wf, &cluster, algo, policy).run(&order);
                let parallel = Engine::new(&wf, &cluster, algo, policy)
                    .with_parallel_scoring(&pool)
                    .run(&order);
                assert_eq!(serial.valid, parallel.valid, "{algo:?} × {threads}");
                assert_eq!(serial.failures, parallel.failures, "{algo:?} × {threads}");
                assert_eq!(serial.tasks, parallel.tasks, "{algo:?} × {threads}");
                assert_eq!(
                    serial.makespan.to_bits(),
                    parallel.makespan.to_bits(),
                    "{algo:?} × {threads}"
                );
            }
        }
    }
}
