//! Makespan lower bound per (workflow, cluster).
//!
//! Two classic relaxations, both provable lower bounds on the makespan of
//! *any* schedule (valid or invalid, memory-aware or not), and therefore
//! on any σ = 0 simulated replay of one:
//!
//! - the **critical-path bound**: the longest dependency chain with every
//!   task running at the fastest processor speed and communication free —
//!   dropping resource contention, memory, and comm can only shorten a
//!   schedule, and precedence still forces the chain to serialize;
//! - the **total-work bound**: all work spread perfectly over the
//!   aggregate speed `Σ_j s_j` — no schedule can process operations
//!   faster than every processor running flat out.
//!
//! The reported bound is the max of the two. Result rows derive
//! `optimality_gap = (makespan − lb) / lb` from it, so every batch /
//! experiment / serve row carries a distance-from-optimal estimate
//! rather than a bare makespan.

use crate::platform::Cluster;
use crate::workflow::Workflow;

/// Provable makespan lower bound: `max(critical-path, total-work)`.
/// Returns 0 for empty or zero-work workflows.
pub fn makespan_lower_bound(wf: &Workflow, cluster: &Cluster) -> f64 {
    let n = wf.num_tasks();
    if n == 0 || cluster.is_empty() {
        return 0.0;
    }
    let s_max = cluster.processors.iter().map(|p| p.speed).fold(0.0f64, f64::max);
    let s_sum: f64 = cluster.processors.iter().map(|p| p.speed).sum();

    // Critical path at the fastest speed, ignoring communication.
    let mut down = vec![0.0f64; n];
    let mut cp = 0.0f64;
    for &v in &wf.topological_order() {
        let longest_in = wf.parents(v).map(|(p, _)| down[p]).fold(0.0, f64::max);
        down[v] = longest_in + wf.task(v).work / s_max;
        cp = cp.max(down[v]);
    }

    // Total work over aggregate speed.
    let total: f64 = (0..n).map(|v| wf.task(v).work).sum::<f64>() / s_sum;

    cp.max(total)
}

/// Relative optimality gap `(makespan − lb) / lb`, clamped at 0 (σ > 0
/// replays can dip below an estimate-based bound; the static analytic
/// makespan never does). Returns 0 when the bound is degenerate
/// (zero-work workflows) and NaN when the makespan is NaN, so JSON rows
/// render `null` exactly when the makespan does.
pub fn optimality_gap(makespan: f64, lower_bound: f64) -> f64 {
    if makespan.is_nan() {
        return f64::NAN;
    }
    if lower_bound > 0.0 && makespan.is_finite() {
        ((makespan - lower_bound) / lower_bound).max(0.0)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
    use crate::workflow::WorkflowBuilder;

    fn chain(n: usize, work: f64) -> Workflow {
        let mut b = WorkflowBuilder::new("chain");
        let ids: Vec<_> = (0..n).map(|i| b.task(format!("t{i}"), "t", work, 1.0)).collect();
        for w in ids.windows(2) {
            b.edge(w[0], w[1], 1.0);
        }
        b.build().unwrap()
    }

    #[test]
    fn chain_bound_is_critical_path() {
        let cluster = small_cluster();
        let s_max = cluster.processors.iter().map(|p| p.speed).fold(0.0f64, f64::max);
        let wf = chain(5, 10.0);
        // A chain's critical path dominates its total-work bound.
        let lb = makespan_lower_bound(&wf, &cluster);
        assert!((lb - 5.0 * 10.0 / s_max).abs() < 1e-9);
    }

    #[test]
    fn wide_bound_is_total_work() {
        let cluster = small_cluster();
        let s_sum: f64 = cluster.processors.iter().map(|p| p.speed).sum();
        let s_max = cluster.processors.iter().map(|p| p.speed).fold(0.0f64, f64::max);
        // 100 independent tasks: total work dominates one task's exec.
        let mut b = WorkflowBuilder::new("wide");
        for i in 0..100 {
            b.task(format!("t{i}"), "t", 7.0, 1.0);
        }
        let wf = b.build().unwrap();
        let lb = makespan_lower_bound(&wf, &cluster);
        assert!((lb - 700.0 / s_sum).abs() < 1e-9);
        assert!(lb >= 7.0 / s_max);
    }

    #[test]
    fn bound_below_every_algorithm() {
        let spec = crate::experiments::WorkloadSpec {
            family: "chipseq".into(),
            size: Some(120),
            input: 3,
            seed: 11,
        };
        let wf = spec.build().unwrap();
        let cluster = small_cluster();
        let lb = makespan_lower_bound(&wf, &cluster);
        assert!(lb > 0.0);
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster)
                .algo(algo)
                .policy(EvictionPolicy::LargestFirst)
                .run();
            assert!(
                s.makespan + 1e-9 >= lb,
                "{algo:?}: makespan {} < lower bound {lb}",
                s.makespan
            );
            let gap = optimality_gap(s.makespan, lb);
            assert!(gap >= 0.0 && gap.is_finite());
        }
    }

    #[test]
    fn degenerate_inputs() {
        let cluster = small_cluster();
        let mut b = WorkflowBuilder::new("zero-work");
        b.task("t0", "t", 0.0, 1.0);
        let wf = b.build().unwrap();
        assert_eq!(makespan_lower_bound(&wf, &cluster), 0.0);
        assert_eq!(optimality_gap(5.0, 0.0), 0.0);
        assert_eq!(optimality_gap(f64::INFINITY, 1.0), 0.0);
        assert!(optimality_gap(f64::NAN, 1.0).is_nan());
        assert!((optimality_gap(3.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(optimality_gap(1.0, 2.0), 0.0);
    }
}
