//! Scheduling heuristics (paper §IV): the memory-oblivious HEFT baseline
//! and the three memory-aware variants HEFTM-BL, HEFTM-BLC, HEFTM-MM.
//!
//! All four share the two-phase list-scheduling skeleton: (1) compute a
//! priority order over tasks ([`ranking`]), (2) greedily assign each task
//! to the processor minimizing its finish time ([`engine`]). The HEFTM
//! variants additionally enforce the per-processor memory constraint,
//! evicting pending files into communication buffers when needed
//! ([`state`]), and may declare a placement infeasible.
//!
//! [`retrace`] re-validates a committed schedule after task parameters
//! deviate (paper §V).

pub mod engine;
pub mod ranking;
pub mod retrace;
pub mod state;

pub use engine::{Engine, Failure, Schedule, ScoreBuffers, ScoringCtx, TaskSchedule};
pub use state::{EvictCache, EvictionPolicy, PlatformState};

use crate::platform::Cluster;
use crate::service::pool::ScorePool;
use crate::workflow::{TaskId, Workflow};

/// The four scheduling algorithms of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Baseline HEFT [30]: memory-oblivious; may produce invalid schedules.
    Heft,
    /// HEFTM-BL: memory-aware, bottom-level ranking.
    HeftmBl,
    /// HEFTM-BLC: memory-aware, bottom-level-with-communication ranking.
    HeftmBlc,
    /// HEFTM-MM: memory-aware, MemDag minimum-memory traversal ranking.
    HeftmMm,
}

impl Algorithm {
    pub fn memory_aware(self) -> bool {
        !matches!(self, Algorithm::Heft)
    }

    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Heft => "HEFT",
            Algorithm::HeftmBl => "HEFTM-BL",
            Algorithm::HeftmBlc => "HEFTM-BLC",
            Algorithm::HeftmMm => "HEFTM-MM",
        }
    }

    pub fn all() -> [Algorithm; 4] {
        [Algorithm::Heft, Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm]
    }

    /// Compute this algorithm's rank order (phase 1).
    pub fn rank_order(self, wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
        match self {
            Algorithm::Heft | Algorithm::HeftmBl => ranking::rank_bl(wf, cluster),
            Algorithm::HeftmBlc => ranking::rank_blc(wf, cluster),
            Algorithm::HeftmMm => ranking::rank_mm(wf),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "heft" => Ok(Algorithm::Heft),
            "heftm-bl" | "bl" => Ok(Algorithm::HeftmBl),
            "heftm-blc" | "blc" => Ok(Algorithm::HeftmBlc),
            "heftm-mm" | "mm" => Ok(Algorithm::HeftmMm),
            other => anyhow::bail!(
                "unknown algorithm `{other}` (expected heft, heftm-bl, heftm-blc, heftm-mm)"
            ),
        }
    }
}

/// Compute a full static schedule (phases 1 + 2).
pub fn compute_schedule(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
) -> Schedule {
    compute_schedule_with(wf, cluster, algo, policy, None)
}

/// [`compute_schedule`] with optional intra-schedule parallel scoring:
/// when a [`ScorePool`] is given, every task's per-processor tentative
/// scoring fans out across its workers. The resulting schedule is
/// byte-identical to the serial one for any thread count (deterministic
/// reduction — see [`Engine::with_parallel_scoring`]).
pub fn compute_schedule_with(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
    score_pool: Option<&ScorePool>,
) -> Schedule {
    let order = algo.rank_order(wf, cluster);
    let mut engine = Engine::new(wf, cluster, algo, policy);
    if let Some(pool) = score_pool {
        engine = engine.with_parallel_scoring(pool);
    }
    engine.run(&order)
}
