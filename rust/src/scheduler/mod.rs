//! Scheduling heuristics (paper §IV): the memory-oblivious HEFT baseline
//! and the three memory-aware variants HEFTM-BL, HEFTM-BLC, HEFTM-MM.
//!
//! All four share the two-phase list-scheduling skeleton: (1) compute a
//! priority order over tasks ([`ranking`]), (2) greedily assign each task
//! to the processor minimizing its finish time ([`engine`]). The HEFTM
//! variants additionally enforce the per-processor memory constraint,
//! evicting pending files into communication buffers when needed
//! ([`state`]), and may declare a placement infeasible.
//!
//! [`retrace`] re-validates a committed schedule after task parameters
//! deviate (paper §V).

pub mod engine;
pub mod ranking;
pub mod retrace;
pub mod state;

pub use engine::{Engine, Failure, Schedule, ScoreBuffers, ScoringCtx, TaskSchedule};
pub use state::{EvictCache, EvictionPolicy, PlatformState};

use crate::platform::Cluster;
use crate::service::pool::ScorePool;
use crate::workflow::{TaskId, Workflow};

/// The four scheduling algorithms of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Baseline HEFT [30]: memory-oblivious; may produce invalid schedules.
    Heft,
    /// HEFTM-BL: memory-aware, bottom-level ranking.
    HeftmBl,
    /// HEFTM-BLC: memory-aware, bottom-level-with-communication ranking.
    HeftmBlc,
    /// HEFTM-MM: memory-aware, MemDag minimum-memory traversal ranking.
    HeftmMm,
}

impl Algorithm {
    pub fn memory_aware(self) -> bool {
        !matches!(self, Algorithm::Heft)
    }

    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Heft => "HEFT",
            Algorithm::HeftmBl => "HEFTM-BL",
            Algorithm::HeftmBlc => "HEFTM-BLC",
            Algorithm::HeftmMm => "HEFTM-MM",
        }
    }

    pub fn all() -> [Algorithm; 4] {
        [Algorithm::Heft, Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm]
    }

    /// Compute this algorithm's rank order (phase 1).
    pub fn rank_order(self, wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
        match self {
            Algorithm::Heft | Algorithm::HeftmBl => ranking::rank_bl(wf, cluster),
            Algorithm::HeftmBlc => ranking::rank_blc(wf, cluster),
            Algorithm::HeftmMm => ranking::rank_mm(wf),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "heft" => Ok(Algorithm::Heft),
            "heftm-bl" | "bl" => Ok(Algorithm::HeftmBl),
            "heftm-blc" | "blc" => Ok(Algorithm::HeftmBlc),
            "heftm-mm" | "mm" => Ok(Algorithm::HeftmMm),
            other => anyhow::bail!(
                "unknown algorithm `{other}` (expected heft, heftm-bl, heftm-blc, heftm-mm)"
            ),
        }
    }
}

/// Measured crossover for pool-parallel tentative scoring, in units of
/// `cluster.len() × mean task fan-in` — the per-task scoring work that
/// the [`ScorePool`] fans out. Below it, dispatch overhead exceeds the
/// win and serial scoring is faster (`bench_engine` is the measuring
/// harness: the paper's 72-processor cluster with chipseq-like fan-in
/// sits comfortably above, the 4–8 processor presets far below).
/// Refresh from a `ci.sh --crossover` run (the dedicated sweep in
/// `bench_engine`, `MEMSCHED_BENCH_CROSSOVER=1`) whenever the scoring
/// loop changes; it prints the measured suggestion for this constant.
pub const SCORE_PARALLEL_CROSSOVER: f64 = 64.0;

/// Adaptive score-thread choice (`--score-threads auto`): serial when
/// the instance sits below [`SCORE_PARALLEL_CROSSOVER`], all cores
/// above it. Schedules are byte-identical either way, so the choice is
/// purely a throughput knob.
pub fn auto_score_threads(wf: &Workflow, cluster: &Cluster) -> usize {
    let mean_fan_in = wf.num_edges() as f64 / wf.num_tasks().max(1) as f64;
    if (cluster.len() as f64) * mean_fan_in < SCORE_PARALLEL_CROSSOVER {
        1
    } else {
        // Deliberately not `service::pool::default_workers()`: the
        // scheduler layer must not depend upward on the service (the
        // two expressions are identical).
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Compute a full static schedule (phases 1 + 2).
pub fn compute_schedule(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
) -> Schedule {
    compute_schedule_with(wf, cluster, algo, policy, None)
}

/// [`compute_schedule`] with optional intra-schedule parallel scoring:
/// when a [`ScorePool`] is given, every task's per-processor tentative
/// scoring fans out across its workers. The resulting schedule is
/// byte-identical to the serial one for any thread count (deterministic
/// reduction — see [`Engine::with_parallel_scoring`]).
pub fn compute_schedule_with(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
    score_pool: Option<&ScorePool>,
) -> Schedule {
    let order = algo.rank_order(wf, cluster);
    let mut engine = Engine::new(wf, cluster, algo, policy);
    if let Some(pool) = score_pool {
        engine = engine.with_parallel_scoring(pool);
    }
    engine.run(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets;
    use crate::workflow::WorkflowBuilder;

    /// A chain workflow with `extra` additional cross edges, so mean
    /// fan-in is controllable: `(n - 1 + extra) / n` edges per task.
    fn wf_with_edges(n: usize, extra: usize) -> Workflow {
        let mut b = WorkflowBuilder::new("fanin");
        let ids: Vec<_> = (0..n).map(|i| b.task(&format!("t{i}"), "t", 1.0, 1.0)).collect();
        for w in ids.windows(2) {
            b.edge(w[0], w[1], 1.0);
        }
        let mut added = 0;
        'outer: for gap in 2..n {
            for i in 0..n.saturating_sub(gap) {
                if added == extra {
                    break 'outer;
                }
                b.edge(ids[i], ids[i + gap], 1.0);
                added += 1;
            }
        }
        assert_eq!(added, extra, "requested more extra edges than the DAG admits");
        b.build().unwrap()
    }

    #[test]
    fn auto_score_threads_pins_the_crossover() {
        let all_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        // 20 tasks, 19 edges on the 6-processor test cluster:
        // 6 × 0.95 = 5.7, far below the crossover → serial.
        let small = presets::small_cluster();
        assert_eq!(auto_score_threads(&wf_with_edges(20, 0), &small), 1);

        // The paper's 72-processor cluster with fan-in ≥ 1 sits above:
        // 72 × 0.95 = 68.4 ≥ 64 → parallel (all cores).
        let big = presets::default_cluster();
        assert_eq!(auto_score_threads(&wf_with_edges(20, 0), &big), all_cores);

        // Exact boundary arithmetic on the small cluster: 6 procs need
        // mean fan-in ≥ 64/6 ≈ 10.67, i.e. ≥ 534 edges on 50 tasks.
        // 533 edges → 6 × 10.66 = 63.96 < 64 (serial), 534 → 64.08 ≥ 64
        // (parallel); the constant itself is pinned so accidental
        // retuning fails loudly.
        assert_eq!(SCORE_PARALLEL_CROSSOVER, 64.0);
        assert_eq!(auto_score_threads(&wf_with_edges(50, 533 - 49), &small), 1);
        assert_eq!(auto_score_threads(&wf_with_edges(50, 534 - 49), &small), all_cores);
    }
}
