//! Scheduling heuristics (paper §IV): the memory-oblivious HEFT baseline,
//! the three memory-aware variants HEFTM-BL, HEFTM-BLC, HEFTM-MM, and the
//! literature extensions PEFT, Lookahead, and DLS — all behind one
//! [`ScheduleRequest`] entrypoint.
//!
//! The list schedulers share the two-phase skeleton: (1) compute a
//! priority order over tasks ([`ranking`]), (2) greedily assign each task
//! to the processor optimizing its selection key ([`engine`]). The
//! memory-aware variants additionally enforce the per-processor memory
//! constraint, evicting pending files into communication buffers when
//! needed ([`state`]), and may declare a placement infeasible.
//!
//! Beyond the paper's four algorithms:
//! - **PEFT** ranks by the optimistic cost table (OCT) and picks the
//!   processor minimizing `EFT + OCT` ([`ranking::oct_table`]);
//! - **Lookahead** ranks like HEFT but picks the processor minimizing the
//!   worst estimated child EFT (one-level lookahead);
//! - **DLS** abandons the static order entirely: every step commits the
//!   (ready task, processor) pair with the highest dynamic level;
//! - **Portfolio** is a meta-scheduler: it runs every standalone
//!   algorithm and commits the best candidate. At this layer "best" is
//!   the minimum analytic makespan (valid before invalid); the service
//!   layer supersedes this with replay-scored selection through the
//!   simulator's `SimScaffold` path (see `service::SchedulingService`).
//!
//! [`lower_bound`] gives a provable makespan lower bound per
//! (workflow, cluster) so results can report an optimality gap.
//! [`retrace`] re-validates a committed schedule after task parameters
//! deviate (paper §V).

pub mod engine;
pub mod lower_bound;
pub mod ranking;
pub mod retrace;
pub mod state;

pub use engine::{
    Engine, Failure, ResumeParts, Schedule, ScoreBuffers, ScoringCtx, SelectorState, TaskSchedule,
};
pub use state::{EvictCache, EvictionPolicy, PlatformState};

use crate::platform::Cluster;
use crate::service::pool::ScorePool;
use crate::workflow::{TaskId, Workflow};

/// The scheduling algorithms: the paper's four plus PEFT, Lookahead, DLS,
/// and the Portfolio meta-scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Baseline HEFT [30]: memory-oblivious; may produce invalid schedules.
    Heft,
    /// HEFTM-BL: memory-aware, bottom-level ranking.
    HeftmBl,
    /// HEFTM-BLC: memory-aware, bottom-level-with-communication ranking.
    HeftmBlc,
    /// HEFTM-MM: memory-aware, MemDag minimum-memory traversal ranking.
    HeftmMm,
    /// PEFT (Arabnejad & Barbosa): optimistic-cost-table rank, `EFT + OCT`
    /// processor selection; memory-aware.
    Peft,
    /// HEFT ranking with one-level lookahead processor selection
    /// (minimize the worst estimated child EFT); memory-aware.
    Lookahead,
    /// DLS (Sih & Lee): dynamic levels, re-ranked at every step;
    /// memory-aware.
    Dls,
    /// Meta-scheduler: run every algorithm in [`Algorithm::all`] and
    /// commit the best candidate (replay-scored in the service layer).
    Portfolio,
}

impl Algorithm {
    pub fn memory_aware(self) -> bool {
        !matches!(self, Algorithm::Heft)
    }

    /// Human-facing label (result rows, figures).
    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Heft => "HEFT",
            Algorithm::HeftmBl => "HEFTM-BL",
            Algorithm::HeftmBlc => "HEFTM-BLC",
            Algorithm::HeftmMm => "HEFTM-MM",
            Algorithm::Peft => "PEFT",
            Algorithm::Lookahead => "LOOKAHEAD",
            Algorithm::Dls => "DLS",
            Algorithm::Portfolio => "PORTFOLIO",
        }
    }

    /// Canonical CLI/job-spec name; `from_str` accepts exactly these
    /// (plus legacy aliases), so `as_str`/`from_str` round-trip.
    pub fn as_str(self) -> &'static str {
        match self {
            Algorithm::Heft => "heft",
            Algorithm::HeftmBl => "heftm-bl",
            Algorithm::HeftmBlc => "heftm-blc",
            Algorithm::HeftmMm => "heftm-mm",
            Algorithm::Peft => "peft",
            Algorithm::Lookahead => "lookahead",
            Algorithm::Dls => "dls",
            Algorithm::Portfolio => "portfolio",
        }
    }

    /// The standalone schedulable algorithms, HEFT first (experiment
    /// suites normalize against the leading HEFT row). Excludes
    /// [`Algorithm::Portfolio`], which fans out over exactly this slice —
    /// callers iterating `all()` therefore never recurse.
    pub fn all() -> &'static [Algorithm] {
        &[
            Algorithm::Heft,
            Algorithm::HeftmBl,
            Algorithm::HeftmBlc,
            Algorithm::HeftmMm,
            Algorithm::Peft,
            Algorithm::Lookahead,
            Algorithm::Dls,
        ]
    }

    /// Every variant, including [`Algorithm::Portfolio`] (name/tag maps).
    pub fn variants() -> &'static [Algorithm] {
        &[
            Algorithm::Heft,
            Algorithm::HeftmBl,
            Algorithm::HeftmBlc,
            Algorithm::HeftmMm,
            Algorithm::Peft,
            Algorithm::Lookahead,
            Algorithm::Dls,
            Algorithm::Portfolio,
        ]
    }

    /// Compute this algorithm's rank order (phase 1). DLS re-ranks
    /// dynamically inside the engine; its static order here (and
    /// Portfolio's nominal HEFT order) only seeds resume paths and
    /// debug topology checks.
    pub fn rank_order(self, wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
        match self {
            Algorithm::Heft | Algorithm::HeftmBl | Algorithm::Lookahead | Algorithm::Portfolio => {
                ranking::rank_bl(wf, cluster)
            }
            Algorithm::HeftmBlc => ranking::rank_blc(wf, cluster),
            Algorithm::HeftmMm => ranking::rank_mm(wf),
            Algorithm::Peft => ranking::rank_peft(wf, cluster),
            Algorithm::Dls => ranking::rank_dls(wf, cluster),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        // Legacy aliases kept from the original four-algorithm CLI.
        let canonical = match lower.as_str() {
            "bl" => "heftm-bl",
            "blc" => "heftm-blc",
            "mm" => "heftm-mm",
            other => other,
        };
        Algorithm::variants()
            .iter()
            .copied()
            .find(|a| a.as_str() == canonical)
            .ok_or_else(|| {
                let names: Vec<&str> = Algorithm::variants().iter().map(|a| a.as_str()).collect();
                anyhow::anyhow!("unknown algorithm `{s}` (expected one of: {})", names.join(", "))
            })
    }
}

/// Measured crossover for pool-parallel tentative scoring, in units of
/// `cluster.len() × mean task fan-in` — the per-task scoring work that
/// the [`ScorePool`] fans out. Below it, dispatch overhead exceeds the
/// win and serial scoring is faster (`bench_engine` is the measuring
/// harness: the paper's 72-processor cluster with chipseq-like fan-in
/// sits comfortably above, the 4–8 processor presets far below).
/// Refresh from a `ci.sh --crossover` run (the dedicated sweep in
/// `bench_engine`, `MEMSCHED_BENCH_CROSSOVER=1`) whenever the scoring
/// loop changes; it prints the measured suggestion for this constant.
pub const SCORE_PARALLEL_CROSSOVER: f64 = 64.0;

/// Adaptive score-thread choice (`--score-threads auto`): serial when
/// the instance sits below [`SCORE_PARALLEL_CROSSOVER`], all cores
/// above it. Schedules are byte-identical either way, so the choice is
/// purely a throughput knob.
pub fn auto_score_threads(wf: &Workflow, cluster: &Cluster) -> usize {
    let mean_fan_in = wf.num_edges() as f64 / wf.num_tasks().max(1) as f64;
    if (cluster.len() as f64) * mean_fan_in < SCORE_PARALLEL_CROSSOVER {
        1
    } else {
        // Deliberately not `service::pool::default_workers()`: the
        // scheduler layer must not depend upward on the service (the
        // two expressions are identical).
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// The one scheduling entrypoint: a builder over (workflow, cluster)
/// with algorithm, eviction policy, and optional parallel scoring.
///
/// ```ignore
/// let s = ScheduleRequest::new(&wf, &cluster)
///     .algo(Algorithm::Peft)
///     .policy(EvictionPolicy::LargestFirst)
///     .score_pool(Some(&pool))
///     .run();
/// ```
///
/// Defaults: `HeftmBl`, `LargestFirst`, serial scoring. The former free
/// functions `compute_schedule` / `compute_schedule_with` are deprecated
/// shims over this builder and produce bit-identical schedules.
#[derive(Clone, Copy)]
pub struct ScheduleRequest<'a> {
    wf: &'a Workflow,
    cluster: &'a Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
    score_pool: Option<&'a ScorePool>,
}

impl<'a> ScheduleRequest<'a> {
    pub fn new(wf: &'a Workflow, cluster: &'a Cluster) -> ScheduleRequest<'a> {
        ScheduleRequest {
            wf,
            cluster,
            algo: Algorithm::HeftmBl,
            policy: EvictionPolicy::LargestFirst,
            score_pool: None,
        }
    }

    pub fn algo(mut self, algo: Algorithm) -> Self {
        self.algo = algo;
        self
    }

    pub fn policy(mut self, policy: EvictionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Fan intra-schedule tentative scoring across `pool`'s workers;
    /// schedules are byte-identical for any thread count (deterministic
    /// reduction — see [`Engine::with_parallel_scoring`]). `None` keeps
    /// serial scoring, so callers can pass an `Option` through.
    pub fn score_pool(mut self, pool: Option<&'a ScorePool>) -> Self {
        self.score_pool = pool;
        self
    }

    /// Compute the schedule (phases 1 + 2).
    pub fn run(&self) -> Schedule {
        if self.algo == Algorithm::Portfolio {
            return self.run_portfolio();
        }
        self.run_single(self.algo)
    }

    fn run_single(&self, algo: Algorithm) -> Schedule {
        let order = algo.rank_order(self.wf, self.cluster);
        let mut engine = Engine::new(self.wf, self.cluster, algo, self.policy);
        if let Some(pool) = self.score_pool {
            engine = engine.with_parallel_scoring(pool);
        }
        engine.run(&order)
    }

    /// Scheduler-layer portfolio: run every standalone algorithm and keep
    /// the analytically best candidate — valid beats invalid, then
    /// minimum makespan, ties to the lowest [`Algorithm::all`] index.
    /// The returned schedule keeps the *winner's* `algorithm` tag so
    /// downstream resume/retrace paths reconstruct the right selector.
    ///
    /// The service layer replaces the analytic criterion with the
    /// simulated (σ = 0 replay) makespan; for valid schedules the two
    /// agree up to simulation modeling of the identical timeline.
    fn run_portfolio(&self) -> Schedule {
        let mut best: Option<Schedule> = None;
        for &algo in Algorithm::all() {
            let s = self.run_single(algo);
            let better = match &best {
                None => true,
                Some(b) => {
                    (s.valid && !b.valid) || (s.valid == b.valid && s.makespan < b.makespan)
                }
            };
            if better {
                best = Some(s);
            }
        }
        best.expect("Algorithm::all() is non-empty")
    }
}

/// Compute a full static schedule (phases 1 + 2).
#[deprecated(since = "0.9.0", note = "use `ScheduleRequest::new(wf, cluster).algo(..).run()`")]
pub fn compute_schedule(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
) -> Schedule {
    ScheduleRequest::new(wf, cluster).algo(algo).policy(policy).run()
}

/// `compute_schedule` with optional intra-schedule parallel scoring.
#[deprecated(
    since = "0.9.0",
    note = "use `ScheduleRequest::new(wf, cluster).algo(..).score_pool(..).run()`"
)]
pub fn compute_schedule_with(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
    score_pool: Option<&ScorePool>,
) -> Schedule {
    ScheduleRequest::new(wf, cluster).algo(algo).policy(policy).score_pool(score_pool).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets;
    use crate::workflow::WorkflowBuilder;

    /// A chain workflow with `extra` additional cross edges, so mean
    /// fan-in is controllable: `(n - 1 + extra) / n` edges per task.
    fn wf_with_edges(n: usize, extra: usize) -> Workflow {
        let mut b = WorkflowBuilder::new("fanin");
        let ids: Vec<_> = (0..n).map(|i| b.task(&format!("t{i}"), "t", 1.0, 1.0)).collect();
        for w in ids.windows(2) {
            b.edge(w[0], w[1], 1.0);
        }
        let mut added = 0;
        'outer: for gap in 2..n {
            for i in 0..n.saturating_sub(gap) {
                if added == extra {
                    break 'outer;
                }
                b.edge(ids[i], ids[i + gap], 1.0);
                added += 1;
            }
        }
        assert_eq!(added, extra, "requested more extra edges than the DAG admits");
        b.build().unwrap()
    }

    #[test]
    fn auto_score_threads_pins_the_crossover() {
        let all_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        // 20 tasks, 19 edges on the 6-processor test cluster:
        // 6 × 0.95 = 5.7, far below the crossover → serial.
        let small = presets::small_cluster();
        assert_eq!(auto_score_threads(&wf_with_edges(20, 0), &small), 1);

        // The paper's 72-processor cluster with fan-in ≥ 1 sits above:
        // 72 × 0.95 = 68.4 ≥ 64 → parallel (all cores).
        let big = presets::default_cluster();
        assert_eq!(auto_score_threads(&wf_with_edges(20, 0), &big), all_cores);

        // Exact boundary arithmetic on the small cluster: 6 procs need
        // mean fan-in ≥ 64/6 ≈ 10.67, i.e. ≥ 534 edges on 50 tasks.
        // 533 edges → 6 × 10.66 = 63.96 < 64 (serial), 534 → 64.08 ≥ 64
        // (parallel); the constant itself is pinned so accidental
        // retuning fails loudly.
        assert_eq!(SCORE_PARALLEL_CROSSOVER, 64.0);
        assert_eq!(auto_score_threads(&wf_with_edges(50, 533 - 49), &small), 1);
        assert_eq!(auto_score_threads(&wf_with_edges(50, 534 - 49), &small), all_cores);
    }

    #[test]
    fn algorithm_names_round_trip() {
        for &algo in Algorithm::variants() {
            let parsed: Algorithm = algo.as_str().parse().unwrap();
            assert_eq!(parsed, algo, "canonical name must round-trip");
            // Labels are the uppercase rendering of distinct algorithms:
            // parsing a label is not supported, but labels stay unique.
        }
        let labels: std::collections::HashSet<_> =
            Algorithm::variants().iter().map(|a| a.label()).collect();
        assert_eq!(labels.len(), Algorithm::variants().len());
        let names: std::collections::HashSet<_> =
            Algorithm::variants().iter().map(|a| a.as_str()).collect();
        assert_eq!(names.len(), Algorithm::variants().len());
        // Legacy aliases still parse.
        assert_eq!("bl".parse::<Algorithm>().unwrap(), Algorithm::HeftmBl);
        assert_eq!("blc".parse::<Algorithm>().unwrap(), Algorithm::HeftmBlc);
        assert_eq!("mm".parse::<Algorithm>().unwrap(), Algorithm::HeftmMm);
        // Unknown names produce an error naming the full registry.
        let err = "definitely-not-an-algo".parse::<Algorithm>().unwrap_err().to_string();
        assert!(err.contains("portfolio") && err.contains("peft"), "{err}");
        // HEFT leads `all()` (experiment normalization depends on it) and
        // Portfolio is not a standalone candidate.
        assert_eq!(Algorithm::all()[0], Algorithm::Heft);
        assert!(!Algorithm::all().contains(&Algorithm::Portfolio));
        assert_eq!(Algorithm::variants().len(), Algorithm::all().len() + 1);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_builder_bitwise() {
        let wf = wf_with_edges(40, 25);
        let cluster = presets::small_cluster();
        for &algo in Algorithm::variants() {
            let via_builder =
                ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            let via_shim = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
            let via_shim_with =
                compute_schedule_with(&wf, &cluster, algo, EvictionPolicy::LargestFirst, None);
            for other in [&via_shim, &via_shim_with] {
                assert_eq!(via_builder.algorithm, other.algorithm, "{algo:?}");
                assert_eq!(via_builder.rank_order, other.rank_order, "{algo:?}");
                assert_eq!(via_builder.tasks, other.tasks, "{algo:?}");
                assert_eq!(via_builder.makespan.to_bits(), other.makespan.to_bits(), "{algo:?}");
            }
        }
    }

    #[test]
    fn portfolio_commits_an_all_candidate() {
        let wf = wf_with_edges(30, 10);
        let cluster = presets::small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Portfolio).run();
        // The winner carries its own algorithm tag, never Portfolio.
        assert!(Algorithm::all().contains(&s.algorithm));
        // Analytic argmin: no standalone candidate beats the winner.
        for &algo in Algorithm::all() {
            let c = ScheduleRequest::new(&wf, &cluster).algo(algo).run();
            if c.valid == s.valid {
                assert!(s.makespan <= c.makespan + 1e-9, "{algo:?} beat the portfolio");
            }
        }
    }
}
