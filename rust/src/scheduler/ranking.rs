//! Task prioritization (phase 1 of HEFT / HEFTM, §IV).
//!
//! - `bl(u)`  — bottom level: `w_u + max_{(u,v)} (c_{u,v} + bl(v))`
//!   (HEFT and HEFTM-BL);
//! - `blc(u)` — bottom level with communications: `bl`'s recursion plus
//!   `max_{(v,u)} c_{v,u}`, prioritizing tasks with large incoming files
//!   so their inputs leave memory sooner (HEFTM-BLC);
//! - MM       — the MemDag minimum-peak-memory traversal order ([19],
//!   HEFTM-MM).
//!
//! Units: the paper states the recursions over raw `w_u` and `c_{u,v}`;
//! with real traces these have incompatible units (operations vs bytes),
//! so — as in reference HEFT implementations — both are converted to
//! *time*: `w_u / s̄` (mean processor speed) and `c_{u,v} / β`. This keeps
//! the priority semantics while making the sum well-defined.

use crate::platform::Cluster;
use crate::workflow::{TaskId, Workflow};

/// Bottom levels `bl(u)` in time units.
pub fn bottom_levels(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let s = cluster.mean_speed();
    let beta = cluster.bandwidth;
    let order = wf.topological_order();
    let mut bl = vec![0.0f64; wf.num_tasks()];
    for &u in order.iter().rev() {
        let mut best = 0.0f64;
        for (v, c) in wf.children(u) {
            best = best.max(c / beta + bl[v]);
        }
        bl[u] = wf.task(u).work / s + best;
    }
    bl
}

/// Bottom levels with communications `blc(u)` in time units.
pub fn bottom_levels_comm(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let s = cluster.mean_speed();
    let beta = cluster.bandwidth;
    let order = wf.topological_order();
    let mut blc = vec![0.0f64; wf.num_tasks()];
    for &u in order.iter().rev() {
        let mut best = 0.0f64;
        for (v, c) in wf.children(u) {
            best = best.max(c / beta + blc[v]);
        }
        let max_in = wf.parents(u).map(|(_, c)| c / beta).fold(0.0, f64::max);
        blc[u] = wf.task(u).work / s + best + max_in;
    }
    blc
}

/// Order tasks by non-increasing key, stably over a topological base
/// order. Because `key(parent) ≥ key(child)` for bottom-level-style keys,
/// stability guarantees the result remains topological even with ties.
pub fn order_by_key_desc(wf: &Workflow, key: &[f64]) -> Vec<TaskId> {
    let mut order = wf.topological_order();
    order.sort_by(|&a, &b| key[b].partial_cmp(&key[a]).unwrap_or(std::cmp::Ordering::Equal));
    debug_assert!(wf.is_topological_order(&order), "rank order must stay topological");
    order
}

/// Rank order for HEFT / HEFTM-BL.
pub fn rank_bl(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    order_by_key_desc(wf, &bottom_levels(wf, cluster))
}

/// Rank order for HEFTM-BLC.
pub fn rank_blc(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    order_by_key_desc(wf, &bottom_levels_comm(wf, cluster))
}

/// Rank order for HEFTM-MM: the MemDag traversal.
pub fn rank_mm(wf: &Workflow) -> Vec<TaskId> {
    crate::memdag::min_memory_traversal(wf).order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::workflow::WorkflowBuilder;

    fn wf() -> Workflow {
        // 0 -> 1 -> 3, 0 -> 2 -> 3; task 1 heavier than 2.
        let mut b = WorkflowBuilder::new("t");
        let t0 = b.task("t0", "t", 10.0, 1.0);
        let t1 = b.task("t1", "t", 50.0, 1.0);
        let t2 = b.task("t2", "t", 5.0, 1.0);
        let t3 = b.task("t3", "t", 10.0, 1.0);
        b.edge(t0, t1, 1e9);
        b.edge(t0, t2, 1e9);
        b.edge(t1, t3, 1e9);
        b.edge(t2, t3, 2e9);
        b.build().unwrap()
    }

    #[test]
    fn bl_monotone_along_paths() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        // Parent strictly larger than each child (positive works).
        for e in wf.edges() {
            assert!(bl[e.src] > bl[e.dst], "bl[{}] vs bl[{}]", e.src, e.dst);
        }
        // Sink bottom level = its own execution time.
        assert!((bl[3] - 10.0 / cluster.mean_speed()).abs() < 1e-12);
    }

    #[test]
    fn bl_picks_heavier_branch() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        assert!(bl[1] > bl[2]);
        let order = rank_bl(&wf, &cluster);
        assert!(wf.is_topological_order(&order));
        let pos = |t: usize| order.iter().position(|&x| x == t).unwrap();
        assert!(pos(1) < pos(2));
    }

    #[test]
    fn blc_adds_incoming_comm() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        let blc = bottom_levels_comm(&wf, &cluster);
        // Source has no incoming edges: blc accumulates children's blc
        // which are larger, so blc >= bl everywhere.
        for u in 0..wf.num_tasks() {
            assert!(blc[u] >= bl[u] - 1e-12);
        }
        // Task 3's blc exceeds its bl by max incoming comm (2e9 / beta).
        let beta = cluster.bandwidth;
        assert!((blc[3] - bl[3] - 2e9 / beta).abs() < 1e-6);
    }

    #[test]
    fn rank_orders_topological() {
        let wf = wf();
        let cluster = small_cluster();
        assert!(wf.is_topological_order(&rank_bl(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_blc(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_mm(&wf)));
    }

    #[test]
    fn ties_preserve_topology() {
        // All-zero works and comms: every bl = 0; stability must keep a
        // topological order.
        let mut b = WorkflowBuilder::new("z");
        let ids: Vec<_> = (0..6).map(|i| b.task(format!("t{i}"), "t", 0.0, 0.0)).collect();
        b.edge(ids[0], ids[3], 0.0);
        b.edge(ids[3], ids[1], 0.0);
        b.edge(ids[1], ids[5], 0.0);
        b.edge(ids[0], ids[4], 0.0);
        let wf = b.build().unwrap();
        let cluster = small_cluster();
        assert!(wf.is_topological_order(&rank_bl(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_blc(&wf, &cluster)));
    }
}
