//! Task prioritization (phase 1 of HEFT / HEFTM, §IV).
//!
//! - `bl(u)`  — bottom level: `w_u + max_{(u,v)} (c_{u,v} + bl(v))`
//!   (HEFT and HEFTM-BL);
//! - `blc(u)` — bottom level with communications: `bl`'s recursion plus
//!   `max_{(v,u)} c_{v,u}`, prioritizing tasks with large incoming files
//!   so their inputs leave memory sooner (HEFTM-BLC);
//! - MM       — the MemDag minimum-peak-memory traversal order ([19],
//!   HEFTM-MM).
//!
//! Units: the paper states the recursions over raw `w_u` and `c_{u,v}`;
//! with real traces these have incompatible units (operations vs bytes),
//! so — as in reference HEFT implementations — both are converted to
//! *time*: `w_u / s̄` (mean processor speed) and `c_{u,v} / β`. This keeps
//! the priority semantics while making the sum well-defined.

use crate::platform::Cluster;
use crate::workflow::{TaskId, Workflow};

#[cfg(test)]
thread_local! {
    /// Per-thread count of [`oct_table`] builds. Thread-local (not a
    /// global atomic) so concurrently running tests cannot perturb each
    /// other's deltas; the recompute fast-path tests pin that a scaffold
    /// builds PEFT's table exactly once however many triggers it serves.
    pub static OCT_BUILDS: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Bottom levels `bl(u)` in time units.
pub fn bottom_levels(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let s = cluster.mean_speed();
    let beta = cluster.bandwidth;
    let order = wf.topological_order();
    let mut bl = vec![0.0f64; wf.num_tasks()];
    for &u in order.iter().rev() {
        let mut best = 0.0f64;
        for (v, c) in wf.children(u) {
            best = best.max(c / beta + bl[v]);
        }
        bl[u] = wf.task(u).work / s + best;
    }
    bl
}

/// Bottom levels with communications `blc(u)` in time units.
pub fn bottom_levels_comm(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let s = cluster.mean_speed();
    let beta = cluster.bandwidth;
    let order = wf.topological_order();
    let mut blc = vec![0.0f64; wf.num_tasks()];
    for &u in order.iter().rev() {
        let mut best = 0.0f64;
        for (v, c) in wf.children(u) {
            best = best.max(c / beta + blc[v]);
        }
        let max_in = wf.parents(u).map(|(_, c)| c / beta).fold(0.0, f64::max);
        blc[u] = wf.task(u).work / s + best + max_in;
    }
    blc
}

/// Order tasks by non-increasing key, stably over a topological base
/// order. Because `key(parent) ≥ key(child)` for bottom-level-style keys,
/// stability guarantees the result remains topological even with ties.
pub fn order_by_key_desc(wf: &Workflow, key: &[f64]) -> Vec<TaskId> {
    let mut order = wf.topological_order();
    order.sort_by(|&a, &b| key[b].partial_cmp(&key[a]).unwrap_or(std::cmp::Ordering::Equal));
    debug_assert!(wf.is_topological_order(&order), "rank order must stay topological");
    order
}

/// Rank order for HEFT / HEFTM-BL.
pub fn rank_bl(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    order_by_key_desc(wf, &bottom_levels(wf, cluster))
}

/// Rank order for HEFTM-BLC.
pub fn rank_blc(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    order_by_key_desc(wf, &bottom_levels_comm(wf, cluster))
}

/// Rank order for HEFTM-MM: the MemDag traversal.
pub fn rank_mm(wf: &Workflow) -> Vec<TaskId> {
    crate::memdag::min_memory_traversal(wf).order
}

/// Finite `f64` priority for the ready-list heap below: total order via
/// `partial_cmp` (keys are finite by construction — works and speeds are
/// finite, comm times are finite).
#[derive(PartialEq, PartialOrd)]
struct Priority(f64);

impl Eq for Priority {}
impl Ord for Priority {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Deterministic priority-list topological order: repeatedly emit the
/// *ready* task with the largest key, ties to the lowest task id.
///
/// Unlike [`order_by_key_desc`], this never relies on
/// `key(parent) ≥ key(child)` along edges — a property bottom-level keys
/// have but PEFT's average-OCT rank and DLS static levels do not (on
/// heterogeneous speeds the averages are not monotone along edges), so a
/// plain stable sort could emit a child before its parent.
pub fn priority_topo_order(wf: &Workflow, key: &[f64]) -> Vec<TaskId> {
    let n = wf.num_tasks();
    let mut missing: Vec<usize> = (0..n).map(|v| wf.parents(v).count()).collect();
    let mut heap: std::collections::BinaryHeap<(Priority, std::cmp::Reverse<TaskId>)> =
        (0..n).filter(|&v| missing[v] == 0).map(|v| (Priority(key[v]), std::cmp::Reverse(v))).collect();
    let mut order = Vec::with_capacity(n);
    while let Some((_, std::cmp::Reverse(v))) = heap.pop() {
        order.push(v);
        for (c, _) in wf.children(v) {
            missing[c] -= 1;
            if missing[c] == 0 {
                heap.push((Priority(key[c]), std::cmp::Reverse(c)));
            }
        }
    }
    debug_assert_eq!(order.len(), n, "workflow must be acyclic");
    debug_assert!(wf.is_topological_order(&order));
    order
}

/// PEFT's optimistic cost table, row-major `n × k`: `oct[v·k + j]` is the
/// optimistic remaining time *after* `v` finishes on processor `j` — the
/// worst child's best-case completion chain,
///
/// `OCT(v, j) = max_c min_q [ OCT(c, q) + w_c/s_q + (q ≠ j ? c_{v,c}/β : 0) ]`,
///
/// recursing to 0 at sinks. Dense row-major layout so the engine's
/// per-processor selection key reads `oct[v*k + j]` with unit stride.
pub fn oct_table(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    #[cfg(test)]
    OCT_BUILDS.with(|c| c.set(c.get() + 1));
    let n = wf.num_tasks();
    let k = cluster.len();
    let beta = cluster.bandwidth;
    let order = wf.topological_order();
    let mut oct = vec![0.0f64; n * k];
    for &u in order.iter().rev() {
        for j in 0..k {
            let mut worst = 0.0f64;
            for (c, data) in wf.children(u) {
                let mut best = f64::INFINITY;
                for q in 0..k {
                    let comm = if q == j { 0.0 } else { data / beta };
                    let cost = oct[c * k + q] + cluster.exec_time(wf.task(c).work, q) + comm;
                    if cost < best {
                        best = cost;
                    }
                }
                if best > worst {
                    worst = best;
                }
            }
            oct[u * k + j] = worst;
        }
    }
    oct
}

/// PEFT ranks: the per-task average of [`oct_table`]'s rows.
pub fn oct_ranks(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let k = cluster.len();
    let oct = oct_table(wf, cluster);
    (0..wf.num_tasks()).map(|v| oct[v * k..(v + 1) * k].iter().sum::<f64>() / k as f64).collect()
}

/// Rank order for PEFT: priority-list order by average OCT.
pub fn rank_peft(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    priority_topo_order(wf, &oct_ranks(wf, cluster))
}

/// DLS static levels: `SL(v) = w_v/s̄ + max_c SL(c)` — the bottom level
/// *without* communication terms (Sih & Lee's definition, converted to
/// time over the mean speed like the other ranks).
pub fn static_levels(wf: &Workflow, cluster: &Cluster) -> Vec<f64> {
    let s = cluster.mean_speed();
    let order = wf.topological_order();
    let mut sl = vec![0.0f64; wf.num_tasks()];
    for &u in order.iter().rev() {
        let mut best = 0.0f64;
        for (v, _) in wf.children(u) {
            best = best.max(sl[v]);
        }
        sl[u] = wf.task(u).work / s + best;
    }
    sl
}

/// Nominal rank order for DLS: priority-list order by static level. The
/// engine re-ranks dynamically at every step; this order seeds resume
/// paths and the topological debug check only.
pub fn rank_dls(wf: &Workflow, cluster: &Cluster) -> Vec<TaskId> {
    priority_topo_order(wf, &static_levels(wf, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::workflow::WorkflowBuilder;

    fn wf() -> Workflow {
        // 0 -> 1 -> 3, 0 -> 2 -> 3; task 1 heavier than 2.
        let mut b = WorkflowBuilder::new("t");
        let t0 = b.task("t0", "t", 10.0, 1.0);
        let t1 = b.task("t1", "t", 50.0, 1.0);
        let t2 = b.task("t2", "t", 5.0, 1.0);
        let t3 = b.task("t3", "t", 10.0, 1.0);
        b.edge(t0, t1, 1e9);
        b.edge(t0, t2, 1e9);
        b.edge(t1, t3, 1e9);
        b.edge(t2, t3, 2e9);
        b.build().unwrap()
    }

    #[test]
    fn bl_monotone_along_paths() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        // Parent strictly larger than each child (positive works).
        for e in wf.edges() {
            assert!(bl[e.src] > bl[e.dst], "bl[{}] vs bl[{}]", e.src, e.dst);
        }
        // Sink bottom level = its own execution time.
        assert!((bl[3] - 10.0 / cluster.mean_speed()).abs() < 1e-12);
    }

    #[test]
    fn bl_picks_heavier_branch() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        assert!(bl[1] > bl[2]);
        let order = rank_bl(&wf, &cluster);
        assert!(wf.is_topological_order(&order));
        let pos = |t: usize| order.iter().position(|&x| x == t).unwrap();
        assert!(pos(1) < pos(2));
    }

    #[test]
    fn blc_adds_incoming_comm() {
        let wf = wf();
        let cluster = small_cluster();
        let bl = bottom_levels(&wf, &cluster);
        let blc = bottom_levels_comm(&wf, &cluster);
        // Source has no incoming edges: blc accumulates children's blc
        // which are larger, so blc >= bl everywhere.
        for u in 0..wf.num_tasks() {
            assert!(blc[u] >= bl[u] - 1e-12);
        }
        // Task 3's blc exceeds its bl by max incoming comm (2e9 / beta).
        let beta = cluster.bandwidth;
        assert!((blc[3] - bl[3] - 2e9 / beta).abs() < 1e-6);
    }

    #[test]
    fn rank_orders_topological() {
        let wf = wf();
        let cluster = small_cluster();
        assert!(wf.is_topological_order(&rank_bl(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_blc(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_mm(&wf)));
    }

    #[test]
    fn oct_table_and_peft_rank() {
        let wf = wf();
        let cluster = small_cluster();
        let k = cluster.len();
        let oct = oct_table(&wf, &cluster);
        // Sinks have zero OCT on every processor.
        assert!(oct[3 * k..4 * k].iter().all(|&x| x == 0.0));
        // Non-sinks are strictly positive (children have positive work).
        for v in 0..3 {
            assert!(oct[v * k..(v + 1) * k].iter().all(|&x| x > 0.0), "task {v}");
        }
        // OCT of a parent dominates the child's best-case chain: for any
        // j, OCT(0, j) ≥ min_q (OCT(1, q) + w_1/s_q) (comm ≥ 0).
        let best_child: f64 = (0..k)
            .map(|q| oct[k + q] + cluster.exec_time(wf.task(1).work, q))
            .fold(f64::INFINITY, f64::min);
        for j in 0..k {
            assert!(oct[j] + 1e-9 >= best_child);
        }
        let order = rank_peft(&wf, &cluster);
        assert!(wf.is_topological_order(&order));
    }

    #[test]
    fn static_levels_and_dls_rank() {
        let wf = wf();
        let cluster = small_cluster();
        let sl = static_levels(&wf, &cluster);
        let bl = bottom_levels(&wf, &cluster);
        // SL is bl without comm terms: never larger, monotone along edges.
        for u in 0..wf.num_tasks() {
            assert!(sl[u] <= bl[u] + 1e-12);
        }
        for e in wf.edges() {
            assert!(sl[e.src] > sl[e.dst]);
        }
        assert!(wf.is_topological_order(&rank_dls(&wf, &cluster)));
    }

    #[test]
    fn priority_topo_order_handles_non_monotone_keys() {
        // Keys *inverted* along every edge: a plain descending sort would
        // emit children first; the ready-list order must stay topological
        // and, within the ready set, prefer the largest key.
        let wf = wf();
        let inverted: Vec<f64> = (0..wf.num_tasks()).map(|v| v as f64).collect();
        let order = priority_topo_order(&wf, &inverted);
        assert!(wf.is_topological_order(&order));
        // After the source, tasks 1 and 2 are both ready: 2 has the
        // larger key and must come first.
        assert_eq!(order, vec![0, 2, 1, 3]);
    }

    #[test]
    fn ties_preserve_topology() {
        // All-zero works and comms: every bl = 0; stability must keep a
        // topological order.
        let mut b = WorkflowBuilder::new("z");
        let ids: Vec<_> = (0..6).map(|i| b.task(format!("t{i}"), "t", 0.0, 0.0)).collect();
        b.edge(ids[0], ids[3], 0.0);
        b.edge(ids[3], ids[1], 0.0);
        b.edge(ids[1], ids[5], 0.0);
        b.edge(ids[0], ids[4], 0.0);
        let wf = b.build().unwrap();
        let cluster = small_cluster();
        assert!(wf.is_topological_order(&rank_bl(&wf, &cluster)));
        assert!(wf.is_topological_order(&rank_blc(&wf, &cluster)));
    }
}
