//! Schedule retracing (paper §V): assess the impact of reported parameter
//! changes on an existing schedule *without* re-deciding placements.
//!
//! Tasks are walked in the original rank order (topological). For each
//! task the memory residual `Res` is re-evaluated on its committed
//! processor under the updated parameters:
//!
//! - a task whose original placement needed no eviction (`Res ≥ 0`) must
//!   still satisfy `Res ≥ 0` — new evictions could invalidate later tasks;
//! - a task that originally evicted may evict again, but the files must
//!   still fit into the communication buffer;
//! - start/finish times are recomputed (Step 3) with the updated execution
//!   times and channel ready times.
//!
//! If a processor hosting tasks was lost, the schedule is invalid
//! immediately.

use super::engine::{Engine, Failure, Schedule, TaskSchedule};
use super::state::EvictionPolicy;
use crate::platform::{Cluster, ProcId};
use crate::workflow::{TaskId, Workflow};

/// Outcome of retracing a schedule against updated task parameters.
#[derive(Debug, Clone)]
pub struct RetraceResult {
    /// Whether the schedule survives the deviations.
    pub valid: bool,
    /// First violation, if any.
    pub failure: Option<Failure>,
    /// Task id at which retracing stopped (first violation).
    pub failed_task: Option<TaskId>,
    /// Updated placements (complete only if `valid`).
    pub tasks: Vec<Option<TaskSchedule>>,
    /// Updated makespan over the retraced prefix.
    pub makespan: f64,
}

/// Retrace `schedule` against the (deviated) workflow `wf`.
///
/// `wf` must have the same DAG structure as the workflow the schedule was
/// computed from; only the weights (`w`, `m`, `c`) may differ.
/// `lost_procs` lists processors that terminated since scheduling.
pub fn retrace(
    wf: &Workflow,
    cluster: &Cluster,
    schedule: &Schedule,
    policy: EvictionPolicy,
    lost_procs: &[ProcId],
) -> RetraceResult {
    // Processor loss check (§V): any assigned task on a lost processor
    // invalidates the schedule outright.
    if !lost_procs.is_empty() {
        for (v, t) in schedule.tasks.iter().enumerate() {
            if lost_procs.contains(&t.proc) {
                return RetraceResult {
                    valid: false,
                    failure: Some(Failure::ProcessorLost { task: v, proc: t.proc }),
                    failed_task: Some(v),
                    tasks: vec![None; wf.num_tasks()],
                    makespan: 0.0,
                };
            }
        }
    }

    let mut engine = Engine::new(wf, cluster, schedule.algorithm, policy);
    let mut makespan = 0.0f64;
    for &v in &schedule.rank_order {
        let orig = &schedule.tasks[v];
        // Paper rule: originally-nonnegative residual must stay so.
        match engine.place_forced(v, orig.proc, !orig.res_nonneg) {
            Ok(t) => makespan = makespan.max(t.finish),
            Err(f) => {
                return RetraceResult {
                    valid: false,
                    failure: Some(f),
                    failed_task: Some(v),
                    tasks: engine.placements().to_vec(),
                    makespan,
                };
            }
        }
    }
    RetraceResult {
        valid: true,
        failure: None,
        failed_task: None,
        tasks: engine.placements().to_vec(),
        makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
    use crate::workflow::{Workflow, WorkflowBuilder};

    fn sample_wf() -> Workflow {
        let model = crate::generator::models::atacseq();
        let wf = crate::generator::expand(&model, 6).unwrap();
        let data = crate::traces::HistoricalData::synthesize(
            &crate::traces::task_types(&wf),
            &crate::traces::TraceConfig::default(),
            3,
        );
        crate::traces::bind_weights(&wf, &data, 1)
    }

    /// Scale all task works by `f` (structure preserved).
    fn scale_works(wf: &Workflow, f: f64) -> Workflow {
        let mut b = WorkflowBuilder::new(&wf.name);
        for t in wf.tasks() {
            b.task(&t.name, &t.task_type, t.work * f, t.memory);
        }
        for e in wf.edges() {
            b.edge(e.src, e.dst, e.data);
        }
        b.build().unwrap()
    }

    /// Scale all task memories by `f`.
    fn scale_mems(wf: &Workflow, f: f64) -> Workflow {
        let mut b = WorkflowBuilder::new(&wf.name);
        for t in wf.tasks() {
            b.task(&t.name, &t.task_type, t.work, t.memory * f);
        }
        for e in wf.edges() {
            b.edge(e.src, e.dst, e.data);
        }
        b.build().unwrap()
    }

    #[test]
    fn identity_retrace_reproduces_schedule() {
        let wf = sample_wf();
        let cluster = small_cluster();
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            assert!(s.valid, "{algo:?}");
            let r = retrace(&wf, &cluster, &s, EvictionPolicy::LargestFirst, &[]);
            assert!(r.valid, "{algo:?}: {:?}", r.failure);
            assert!((r.makespan - s.makespan).abs() < 1e-6 * s.makespan.max(1.0));
            for (v, t) in s.tasks.iter().enumerate() {
                let rt = r.tasks[v].as_ref().unwrap();
                assert_eq!(rt.proc, t.proc);
                assert!((rt.finish - t.finish).abs() < 1e-9 * t.finish.max(1.0));
            }
        }
    }

    #[test]
    fn longer_tasks_delay_makespan_but_stay_valid() {
        let wf = sample_wf();
        let cluster = small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let slower = scale_works(&wf, 1.5);
        let r = retrace(&slower, &cluster, &s, EvictionPolicy::LargestFirst, &[]);
        assert!(r.valid, "{:?}", r.failure);
        assert!(r.makespan > s.makespan);
    }

    #[test]
    fn memory_blowup_invalidates() {
        let wf = sample_wf();
        let cluster = small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        // 50× memory cannot fit anywhere.
        let heavy = scale_mems(&wf, 50.0);
        let r = retrace(&heavy, &cluster, &s, EvictionPolicy::LargestFirst, &[]);
        assert!(!r.valid);
        assert!(r.failed_task.is_some());
    }

    #[test]
    fn lost_processor_invalidates() {
        let wf = sample_wf();
        let cluster = small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let used_proc = s.tasks[0].proc;
        let r = retrace(&wf, &cluster, &s, EvictionPolicy::LargestFirst, &[used_proc]);
        assert!(!r.valid);
        // The loss is reported as such, not misfiled as an OOM, and the
        // failure names the lost processor.
        assert!(
            matches!(r.failure, Some(Failure::ProcessorLost { proc, .. }) if proc == used_proc),
            "{:?}",
            r.failure
        );
        // A processor nobody uses does not invalidate.
        let unused: Vec<usize> =
            (0..cluster.len()).filter(|j| s.tasks.iter().all(|t| t.proc != *j)).collect();
        if let Some(&j) = unused.first() {
            let r2 = retrace(&wf, &cluster, &s, EvictionPolicy::LargestFirst, &[j]);
            assert!(r2.valid);
        }
    }

    #[test]
    fn small_deviation_usually_survives() {
        let wf = sample_wf();
        let cluster = small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        // ±3% memory deviation: plenty of slack on the default-ish cluster.
        let wobble = scale_mems(&wf, 1.03);
        let r = retrace(&wobble, &cluster, &s, EvictionPolicy::LargestFirst, &[]);
        assert!(r.valid, "{:?}", r.failure);
    }
}
