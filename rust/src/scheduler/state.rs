//! Mutable platform state used during schedule construction (§III-B).
//!
//! Tracks, per processor `p_j`: the ready time `rt_j`, available memory
//! `availM_j`, available communication-buffer space `availC_j`, and the
//! pending-data set `PD_j` (files produced on `p_j` and still resident in
//! its memory). Additionally the pairwise communication-channel ready
//! times `rt_{j,j'}` and the set of files evicted into each processor's
//! communication buffer.
//!
//! Files are identified by their [`EdgeId`]: each edge `(u, v)` is one
//! file of size `c_{u,v}`.

use crate::platform::{Cluster, ProcId};
use crate::workflow::EdgeId;
use std::collections::HashMap;

/// Deterministic single-multiply hasher for [`EdgeId`] keys.
///
/// Pending-set probes sit on the replay fast path (every simulated
/// start/finish event probes or mutates `PD_j`), and the keys are small
/// dense integers — SipHash's DoS resistance buys nothing here while
/// costing a full round per lookup. A Fibonacci multiply spreads the
/// low bits across the word in one instruction. Map *iteration order*
/// changes with the hasher, but the only iterating consumers
/// ([`PendingSet::iter`] via [`PendingSet::candidates`]) fully sort by
/// `(size, edge id)` before use, so every observable byte of scheduler
/// and simulator output is unaffected.
#[derive(Debug, Clone, Copy, Default)]
pub struct EdgeIdHasher(u64);

impl std::hash::Hasher for EdgeIdHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        // Generic fallback (EdgeId keys take the integer paths): FNV-1a.
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    fn write_u64(&mut self, n: u64) {
        self.0 = (self.0 ^ n).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }

    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

type EdgeIdBuildHasher = std::hash::BuildHasherDefault<EdgeIdHasher>;

/// Pending-data set `PD_j`: files resident in a processor's memory.
#[derive(Debug, Clone, Default)]
pub struct PendingSet {
    files: HashMap<EdgeId, f64, EdgeIdBuildHasher>,
    total: f64,
}

impl PendingSet {
    pub fn contains(&self, e: EdgeId) -> bool {
        self.files.contains_key(&e)
    }

    /// Size of a pending file, if present.
    pub fn get(&self, e: EdgeId) -> Option<f64> {
        self.files.get(&e).copied()
    }

    pub fn insert(&mut self, e: EdgeId, size: f64) {
        debug_assert!(!self.files.contains_key(&e), "file {e} already pending");
        self.files.insert(e, size);
        self.total += size;
    }

    /// Remove every file, keeping the map's allocation (the simulator's
    /// run arena resets pending sets in place between replay points).
    pub fn clear(&mut self) {
        self.files.clear();
        self.total = 0.0;
    }

    /// Overwrite this set with `other`'s contents, reusing the map
    /// allocation (`HashMap::clone_from` keeps capacity). The adaptive
    /// recompute path refills platform snapshots in place per trigger;
    /// iteration order may differ from a fresh clone, but every
    /// consumer sorts before use (see [`EdgeIdHasher`]), so outcomes
    /// are byte-identical.
    pub fn clone_from_set(&mut self, other: &PendingSet) {
        self.files.clone_from(&other.files);
        self.total = other.total;
    }

    /// Remove a file; returns its size if present.
    pub fn remove(&mut self, e: EdgeId) -> Option<f64> {
        let size = self.files.remove(&e)?;
        self.total -= size;
        Some(size)
    }

    pub fn total_size(&self) -> f64 {
        self.total
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (EdgeId, f64)> + '_ {
        self.files.iter().map(|(&e, &s)| (e, s))
    }

    /// Eviction candidates sorted by the given policy (deterministic:
    /// size, then edge id).
    pub fn candidates(&self, policy: EvictionPolicy) -> Vec<(EdgeId, f64)> {
        let mut v: Vec<(EdgeId, f64)> = self.files.iter().map(|(&e, &s)| (e, s)).collect();
        match policy {
            EvictionPolicy::LargestFirst => {
                v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)))
            }
            EvictionPolicy::SmallestFirst => {
                v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)))
            }
        }
        v
    }
}

/// Order in which pending files are evicted when memory is short (§IV-B
/// Step 2). The paper evaluates both and reports no significant difference;
/// `LargestFirst` is the default used in its experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionPolicy {
    #[default]
    LargestFirst,
    SmallestFirst,
}

impl std::str::FromStr for EvictionPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "largest" | "largest-first" => Ok(EvictionPolicy::LargestFirst),
            "smallest" | "smallest-first" => Ok(EvictionPolicy::SmallestFirst),
            other => anyhow::bail!("unknown eviction policy `{other}`"),
        }
    }
}

/// Per-processor cache of eviction candidates sorted by policy.
///
/// `PD_j` only changes on commits, while tentative assignment consults
/// the sorted view once per (task, processor) — caching turns
/// O(tasks · procs · |PD| log |PD|) sorting into O(commits · |PD| log |PD|).
///
/// Unlike its `Rc<RefCell<…>>` predecessor this cache is `Sync`: each
/// cell is a [`OnceLock`](std::sync::OnceLock), so read-only scoring
/// contexts ([`super::engine::ScoringCtx`]) can fill cells from pool
/// workers in parallel, while invalidation ([`EvictCache::invalidate`])
/// requires `&mut self` and therefore only happens in the
/// single-threaded commit phase.
#[derive(Debug, Default)]
pub struct EvictCache {
    cells: Vec<std::sync::OnceLock<Vec<(EdgeId, f64)>>>,
}

impl EvictCache {
    /// An empty cache with one cell per processor.
    pub fn new(num_procs: usize) -> EvictCache {
        EvictCache { cells: (0..num_procs).map(|_| std::sync::OnceLock::new()).collect() }
    }

    /// Sorted candidates of `p_j`, computed from `pending` on first use
    /// and cached until [`invalidate`](EvictCache::invalidate)d.
    pub fn sorted(&self, j: ProcId, pending: &PendingSet, policy: EvictionPolicy) -> &[(EdgeId, f64)] {
        self.cells[j].get_or_init(|| pending.candidates(policy))
    }

    /// Drop `p_j`'s cached view (its pending set is about to change).
    pub fn invalidate(&mut self, j: ProcId) {
        self.cells[j].take();
    }
}

/// Per-processor state.
#[derive(Debug, Clone)]
pub struct ProcState {
    /// `rt_j`: time at which the processor becomes free.
    pub ready_time: f64,
    /// `availM_j`: free memory. May go negative only for the
    /// memory-oblivious HEFT baseline (used to measure its overcommit).
    pub avail_mem: f64,
    /// `availC_j`: free communication-buffer space.
    pub avail_buf: f64,
    /// `PD_j`: files resident in memory (evictable unless needed).
    pub pending: PendingSet,
    /// Files evicted into the communication buffer.
    pub buffered: PendingSet,
    /// High-water mark of memory usage (bytes, includes transients).
    pub peak_used: f64,
}

/// Full platform state: one [`ProcState`] per processor plus the pairwise
/// communication-channel ready times `rt_{j,j'}` (row-major `k × k`).
#[derive(Debug, Clone)]
pub struct PlatformState {
    pub procs: Vec<ProcState>,
    comm_rt: Vec<f64>,
    k: usize,
}

impl PlatformState {
    /// Fresh state: empty memories, all ready times zero.
    pub fn new(cluster: &Cluster) -> PlatformState {
        let procs = cluster
            .processors
            .iter()
            .map(|p| ProcState {
                ready_time: 0.0,
                avail_mem: p.memory,
                avail_buf: p.comm_buffer,
                pending: PendingSet::default(),
                buffered: PendingSet::default(),
                peak_used: 0.0,
            })
            .collect();
        let k = cluster.len();
        PlatformState { procs, comm_rt: vec![0.0; k * k], k }
    }

    /// Restore the fresh state of [`PlatformState::new`] in place,
    /// reusing every allocation (per-proc pending/buffered maps, the
    /// channel matrix). Falls back to a rebuild when the cluster shape
    /// changed — one arena serves heterogeneous sweeps.
    pub fn reset(&mut self, cluster: &Cluster) {
        if self.k != cluster.len() || self.procs.len() != cluster.len() {
            *self = PlatformState::new(cluster);
            return;
        }
        for (ps, p) in self.procs.iter_mut().zip(&cluster.processors) {
            ps.ready_time = 0.0;
            ps.avail_mem = p.memory;
            ps.avail_buf = p.comm_buffer;
            ps.pending.clear();
            ps.buffered.clear();
            ps.peak_used = 0.0;
        }
        self.comm_rt.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn num_procs(&self) -> usize {
        self.k
    }

    /// `rt_{from,to}`: ready time of the communication channel.
    pub fn comm_ready(&self, from: ProcId, to: ProcId) -> f64 {
        self.comm_rt[from * self.k + to]
    }

    /// Advance the channel ready time by `dt` (paper: commit bullet 3).
    pub fn push_comm(&mut self, from: ProcId, to: ProcId, dt: f64) {
        self.comm_rt[from * self.k + to] += dt;
    }

    /// Record a transient memory high-water mark on `j`.
    /// `used` is the absolute usage in bytes during a task's execution.
    pub fn note_usage(&mut self, j: ProcId, used: f64) {
        if used > self.procs[j].peak_used {
            self.procs[j].peak_used = used;
        }
    }

    /// Consume an input file that resides on the *producer's* processor
    /// `j'` (memory or buffer), freeing the corresponding space (paper:
    /// commit bullet 3). No-op if the file is not tracked (e.g. consumed
    /// by a second same-pair edge — cannot happen with unique EdgeIds).
    pub fn consume_remote(&mut self, producer_proc: ProcId, e: EdgeId) {
        let ps = &mut self.procs[producer_proc];
        if let Some(size) = ps.pending.remove(e) {
            ps.avail_mem += size;
        } else if let Some(size) = ps.buffered.remove(e) {
            ps.avail_buf += size;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;

    #[test]
    fn pending_set_accounting() {
        let mut pd = PendingSet::default();
        pd.insert(0, 10.0);
        pd.insert(1, 30.0);
        pd.insert(2, 20.0);
        assert_eq!(pd.total_size(), 60.0);
        assert!(pd.contains(1));
        assert_eq!(pd.remove(1), Some(30.0));
        assert_eq!(pd.remove(1), None);
        assert_eq!(pd.total_size(), 30.0);
        assert_eq!(pd.len(), 2);
        pd.clear();
        assert!(pd.is_empty());
        assert_eq!(pd.total_size(), 0.0);
        // Cleared sets accept re-inserts (arena reuse path).
        pd.insert(1, 5.0);
        assert_eq!(pd.total_size(), 5.0);
    }

    #[test]
    fn eviction_candidate_order() {
        let mut pd = PendingSet::default();
        pd.insert(0, 10.0);
        pd.insert(1, 30.0);
        pd.insert(2, 20.0);
        let largest = pd.candidates(EvictionPolicy::LargestFirst);
        assert_eq!(largest.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 2, 0]);
        let smallest = pd.candidates(EvictionPolicy::SmallestFirst);
        assert_eq!(smallest.iter().map(|x| x.0).collect::<Vec<_>>(), vec![0, 2, 1]);
    }

    #[test]
    fn candidate_tie_break_by_edge_id() {
        let mut pd = PendingSet::default();
        pd.insert(5, 10.0);
        pd.insert(3, 10.0);
        let c = pd.candidates(EvictionPolicy::LargestFirst);
        assert_eq!(c.iter().map(|x| x.0).collect::<Vec<_>>(), vec![3, 5]);
    }

    #[test]
    fn edge_id_hasher_is_deterministic_and_spreads_small_ids() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let bh = BuildHasherDefault::<EdgeIdHasher>::default();
        let h = |n: usize| bh.hash_one(n);
        // Stable across calls (the map's behaviour must not depend on
        // process-level randomness, unlike RandomState).
        assert_eq!(h(42), h(42));
        // Dense small ids — the only keys PendingSet sees — land in
        // distinct, well-spread slots (top bits differ, which is what
        // hashbrown's bucket selection uses).
        let tops: std::collections::HashSet<u64> = (0..1000).map(|n| h(n) >> 48).collect();
        assert!(tops.len() > 900, "only {} distinct top-16-bit patterns", tops.len());
    }

    #[test]
    fn platform_state_init_and_comm() {
        let cluster = small_cluster();
        let mut st = PlatformState::new(&cluster);
        assert_eq!(st.num_procs(), 6);
        assert_eq!(st.procs[0].avail_mem, cluster.proc(0).memory);
        assert_eq!(st.comm_ready(0, 1), 0.0);
        st.push_comm(0, 1, 2.5);
        assert_eq!(st.comm_ready(0, 1), 2.5);
        assert_eq!(st.comm_ready(1, 0), 0.0);
    }

    #[test]
    fn evict_cache_serves_stale_view_until_invalidated() {
        let mut pd = PendingSet::default();
        pd.insert(0, 10.0);
        pd.insert(1, 30.0);
        let mut cache = EvictCache::new(2);
        let first: Vec<_> = cache.sorted(0, &pd, EvictionPolicy::LargestFirst).to_vec();
        assert_eq!(first.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 0]);
        // The cache intentionally ignores pending-set changes until the
        // owning processor is invalidated (commits do that).
        pd.insert(2, 50.0);
        assert_eq!(cache.sorted(0, &pd, EvictionPolicy::LargestFirst), &first[..]);
        // Other processors have independent cells.
        assert_eq!(cache.sorted(1, &pd, EvictionPolicy::LargestFirst).len(), 3);
        cache.invalidate(0);
        assert_eq!(cache.sorted(0, &pd, EvictionPolicy::LargestFirst).len(), 3);
    }

    #[test]
    fn clone_from_set_matches_contents_and_reuses_allocation() {
        let mut src = PendingSet::default();
        src.insert(0, 10.0);
        src.insert(4, 30.0);
        let mut dst = PendingSet::default();
        for e in 0..64 {
            dst.insert(e + 100, 1.0); // force a grown allocation
        }
        dst.clone_from_set(&src);
        assert_eq!(dst.len(), 2);
        assert_eq!(dst.total_size(), 40.0);
        assert_eq!(dst.get(0), Some(10.0));
        assert_eq!(dst.get(4), Some(30.0));
        assert!(!dst.contains(100));
        // Observable behavior (the sorted candidate view) matches a
        // fresh clone exactly.
        assert_eq!(
            dst.candidates(EvictionPolicy::LargestFirst),
            src.clone().candidates(EvictionPolicy::LargestFirst)
        );
    }

    #[test]
    fn platform_state_reset_matches_new() {
        let cluster = small_cluster();
        let mut st = PlatformState::new(&cluster);
        st.procs[0].ready_time = 5.0;
        st.procs[0].avail_mem -= 100.0;
        st.procs[0].pending.insert(3, 100.0);
        st.procs[1].buffered.insert(4, 7.0);
        st.note_usage(2, 123.0);
        st.push_comm(0, 1, 2.0);
        st.reset(&cluster);
        let fresh = PlatformState::new(&cluster);
        assert_eq!(st.num_procs(), fresh.num_procs());
        for j in 0..cluster.len() {
            assert_eq!(st.procs[j].ready_time, fresh.procs[j].ready_time);
            assert_eq!(st.procs[j].avail_mem, fresh.procs[j].avail_mem);
            assert_eq!(st.procs[j].avail_buf, fresh.procs[j].avail_buf);
            assert_eq!(st.procs[j].peak_used, 0.0);
            assert!(st.procs[j].pending.is_empty());
            assert!(st.procs[j].buffered.is_empty());
            for to in 0..cluster.len() {
                assert_eq!(st.comm_ready(j, to), 0.0);
            }
        }
        // Shape change: rebuilds instead of leaving a stale layout.
        let bigger = crate::platform::presets::default_cluster();
        st.reset(&bigger);
        assert_eq!(st.num_procs(), bigger.len());
    }

    #[test]
    fn consume_remote_frees_memory_or_buffer() {
        let cluster = small_cluster();
        let mut st = PlatformState::new(&cluster);
        let m0 = st.procs[0].avail_mem;
        st.procs[0].pending.insert(7, 100.0);
        st.procs[0].avail_mem -= 100.0;
        st.consume_remote(0, 7);
        assert_eq!(st.procs[0].avail_mem, m0);
        let b0 = st.procs[0].avail_buf;
        st.procs[0].buffered.insert(9, 50.0);
        st.procs[0].avail_buf -= 50.0;
        st.consume_remote(0, 9);
        assert_eq!(st.procs[0].avail_buf, b0);
        // Unknown file: no-op.
        st.consume_remote(0, 1234);
    }
}
