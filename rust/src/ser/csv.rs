//! Tiny CSV writer used by the experiment harness to dump result tables.

use std::fmt::Write as _;

/// Accumulates rows and renders RFC-4180-style CSV text.
#[derive(Debug, Default, Clone)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        CsvWriter { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row; panics in debug builds if the width mismatches.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        debug_assert_eq!(cells.len(), self.header.len(), "CSV row width mismatch");
        self.rows.push(cells);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    /// Render as a GitHub-flavored markdown table (for reports).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(out, "|{}|", vec!["---"; self.header.len()].join("|"));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains([',', '"', '\n']) {
            out.push('"');
            out.push_str(&cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_rows() {
        let mut w = CsvWriter::new(vec!["a", "b"]);
        w.row(vec!["1", "2"]);
        w.row(vec!["x", "y"]);
        assert_eq!(w.to_csv(), "a,b\n1,2\nx,y\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn quoting() {
        let mut w = CsvWriter::new(vec!["v"]);
        w.row(vec!["has,comma"]);
        w.row(vec!["has\"quote"]);
        w.row(vec!["has\nnewline"]);
        assert_eq!(w.to_csv(), "v\n\"has,comma\"\n\"has\"\"quote\"\n\"has\nnewline\"\n");
    }

    #[test]
    fn markdown_table() {
        let mut w = CsvWriter::new(vec!["x", "y"]);
        w.row(vec!["1", "2"]);
        let md = w.to_markdown();
        assert!(md.starts_with("| x | y |\n|---|---|\n"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
