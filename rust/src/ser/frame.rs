//! Length-delimited frame codec for the `memsched serve` wire protocol.
//!
//! One frame = an 8-byte header followed by the payload bytes:
//!
//! ```text
//! offset  size  field
//! 0       4     magic + version, the ASCII bytes b"MSF1"
//! 4       4     payload length, u32 little-endian
//! 8       len   payload (UTF-8 JSON, one object per frame)
//! ```
//!
//! The magic doubles as a protocol version: a future incompatible
//! revision bumps the trailing digit, and mismatched peers fail fast
//! with [`FrameError::BadMagic`] instead of mis-framing the stream.
//!
//! Decoding is defensive by design — the daemon feeds this from
//! untrusted client sockets:
//!
//! - a frame longer than the decoder's cap is reported as
//!   [`FrameError::Oversized`] **after skipping its payload**, so the
//!   connection stays framed and usable;
//! - a bad magic means the peer is not speaking this protocol (or the
//!   stream lost sync) — unrecoverable, the caller should drop the
//!   connection;
//! - EOF in the middle of a header or payload is [`FrameError::Truncated`];
//! - clean EOF **between** frames is `Ok(None)`, the normal end of a
//!   session.

use std::fmt;
use std::io::{Read, Write};

/// Magic + version prefix of every frame.
pub const MAGIC: [u8; 4] = *b"MSF1";

/// Frame header size in bytes (magic + u32 length).
pub const HEADER_LEN: usize = 8;

/// Default payload cap for decoders (`--max-frame-bytes`): far above
/// any real job line, far below an allocation-of-death.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;

/// Decode failure. `Oversized` is recoverable (the stream is still
/// framed); the rest should end the connection.
#[derive(Debug)]
pub enum FrameError {
    /// Payload length exceeded the decoder cap. The payload has been
    /// read and discarded — the next read starts at the next frame.
    Oversized { len: usize, cap: usize },
    /// The 4 magic bytes did not match [`MAGIC`]: wrong protocol or a
    /// desynchronized stream.
    BadMagic([u8; 4]),
    /// EOF inside a header or payload.
    Truncated,
    /// Underlying transport error.
    Io(std::io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Oversized { len, cap } => {
                write!(f, "frame payload of {len} bytes exceeds the {cap}-byte cap")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?} (expected {MAGIC:?})"),
            FrameError::Truncated => write!(f, "truncated frame (EOF mid-header or mid-payload)"),
            FrameError::Io(e) => write!(f, "frame I/O error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

impl FrameError {
    /// Whether the stream is still framed after this error (the caller
    /// may report it and keep reading).
    pub fn recoverable(&self) -> bool {
        matches!(self, FrameError::Oversized { .. })
    }
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let len: u32 = payload.len().try_into().map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame payload exceeds u32 length")
    })?;
    w.write_all(&MAGIC)?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` is clean EOF at a frame
/// boundary; `Err(Oversized)` leaves the stream positioned at the next
/// frame (the payload is skipped), every other error is terminal.
pub fn read_frame(r: &mut impl Read, cap: usize) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    match read_exact_or_eof(r, &mut header)? {
        ReadOutcome::CleanEof => return Ok(None),
        ReadOutcome::Truncated => return Err(FrameError::Truncated),
        ReadOutcome::Full => {}
    }
    let magic: [u8; 4] = header[..4].try_into().unwrap();
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let len = u32::from_le_bytes(header[4..].try_into().unwrap()) as usize;
    if len > cap {
        // Resync: consume the payload so the stream stays framed, then
        // report. A short skip means the peer lied about the length —
        // that *is* terminal.
        match skip_bytes(r, len) {
            Ok(true) => return Err(FrameError::Oversized { len, cap }),
            Ok(false) => return Err(FrameError::Truncated),
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let mut payload = vec![0u8; len];
    match read_exact_or_eof(r, &mut payload)? {
        ReadOutcome::Full => Ok(Some(payload)),
        _ => Err(FrameError::Truncated),
    }
}

enum ReadOutcome {
    Full,
    CleanEof,
    Truncated,
}

/// `read_exact` that distinguishes EOF-before-any-byte (clean) from
/// EOF-mid-buffer (truncated).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<ReadOutcome, FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Ok(if filled == 0 { ReadOutcome::CleanEof } else { ReadOutcome::Truncated })
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(ReadOutcome::Full)
}

/// Discard exactly `n` bytes; `Ok(false)` on early EOF.
fn skip_bytes(r: &mut impl Read, mut n: usize) -> std::io::Result<bool> {
    let mut scratch = [0u8; 4096];
    while n > 0 {
        let want = n.min(scratch.len());
        match r.read(&mut scratch[..want]) {
            Ok(0) => return Ok(false),
            Ok(got) => n -= got,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn encode(payloads: &[&[u8]]) -> Vec<u8> {
        let mut buf = Vec::new();
        for p in payloads {
            write_frame(&mut buf, p).unwrap();
        }
        buf
    }

    #[test]
    fn roundtrips_multiple_frames_and_clean_eof() {
        let buf = encode(&[b"{\"a\":1}", b"", b"{\"b\":[1,2,3]}"]);
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"{\"a\":1}");
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"{\"b\":[1,2,3]}");
        assert!(read_frame(&mut r, 1024).unwrap().is_none(), "clean EOF between frames");
        // EOF is sticky.
        assert!(read_frame(&mut r, 1024).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_reports_and_resyncs() {
        let big = vec![b'x'; 100];
        let buf = encode(&[&big, b"next"]);
        let mut r = Cursor::new(buf);
        match read_frame(&mut r, 64) {
            Err(FrameError::Oversized { len: 100, cap: 64 }) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
        // The oversized payload was skipped: the stream is still framed.
        assert_eq!(read_frame(&mut r, 64).unwrap().unwrap(), b"next");
    }

    #[test]
    fn garbage_bytes_are_bad_magic_not_panic() {
        // Arbitrary garbage: the first 4 bytes fail the magic check.
        let mut r = Cursor::new(b"hello world, definitely not a frame".to_vec());
        match read_frame(&mut r, 1024) {
            Err(e @ FrameError::BadMagic(_)) => assert!(!e.recoverable()),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_detected_everywhere() {
        let full = encode(&[b"{\"a\":1}"]);
        // Mid-header, mid-payload, and lying-length truncations.
        for cut in [3, HEADER_LEN + 2] {
            let mut r = Cursor::new(full[..cut].to_vec());
            assert!(matches!(read_frame(&mut r, 1024), Err(FrameError::Truncated)), "cut={cut}");
        }
        // Oversized frame whose payload ends early: terminal, not resync.
        let mut lying = Vec::new();
        lying.extend_from_slice(&MAGIC);
        lying.extend_from_slice(&1000u32.to_le_bytes());
        lying.extend_from_slice(b"short");
        let mut r = Cursor::new(lying);
        assert!(matches!(read_frame(&mut r, 64), Err(FrameError::Truncated)));
    }

    #[test]
    fn empty_stream_is_clean_eof() {
        let mut r = Cursor::new(Vec::new());
        assert!(read_frame(&mut r, 1024).unwrap().is_none());
    }
}
