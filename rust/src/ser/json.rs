//! A minimal, strict JSON parser and emitter.
//!
//! Supports the full JSON grammar (RFC 8259) with the following practical
//! choices: numbers are `f64`, object key order is preserved (insertion
//! order), and duplicate keys are rejected. Parse errors carry line/column.

use std::collections::BTreeSet;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    /// Insertion-ordered key/value pairs (duplicate keys rejected at parse).
    Object(Vec<(String, Value)>),
}

/// Parse error with 1-based line/column location.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser::new(text);
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if !p.eof() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required JSON field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON field `{key}` must be a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON field `{key}` must be a number"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("JSON field `{key}` must be a non-negative integer"))
    }

    pub fn req_array(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("JSON field `{key}` must be an array"))
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Number(n) => write_number(out, *n),
            Value::String(s) => write_escaped(out, s),
            Value::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Value::Object(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

/// Build an object value from pairs (convenience for emitters).
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Parse a JSONL document: one value per line; blank lines and `#`
/// comment lines are skipped. Errors carry the 1-based *file* line of
/// the offending record (columns stay within that line).
pub fn parse_jsonl(text: &str) -> Result<Vec<Value>, ParseError> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        match Value::parse(trimmed) {
            Ok(v) => out.push(v),
            Err(mut e) => {
                e.line = i + 1;
                return Err(e);
            }
        }
    }
    Ok(out)
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Number(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Number(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Number(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::String(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::String(v)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Array(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            // Integral values render without a trailing `.0`.
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no Inf/NaN; emit null (documented lossy behaviour).
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: usize,
    line_start: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Self {
        Parser { bytes: text.as_bytes(), pos: 0, line: 1, line_start: 0 }
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { msg: msg.into(), line: self.line, col: self.pos - self.line_start + 1 }
    }

    fn eof(&self) -> bool {
        self.pos >= self.bytes.len()
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.line_start = self.pos;
        }
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.bump();
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            Some(got) => Err(self.err(format!("expected `{}`, found `{}`", b as char, got as char))),
            None => Err(self.err(format!("expected `{}`, found end of input", b as char))),
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(format!("unexpected character `{}`", b as char))),
        }
    }

    fn keyword(&mut self, kw: &str, v: Value) -> Result<Value, ParseError> {
        for expected in kw.bytes() {
            match self.bump() {
                Some(got) if got == expected => {}
                _ => return Err(self.err(format!("invalid literal, expected `{kw}`"))),
            }
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        let mut saw_digit = false;
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            saw_digit = true;
            self.bump();
        }
        if !saw_digit {
            return Err(self.err("invalid number"));
        }
        if self.peek() == Some(b'.') {
            self.bump();
            let mut frac = false;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                frac = true;
                self.bump();
            }
            if !frac {
                return Err(self.err("invalid number: digits required after `.`"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.bump();
            }
            let mut exp = false;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                exp = true;
                self.bump();
            }
            if !exp {
                return Err(self.err("invalid number: digits required in exponent"));
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("number out of range: {text}")))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: require a following \uXXXX low half.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate in string"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match c {
                            Some(c) => out.push(c),
                            None => return Err(self.err("invalid unicode escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("invalid UTF-8"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated UTF-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields: Vec<(String, Value)> = Vec::new();
        let mut seen: BTreeSet<String> = BTreeSet::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if !seen.insert(key.clone()) {
                return Err(self.err(format!("duplicate object key `{key}`")));
            }
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(fields)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("false").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn rejects_duplicate_keys() {
        assert!(Value::parse(r#"{"a":1,"a":2}"#).is_err());
    }

    #[test]
    fn jsonl_parses_lines_and_reports_file_line_numbers() {
        let text = "# comment\n{\"a\":1}\n\n{\"b\":2}\n";
        let values = parse_jsonl(text).unwrap();
        assert_eq!(values.len(), 2);
        assert_eq!(values[1].req_usize("b").unwrap(), 2);
        let err = parse_jsonl("{\"ok\":1}\n{broken\n").unwrap_err();
        assert_eq!(err.line, 2, "error must carry the file line: {err}");
        assert!(parse_jsonl("").unwrap().is_empty());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{} x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[", "\"abc", "01x", "tru", "{\"a\" 1}", "[1,]", "{,}", "nan"] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = "line\nbreak \"quoted\" back\\slash tab\t unicode \u{1F600} end";
        let v = Value::String(original.to_string());
        let text = v.to_string_compact();
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(Value::parse(r#""A""#).unwrap(), Value::String("A".into()));
        // Surrogate pair for U+1F600.
        assert_eq!(
            Value::parse(r#""😀""#).unwrap(),
            Value::String("\u{1F600}".into())
        );
        assert!(Value::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = obj(vec![
            ("name", "wf".into()),
            ("tasks", Value::Array(vec![1.0.into(), 2.0.into()])),
            ("nested", obj(vec![("ok", true.into())])),
            ("empty_arr", Value::Array(vec![])),
            ("empty_obj", Value::Object(vec![])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Value::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn error_location_reported() {
        let err = Value::parse("{\n  \"a\": @\n}").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.col >= 8, "col = {}", err.col);
    }

    #[test]
    fn number_precision_preserved_for_integers() {
        let v = Value::parse("123456789012").unwrap();
        assert_eq!(v.as_u64(), Some(123456789012));
        assert_eq!(v.to_string_compact(), "123456789012");
    }

    #[test]
    fn accessors_reject_wrong_types() {
        let v = Value::parse(r#"{"n": 1.5, "s": "x"}"#).unwrap();
        assert!(v.req_usize("n").is_err());
        assert!(v.req_f64("s").is_err());
        assert!(v.req("missing").is_err());
        assert_eq!(v.req_f64("n").unwrap(), 1.5);
    }
}
