//! Self-contained serialization substrates.
//!
//! The build environment is fully offline and `serde` is unavailable, so the
//! library carries its own minimal JSON implementation ([`json`]) and a CSV
//! writer ([`csv`]). Both are deliberately small, strict, and fully tested.

pub mod csv;
pub mod frame;
pub mod json;
