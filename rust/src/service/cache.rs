//! Content-addressed schedule cache.
//!
//! Keyed by [`schedule_fingerprint`](super::fingerprint::schedule_fingerprint):
//! identical (workflow, platform, algorithm, policy) requests resolve to
//! one computation. Each key holds a `OnceLock`, so when several workers
//! race on the same key exactly one computes while the others block on
//! the cell rather than duplicating the work — the cache is the service's
//! cross-job sharing point (e.g. the two dynamic-mode simulations of one
//! workload reuse a single static schedule).
//!
//! Counter semantics: `computed` is the number of distinct schedules
//! actually computed (deterministic: one per unique key); `lookups` is
//! the total number of requests — both direct [`get_or_compute`] calls
//! and batch-level deduplicated jobs recorded via
//! [`note_deduped`](ScheduleCache::note_deduped), which are satisfied
//! without ever reaching the map; `hits = lookups - computed`.
//!
//! [`get_or_compute`]: ScheduleCache::get_or_compute

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::scheduler::Schedule;

use super::fingerprint::Fingerprint;

/// A cached schedule plus the wall time its computation took.
#[derive(Debug, Clone)]
pub struct CachedSchedule {
    pub schedule: Arc<Schedule>,
    /// Seconds the computing worker spent; shared verbatim with cache
    /// hits (reports should treat it as "cost of this schedule", not
    /// "cost of this job").
    pub seconds: f64,
}

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: usize,
    pub computed: usize,
}

impl CacheStats {
    /// Saturating: a reader racing an in-flight computation can observe
    /// `computed` incremented before `lookups`; between batches the two
    /// are consistent.
    pub fn hits(&self) -> usize {
        self.lookups.saturating_sub(self.computed)
    }
}

/// The cache. Cheap to share behind the service; all methods take `&self`.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    map: Mutex<HashMap<u128, Arc<OnceLock<CachedSchedule>>>>,
    lookups: AtomicUsize,
    computed: AtomicUsize,
}

impl ScheduleCache {
    pub fn new() -> ScheduleCache {
        ScheduleCache::default()
    }

    /// Whether a schedule for `fp` has already been computed.
    pub fn contains(&self, fp: Fingerprint) -> bool {
        let map = self.map.lock().unwrap();
        map.get(&fp.0).is_some_and(|cell| cell.get().is_some())
    }

    /// Number of computed entries.
    pub fn len(&self) -> usize {
        let map = self.map.lock().unwrap();
        map.values().filter(|c| c.get().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `fp`, computing (exactly once across all threads) via
    /// `compute` on a miss. `compute` returns the schedule and its
    /// elapsed seconds.
    pub fn get_or_compute<F: FnOnce() -> (Schedule, f64)>(
        &self,
        fp: Fingerprint,
        compute: F,
    ) -> CachedSchedule {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let cell = {
            let mut map = self.map.lock().unwrap();
            map.entry(fp.0).or_insert_with(|| Arc::new(OnceLock::new())).clone()
        };
        cell.get_or_init(|| {
            self.computed.fetch_add(1, Ordering::Relaxed);
            let (schedule, seconds) = compute();
            CachedSchedule { schedule: Arc::new(schedule), seconds }
        })
        .clone()
    }

    /// Record `n` requests satisfied upstream by batch-level
    /// deduplication (they advance `lookups` but never compute, so they
    /// count as hits).
    pub fn note_deduped(&self, n: usize) {
        self.lookups.fetch_add(n, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            computed: self.computed.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{compute_schedule, Algorithm, EvictionPolicy};
    use crate::service::fingerprint::schedule_fingerprint;
    use crate::workflow::WorkflowBuilder;

    fn sample() -> (crate::workflow::Workflow, crate::platform::Cluster) {
        let mut b = WorkflowBuilder::new("w");
        let a = b.task("a", "t", 5.0, 10.0);
        let c = b.task("c", "t", 7.0, 20.0);
        b.edge(a, c, 3.0);
        (b.build().unwrap(), small_cluster())
    }

    #[test]
    fn second_lookup_hits() {
        let (wf, cluster) = sample();
        let cache = ScheduleCache::new();
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let mut computes = 0;
        for _ in 0..3 {
            let cs = cache.get_or_compute(fp, || {
                computes += 1;
                (compute_schedule(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst), 0.01)
            });
            assert!(cs.schedule.valid);
        }
        assert_eq!(computes, 1);
        let stats = cache.stats();
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.computed, 1);
        assert_eq!(stats.hits(), 2);
        assert!(cache.contains(fp));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn concurrent_lookups_compute_once() {
        let (wf, cluster) = sample();
        let cache = ScheduleCache::new();
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        let computes = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    cache.get_or_compute(fp, || {
                        computes.fetch_add(1, Ordering::Relaxed);
                        (
                            compute_schedule(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst),
                            0.0,
                        )
                    });
                });
            }
        });
        assert_eq!(computes.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats().lookups, 8);
        assert_eq!(cache.stats().hits(), 7);
    }
}
