//! Compute-once maps: the generic [`OnceMap`] and the content-addressed
//! [`ScheduleCache`] built on it.
//!
//! `OnceMap` is the one implementation of the Mutex-map-of-`OnceLock`
//! idiom the service previously hand-rolled twice (here and in the
//! workflow/cluster `Memo`): per key one cell, so when several workers
//! race on the same key exactly one computes while the others block on
//! the cell rather than duplicating the work. It optionally enforces an
//! **LRU-by-bytes budget**: computed entries are weighed by a
//! caller-supplied function, and when the total exceeds the budget the
//! least-recently-used entries are dropped (never an entry still being
//! computed, and never the entry being returned). Without a budget the
//! map is append-only and fully deterministic; with one, *which* keys
//! stay resident across batches depends on access order, so evicted keys
//! simply recompute on their next request — values themselves are always
//! deterministic.
//!
//! `ScheduleCache` keys schedules by
//! [`schedule_fingerprint`](super::fingerprint::schedule_fingerprint):
//! identical (workflow, platform, algorithm, policy) requests resolve to
//! one computation — the service's cross-job sharing point (e.g. the two
//! dynamic-mode simulations of one workload reuse a single static
//! schedule).
//!
//! `ScheduleCache` optionally layers a **disk-backed store**
//! ([`DiskStore`], `--cache-dir`) under the in-memory map: memory misses
//! first try the content-addressed on-disk entry, and fresh computations
//! are persisted (atomic rename) — so repeated CLI invocations and CI
//! runs share schedules across processes, and LRU-evicted fingerprints
//! reload instead of recomputing. Corrupt, truncated, stale-version, or
//! mismatched entries degrade to a recompute (see [`super::disk`]).
//!
//! Counter semantics: `computed` is the number of schedule computations
//! actually run (one per unique key, plus recomputations of evicted
//! keys when a byte budget is set); `disk_hits` counts memory misses
//! served from disk (not computations — a fully warm `--cache-dir` run
//! reports `computed == 0`); `lookups` is the total number of
//! requests — both direct [`get_or_compute`] calls and batch-level
//! deduplicated jobs recorded via
//! [`note_deduped`](ScheduleCache::note_deduped), which are satisfied
//! without ever reaching the map; `hits = lookups - computed`.
//!
//! [`get_or_compute`]: ScheduleCache::get_or_compute

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs;
use crate::scheduler::Schedule;

use super::disk::DiskStore;
use super::fingerprint::Fingerprint;

#[derive(Debug)]
struct Entry<V> {
    cell: Arc<OnceLock<V>>,
    /// LRU clock stamp of the most recent request for this key.
    last_used: u64,
    /// Weighed size once computed and accounted; 0 while in flight.
    bytes: usize,
}

#[derive(Debug)]
struct MapInner<K, V> {
    map: HashMap<K, Entry<V>>,
    clock: u64,
    total_bytes: usize,
}

/// Generic compute-once map (see module docs).
#[derive(Debug)]
pub struct OnceMap<K, V> {
    inner: Mutex<MapInner<K, V>>,
    /// LRU byte budget for computed entries (`None` = unbounded).
    cap_bytes: Option<usize>,
}

impl<K, V> Default for OnceMap<K, V> {
    fn default() -> Self {
        OnceMap::new()
    }
}

// Construction needs no key/value bounds.
impl<K, V> OnceMap<K, V> {
    /// An unbounded map.
    pub fn new() -> OnceMap<K, V> {
        OnceMap::with_byte_cap(None)
    }

    /// A map evicting least-recently-used computed entries once their
    /// weighed total exceeds `cap_bytes`.
    pub fn with_byte_cap(cap_bytes: Option<usize>) -> OnceMap<K, V> {
        OnceMap {
            inner: Mutex::new(MapInner { map: HashMap::new(), clock: 0, total_bytes: 0 }),
            cap_bytes,
        }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> OnceMap<K, V> {
    /// Look up `key`, computing (exactly once across all racing threads)
    /// via `init` on a miss. `weigh` sizes a freshly computed value for
    /// the byte budget.
    pub fn get_or_init<F, W>(&self, key: &K, init: F, weigh: W) -> V
    where
        F: FnOnce() -> V,
        W: FnOnce(&V) -> usize,
    {
        let cell = {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            inner.clock += 1;
            let clock = inner.clock;
            let entry = inner.map.entry(key.clone()).or_insert_with(|| Entry {
                cell: Arc::new(OnceLock::new()),
                last_used: 0,
                bytes: 0,
            });
            entry.last_used = clock;
            entry.cell.clone()
        };
        let mut freshly_computed = false;
        let value = cell
            .get_or_init(|| {
                freshly_computed = true;
                init()
            })
            .clone();
        if freshly_computed {
            let bytes = weigh(&value);
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            if let Some(entry) = inner.map.get_mut(key) {
                // Account only if this cell is still the resident one and
                // not yet weighed (it may have been evicted meanwhile).
                if entry.bytes == 0 && Arc::ptr_eq(&entry.cell, &cell) {
                    entry.bytes = bytes;
                    inner.total_bytes += bytes;
                }
            }
            if let Some(cap) = self.cap_bytes {
                Self::evict_lru(inner, cap, key);
            }
        }
        value
    }

    /// Drop least-recently-used *computed* entries until the budget
    /// holds. `keep` (the key just served) is never evicted, so a single
    /// oversized value stays resident rather than thrashing.
    fn evict_lru(inner: &mut MapInner<K, V>, cap: usize, keep: &K) {
        while inner.total_bytes > cap {
            let victim: Option<K> = inner
                .map
                .iter()
                .filter(|&(k, e)| e.bytes > 0 && k != keep)
                .min_by_key(|&(_, e)| e.last_used)
                .map(|(k, _)| (*k).clone());
            match victim {
                Some(k) => {
                    if let Some(e) = inner.map.remove(&k) {
                        inner.total_bytes -= e.bytes;
                    }
                }
                None => break,
            }
        }
    }

    /// Whether a *computed* value exists for `key` (in-flight cells
    /// don't count).
    pub fn contains_computed(&self, key: &K) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.map.get(key).is_some_and(|e| e.cell.get().is_some())
    }

    /// Number of computed entries.
    pub fn len_computed(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.map.values().filter(|e| e.cell.get().is_some()).count()
    }

    /// Current weighed total of resident computed entries.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock().unwrap().total_bytes
    }

    /// Keep only entries for which `pred(key, computed_value)` holds;
    /// in-flight entries (`None`) are judged too. Call only when no
    /// initializations are racing (e.g. at batch boundaries).
    pub fn retain<F: Fn(&K, Option<&V>) -> bool>(&self, pred: F) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let mut freed = 0usize;
        inner.map.retain(|k, e| {
            let keep = pred(k, e.cell.get());
            if !keep {
                freed += e.bytes;
            }
            keep
        });
        inner.total_bytes -= freed;
    }
}

/// A cached schedule plus the wall time its computation took.
#[derive(Debug, Clone)]
pub struct CachedSchedule {
    pub schedule: Arc<Schedule>,
    /// Seconds the computing worker spent; shared verbatim with cache
    /// hits (reports should treat it as "cost of this schedule", not
    /// "cost of this job").
    pub seconds: f64,
}

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: usize,
    /// Schedule computations actually run (disk loads are *not*
    /// computations — a fully warm `--cache-dir` run reports 0 here).
    pub computed: usize,
    /// Misses served by the disk-backed layer instead of a computation.
    pub disk_hits: usize,
}

impl CacheStats {
    /// Requests satisfied without running a schedule computation (memory
    /// hits, batch-level dedupe, and disk loads together). Saturating: a
    /// reader racing an in-flight computation can observe `computed`
    /// incremented before `lookups`; between batches the two are
    /// consistent.
    pub fn hits(&self) -> usize {
        self.lookups.saturating_sub(self.computed)
    }
}

/// The schedule cache: an [`OnceMap`] over schedule fingerprints with
/// request counters and an optional disk-backed second layer
/// ([`DiskStore`]). Cheap to share behind the service; all methods take
/// `&self`.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    map: OnceMap<u128, CachedSchedule>,
    /// Second cache layer: consulted on memory misses, filled on
    /// computes, shared across processes via `--cache-dir`.
    disk: Option<Arc<DiskStore>>,
    lookups: AtomicUsize,
    computed: AtomicUsize,
    disk_hits: AtomicUsize,
}

impl ScheduleCache {
    /// An unbounded, memory-only cache.
    pub fn new() -> ScheduleCache {
        ScheduleCache::default()
    }

    /// A cache evicting least-recently-used schedules beyond `cap_bytes`
    /// (approximate heap bytes, see [`Schedule::approx_bytes`]). Evicted
    /// fingerprints recompute on their next request.
    pub fn with_byte_cap(cap_bytes: Option<usize>) -> ScheduleCache {
        ScheduleCache::with_config(cap_bytes, None)
    }

    /// Full configuration: optional LRU byte cap on the in-memory layer,
    /// optional disk-backed layer. With a disk store, memory misses
    /// first try the on-disk entry (counted in
    /// [`CacheStats::disk_hits`], not `computed`) and fresh computations
    /// are persisted best-effort — so an LRU-evicted or
    /// other-process-computed fingerprint loads instead of recomputing.
    pub fn with_config(cap_bytes: Option<usize>, disk: Option<Arc<DiskStore>>) -> ScheduleCache {
        ScheduleCache {
            map: OnceMap::with_byte_cap(cap_bytes),
            disk,
            lookups: AtomicUsize::new(0),
            computed: AtomicUsize::new(0),
            disk_hits: AtomicUsize::new(0),
        }
    }

    /// Whether a schedule for `fp` has already been computed.
    pub fn contains(&self, fp: Fingerprint) -> bool {
        self.map.contains_computed(&fp.0)
    }

    /// Number of computed entries.
    pub fn len(&self) -> usize {
        self.map.len_computed()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes of cached schedules.
    pub fn resident_bytes(&self) -> usize {
        self.map.total_bytes()
    }

    /// Look up `fp`, computing (exactly once across all threads) via
    /// `compute` on a miss. `compute` returns the schedule and its
    /// elapsed seconds.
    pub fn get_or_compute<F: FnOnce() -> (Schedule, f64)>(
        &self,
        fp: Fingerprint,
        compute: F,
    ) -> CachedSchedule {
        self.get_or_compute_checked(fp, None, compute)
    }

    /// [`get_or_compute`](ScheduleCache::get_or_compute) with a sanity
    /// check on disk loads: an on-disk entry whose task count differs
    /// from `expect_tasks` (a renamed file, fingerprint-collision-shaped
    /// garbage, or a true 128-bit collision) is discarded as a miss and
    /// recomputed — never returned as a wrong schedule.
    pub fn get_or_compute_checked<F: FnOnce() -> (Schedule, f64)>(
        &self,
        fp: Fingerprint,
        expect_tasks: Option<usize>,
        compute: F,
    ) -> CachedSchedule {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        // Distinguishes a memory hit (closure never ran) for the obs layer.
        let ran = std::cell::Cell::new(false);
        let out = self.map.get_or_init(
            &fp.0,
            || {
                ran.set(true);
                if let Some(disk) = &self.disk {
                    if let Some(cached) = disk.load(fp) {
                        if expect_tasks.is_none_or(|n| cached.schedule.tasks.len() == n) {
                            self.disk_hits.fetch_add(1, Ordering::Relaxed);
                            if obs::enabled() {
                                obs::record(obs::Event::CacheHitDisk);
                            }
                            return cached;
                        }
                    }
                }
                self.computed.fetch_add(1, Ordering::Relaxed);
                let (schedule, seconds) = compute();
                let cached = CachedSchedule { schedule: Arc::new(schedule), seconds };
                if let Some(disk) = &self.disk {
                    disk.store(fp, &cached);
                }
                cached
            },
            |cs| cs.schedule.approx_bytes(),
        );
        if !ran.get() && obs::enabled() {
            obs::record(obs::Event::CacheHitMem);
        }
        out
    }

    /// Record `n` requests satisfied upstream by batch-level
    /// deduplication (they advance `lookups` but never compute, so they
    /// count as hits).
    pub fn note_deduped(&self, n: usize) {
        self.lookups.fetch_add(n, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            computed: self.computed.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
    use crate::service::fingerprint::schedule_fingerprint;
    use crate::workflow::WorkflowBuilder;

    fn sample() -> (crate::workflow::Workflow, crate::platform::Cluster) {
        let mut b = WorkflowBuilder::new("w");
        let a = b.task("a", "t", 5.0, 10.0);
        let c = b.task("c", "t", 7.0, 20.0);
        b.edge(a, c, 3.0);
        (b.build().unwrap(), small_cluster())
    }

    #[test]
    fn second_lookup_hits() {
        let (wf, cluster) = sample();
        let cache = ScheduleCache::new();
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let mut computes = 0;
        for _ in 0..3 {
            let cs = cache.get_or_compute(fp, || {
                computes += 1;
                (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.01)
            });
            assert!(cs.schedule.valid);
        }
        assert_eq!(computes, 1);
        let stats = cache.stats();
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.computed, 1);
        assert_eq!(stats.hits(), 2);
        assert!(cache.contains(fp));
        assert_eq!(cache.len(), 1);
        assert!(cache.resident_bytes() > 0);
    }

    #[test]
    fn concurrent_lookups_compute_once() {
        let (wf, cluster) = sample();
        let cache = ScheduleCache::new();
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        let computes = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    cache.get_or_compute(fp, || {
                        computes.fetch_add(1, Ordering::Relaxed);
                        (
                            ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run(),
                            0.0,
                        )
                    });
                });
            }
        });
        assert_eq!(computes.load(Ordering::Relaxed), 1);
        assert_eq!(cache.stats().lookups, 8);
        assert_eq!(cache.stats().hits(), 7);
    }

    #[test]
    fn once_map_retain_prunes_and_reaccounts() {
        let map: OnceMap<String, Result<u32, String>> = OnceMap::new();
        let ok = map.get_or_init(&"good".to_string(), || Ok(1), |_| 10);
        assert_eq!(ok, Ok(1));
        let err = map.get_or_init(&"bad".to_string(), || Err("boom".into()), |_| 10);
        assert!(err.is_err());
        assert_eq!(map.len_computed(), 2);
        assert_eq!(map.total_bytes(), 20);
        // The Memo pattern: drop failed entries between batches.
        map.retain(|_, v| v.is_none_or(|r| r.is_ok()));
        assert_eq!(map.len_computed(), 1);
        assert_eq!(map.total_bytes(), 10);
        assert!(map.contains_computed(&"good".to_string()));
        assert!(!map.contains_computed(&"bad".to_string()));
        // A retried key computes again.
        let retried = map.get_or_init(&"bad".to_string(), || Ok(7), |_| 10);
        assert_eq!(retried, Ok(7));
    }

    #[test]
    fn lru_byte_cap_evicts_least_recently_used() {
        let map: OnceMap<u32, Vec<u8>> = OnceMap::with_byte_cap(Some(250));
        let weigh = |v: &Vec<u8>| v.len();
        for k in 0..3u32 {
            map.get_or_init(&k, || vec![0u8; 100], weigh);
        }
        // 300 bytes > 250: key 0 (least recently used) must be gone.
        assert!(!map.contains_computed(&0));
        assert!(map.contains_computed(&1) && map.contains_computed(&2));
        assert!(map.total_bytes() <= 250);
        // Touch key 1, insert key 3: now key 2 is the LRU victim.
        map.get_or_init(&1, || unreachable!("still resident"), weigh);
        map.get_or_init(&3, || vec![0u8; 100], weigh);
        assert!(map.contains_computed(&1), "recently touched entry survives");
        assert!(!map.contains_computed(&2));
        // Evicted keys recompute on demand.
        let recomputed = std::cell::Cell::new(false);
        map.get_or_init(
            &0,
            || {
                recomputed.set(true);
                vec![0u8; 100]
            },
            weigh,
        );
        assert!(recomputed.get());
    }

    #[test]
    fn oversized_single_entry_stays_resident() {
        let map: OnceMap<u32, Vec<u8>> = OnceMap::with_byte_cap(Some(10));
        map.get_or_init(&1, || vec![0u8; 100], |v| v.len());
        // Over budget, but the just-served key is never evicted.
        assert!(map.contains_computed(&1));
        // The next insert evicts it instead.
        map.get_or_init(&2, || vec![0u8; 100], |v| v.len());
        assert!(!map.contains_computed(&1));
        assert!(map.contains_computed(&2));
    }

    fn disk_store(tag: &str) -> (std::path::PathBuf, Arc<DiskStore>) {
        let dir = std::env::temp_dir().join(format!("memsched_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(DiskStore::open(&dir).unwrap());
        (dir, store)
    }

    #[test]
    fn disk_layer_shares_schedules_across_cache_instances() {
        let (wf, cluster) = sample();
        let (dir, store) = disk_store("share");
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);

        let cold = ScheduleCache::with_config(None, Some(store.clone()));
        let first = cold.get_or_compute_checked(fp, Some(wf.num_tasks()), || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.25)
        });
        assert_eq!(cold.stats().computed, 1);
        assert_eq!(cold.stats().disk_hits, 0);

        // A second cache instance (a "new process") loads from disk.
        let warm = ScheduleCache::with_config(None, Some(store));
        let loaded = warm.get_or_compute_checked(fp, Some(wf.num_tasks()), || {
            panic!("warm cache must not recompute")
        });
        assert_eq!(warm.stats().computed, 0);
        assert_eq!(warm.stats().disk_hits, 1);
        assert_eq!(warm.stats().hits(), 1);
        assert_eq!(loaded.schedule.makespan.to_bits(), first.schedule.makespan.to_bits());
        assert_eq!(loaded.seconds, first.seconds);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_disk_entries_degrade_to_recompute() {
        let (wf, cluster) = sample();
        let (dir, store) = disk_store("corrupt");
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        ScheduleCache::with_config(None, Some(store.clone())).get_or_compute(fp, || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        let path = dir.join(format!("{fp}.sched"));
        let good = std::fs::read(&path).unwrap();
        // Truncation, a wrong version header, and random garbage must
        // all recompute (never panic, never return a wrong schedule).
        let mut wrong_version = good.clone();
        wrong_version[8] ^= 0xff;
        for bad in [&good[..good.len() / 2], &wrong_version[..], &b"not a schedule"[..]] {
            std::fs::write(&path, bad).unwrap();
            let cache = ScheduleCache::with_config(None, Some(store.clone()));
            let mut recomputed = false;
            cache.get_or_compute(fp, || {
                recomputed = true;
                (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run(), 0.0)
            });
            assert!(recomputed);
            assert_eq!(cache.stats().disk_hits, 0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn task_count_mismatch_on_disk_is_a_miss() {
        let (wf, cluster) = sample();
        let (dir, store) = disk_store("mismatch");
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        ScheduleCache::with_config(None, Some(store.clone())).get_or_compute(fp, || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        // A collision-shaped entry: valid bytes, but the requester's
        // workflow has a different task count.
        let cache = ScheduleCache::with_config(None, Some(store));
        let mut recomputed = false;
        cache.get_or_compute_checked(fp, Some(wf.num_tasks() + 1), || {
            recomputed = true;
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        assert!(recomputed, "mismatched task count must force a recompute");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_writers_leave_a_valid_store() {
        let (wf, cluster) = sample();
        let (dir, _) = disk_store("race");
        let fps: Vec<(Algorithm, Fingerprint)> = Algorithm::all()
            .iter()
            .copied()
            .map(|a| (a, schedule_fingerprint(&wf, &cluster, a, EvictionPolicy::LargestFirst)))
            .collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (dir, fps, wf, cluster) = (&dir, &fps, &wf, &cluster);
                s.spawn(move || {
                    // Each writer opens its own store on the shared dir
                    // (separate processes in miniature).
                    let store = Arc::new(DiskStore::open(dir).unwrap());
                    let cache = ScheduleCache::with_config(None, Some(store));
                    for &(algo, fp) in fps {
                        cache.get_or_compute(fp, || {
                            (ScheduleRequest::new(wf, cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run(), 0.0)
                        });
                    }
                });
            }
        });
        // Every entry readable, nothing to recompute, no temp litter.
        let store = Arc::new(DiskStore::open(&dir).unwrap());
        assert_eq!(store.len(), fps.len());
        let cache = ScheduleCache::with_config(None, Some(store));
        for &(_, fp) in &fps {
            cache.get_or_compute(fp, || panic!("store must be fully warm"));
        }
        assert_eq!(cache.stats().disk_hits, fps.len());
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .count();
        assert_eq!(leftovers, 0, "temp files must not accumulate");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn schedule_cache_byte_cap_recomputes_evicted_fingerprints() {
        let (wf, cluster) = sample();
        // A cap far below one schedule's footprint: every distinct
        // fingerprint evicts the previous one.
        let cache = ScheduleCache::with_byte_cap(Some(1));
        let fp_bl = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let fp_mm = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        cache.get_or_compute(fp_bl, || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        cache.get_or_compute(fp_mm, || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        assert!(!cache.contains(fp_bl), "evicted by the second schedule");
        cache.get_or_compute(fp_bl, || {
            (ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run(), 0.0)
        });
        // 3 lookups, 3 computations (one was a post-eviction recompute).
        assert_eq!(cache.stats().computed, 3);
        assert_eq!(cache.stats().hits(), 0);
    }
}
