//! Disk-backed layer of the schedule cache: content-addressed files keyed
//! by the 128-bit schedule fingerprint, so repeated CLI invocations and CI
//! runs share schedules *across processes* (the in-memory
//! [`ScheduleCache`](super::ScheduleCache) only lives as long as one
//! service instance).
//!
//! ## On-disk format (version 1)
//!
//! One file per schedule, named `<32-hex-fingerprint>.sched` under the
//! store directory (`--cache-dir`). Little-endian throughout:
//!
//! ```text
//! magic      8 bytes   b"MEMSCHED"
//! version    u32       format version (currently 1)
//! fp         u128      the schedule fingerprint the payload belongs to
//! seconds    f64       wall seconds of the original computation
//! len        u64       payload length in bytes
//! hash       u64       FNV-1a 64 over the payload bytes
//! payload    len bytes the encoded Schedule (see `encode_schedule`)
//! ```
//!
//! ## Robustness contract
//!
//! Every read path degrades to a **miss** (recompute), never a panic or a
//! wrong schedule:
//!
//! - short/truncated files, bad magic, unknown versions → miss;
//! - payload hash mismatch (bit rot, torn writes that somehow survived
//!   the atomic rename) → miss;
//! - a stored fingerprint that differs from the requested one (renamed or
//!   collision-shaped files) → miss;
//! - trailing bytes after the payload, out-of-range enum tags, or length
//!   fields larger than the remaining bytes → miss;
//! - on top of the codec, the cache layer cross-checks the decoded task
//!   count against the requesting workflow
//!   ([`get_or_compute_checked`](super::ScheduleCache::get_or_compute_checked)).
//!
//! Writers are crash- and concurrency-safe: the entry is written to a
//! unique temp file and atomically renamed into place, so readers only
//! ever observe complete entries, and concurrent writers of one
//! fingerprint race to install bit-identical content (last rename wins).
//! Store errors are deliberately swallowed — the disk layer is an
//! accelerator, not a source of truth.
//!
//! Invalidation is by construction: the file *name* is the schedule
//! fingerprint (any change to workflow weights, platform, or algorithm
//! config addresses a different file), and the `version` header retires
//! whole stores when the schedule representation itself changes. Bump
//! [`FORMAT_VERSION`] whenever `Schedule`'s semantics change without the
//! fingerprint seeing it (e.g. a scheduler bugfix that alters outputs for
//! the same inputs).
//!
//! Long-lived stores (CI cache dirs) can be bounded with an
//! **LRU-by-mtime byte cap** ([`DiskStore::open_capped`],
//! `--cache-dir-bytes`): oldest-mtime `.sched` entries are evicted first,
//! on open and after every write, and evictions degrade to recomputes
//! exactly like any other miss.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::scheduler::{Failure, Schedule, TaskSchedule};

use super::cache::CachedSchedule;
use super::fingerprint::{algo_from_tag, algo_tag, policy_from_tag, policy_tag, Fingerprint};

const MAGIC: &[u8; 8] = b"MEMSCHED";
/// Bump to retire every existing store (see module docs).
pub const FORMAT_VERSION: u32 = 1;

/// Uniquifies temp names within this process (the pid in the name
/// handles other processes). Process-global, not per-store: two stores
/// opened on the same directory must never collide on a temp path.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A directory of content-addressed schedule files, optionally bounded
/// by an LRU-by-mtime byte cap (`--cache-dir-bytes`): when the summed
/// size of `.sched` entries exceeds the cap, oldest-mtime entries are
/// evicted first — on open (a long-lived CI cache dir shrinks to the
/// bound) and after every write. The just-written entry is never its own
/// victim, mirroring the in-memory cache's "oversized single entry stays
/// resident" rule. Evicted fingerprints degrade to a recompute, exactly
/// like any other miss.
#[derive(Debug)]
pub struct DiskStore {
    dir: PathBuf,
    /// Byte budget over `.sched` entries (`None` = unbounded).
    cap_bytes: Option<u64>,
}

/// Temp files older than this are dead by construction (writers rename
/// within milliseconds of creating them) and are swept on `open`.
const STALE_TMP_AGE: std::time::Duration = std::time::Duration::from_secs(3600);

impl DiskStore {
    /// Open (creating if needed) a store at `dir`. Sweeps temp files
    /// orphaned by crashed writers (killed between write and rename) so
    /// a long-lived shared cache dir cannot accumulate them; recent
    /// temps are left alone — they may belong to a live writer.
    pub fn open(dir: &Path) -> anyhow::Result<DiskStore> {
        DiskStore::open_capped(dir, None)
    }

    /// [`open`](DiskStore::open) with an LRU-by-mtime byte cap: the
    /// store is pruned to `cap_bytes` immediately (stale caches shrink
    /// on open) and again after every write.
    pub fn open_capped(dir: &Path, cap_bytes: Option<u64>) -> anyhow::Result<DiskStore> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating cache dir {}: {e}", dir.display()))?;
        if let Ok(entries) = std::fs::read_dir(dir) {
            let now = std::time::SystemTime::now();
            for entry in entries.filter_map(|e| e.ok()) {
                if !entry.file_name().to_string_lossy().starts_with(".tmp-") {
                    continue;
                }
                let stale = entry
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| now.duration_since(t).ok())
                    .is_some_and(|age| age > STALE_TMP_AGE);
                if stale {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        let store = DiskStore { dir: dir.to_path_buf(), cap_bytes };
        store.prune(None);
        Ok(store)
    }

    /// Evict oldest-mtime `.sched` entries until the byte cap holds
    /// (no-op when unbounded). `keep` is never evicted — the caller's
    /// just-written entry survives even a cap smaller than one entry.
    /// Best-effort like every other store write path: I/O errors leave
    /// entries behind rather than failing the computation.
    fn prune(&self, keep: Option<&Path>) {
        let Some(cap) = self.cap_bytes else {
            return;
        };
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return;
        };
        let mut files: Vec<(std::time::SystemTime, PathBuf, u64)> = entries
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "sched"))
            .filter_map(|e| {
                let md = e.metadata().ok()?;
                Some((md.modified().ok()?, e.path(), md.len()))
            })
            .collect();
        let mut total: u64 = files.iter().map(|&(_, _, size)| size).sum();
        if total <= cap {
            return;
        }
        // Oldest mtime first; path tie-break keeps coarse-timestamp
        // filesystems deterministic.
        files.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        for (_, path, size) in files {
            if total <= cap {
                break;
            }
            if keep.is_some_and(|k| k == path) {
                continue;
            }
            if std::fs::remove_file(&path).is_ok() {
                total -= size;
            }
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn entry_path(&self, fp: Fingerprint) -> PathBuf {
        self.dir.join(format!("{fp}.sched"))
    }

    /// Load the entry for `fp`; any unreadable/corrupt/stale/mismatched
    /// file is a miss (`None`), never an error. On a capped store, a hit
    /// refreshes the entry's mtime (best effort), so eviction is
    /// genuinely least-recently-*used* — a day-one entry hit on every
    /// run outlives newer never-reused entries.
    pub fn load(&self, fp: Fingerprint) -> Option<CachedSchedule> {
        let path = self.entry_path(fp);
        let bytes = std::fs::read(&path).ok()?;
        let cached = decode_entry(&bytes, fp)?;
        if self.cap_bytes.is_some() {
            let _ = std::fs::File::options().write(true).open(&path).and_then(|f| {
                f.set_times(std::fs::FileTimes::new().set_modified(std::time::SystemTime::now()))
            });
        }
        Some(cached)
    }

    /// Persist the entry for `fp` (best effort: write to a unique temp
    /// file, atomic rename into place; errors are swallowed).
    pub fn store(&self, fp: Fingerprint, cached: &CachedSchedule) {
        let bytes = encode_entry(fp, cached);
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let dst = self.entry_path(fp);
        if std::fs::write(&tmp, &bytes).is_err() || std::fs::rename(&tmp, &dst).is_err() {
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        self.prune(Some(&dst));
    }

    /// Number of (plausible) entries currently in the store directory.
    pub fn len(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().is_some_and(|x| x == "sched"))
                    .count()
            })
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn fnv64(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x1000_0000_01b3))
}

/// Encode a full store entry (header + payload) for `fp`.
pub fn encode_entry(fp: Fingerprint, cached: &CachedSchedule) -> Vec<u8> {
    let payload = encode_schedule(&cached.schedule);
    let mut out = Vec::with_capacity(payload.len() + 48);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&fp.0.to_le_bytes());
    out.extend_from_slice(&cached.seconds.to_bits().to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode a store entry, verifying it belongs to `expect`. `None` on any
/// corruption, version mismatch, or fingerprint mismatch.
pub fn decode_entry(bytes: &[u8], expect: Fingerprint) -> Option<CachedSchedule> {
    let mut r = Reader { buf: bytes };
    if r.take(MAGIC.len())? != MAGIC {
        return None;
    }
    if r.u32()? != FORMAT_VERSION {
        return None;
    }
    if r.u128()? != expect.0 {
        return None;
    }
    let seconds = r.f64()?;
    let len = r.len()?;
    let hash = r.u64()?;
    let payload = r.take(len)?;
    if !r.buf.is_empty() || fnv64(payload) != hash {
        return None;
    }
    let schedule = decode_schedule(payload)?;
    Some(CachedSchedule { schedule: std::sync::Arc::new(schedule), seconds })
}

fn encode_schedule(s: &Schedule) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + s.tasks.len() * 40);
    out.push(algo_tag(s.algorithm) as u8);
    out.push(policy_tag(s.policy) as u8);
    out.push(s.valid as u8);
    out.extend_from_slice(&s.makespan.to_bits().to_le_bytes());
    out.extend_from_slice(&(s.rank_order.len() as u64).to_le_bytes());
    for &v in &s.rank_order {
        out.extend_from_slice(&(v as u64).to_le_bytes());
    }
    out.extend_from_slice(&(s.tasks.len() as u64).to_le_bytes());
    for t in &s.tasks {
        out.extend_from_slice(&(t.proc as u64).to_le_bytes());
        out.extend_from_slice(&t.start.to_bits().to_le_bytes());
        out.extend_from_slice(&t.finish.to_bits().to_le_bytes());
        out.push(t.res_nonneg as u8);
        out.extend_from_slice(&(t.evicted.len() as u64).to_le_bytes());
        for &e in &t.evicted {
            out.extend_from_slice(&(e as u64).to_le_bytes());
        }
    }
    out.extend_from_slice(&(s.failures.len() as u64).to_le_bytes());
    for f in &s.failures {
        match f {
            Failure::OutOfMemory { task } => {
                out.push(0);
                out.extend_from_slice(&(*task as u64).to_le_bytes());
                out.extend_from_slice(&0u64.to_le_bytes());
            }
            Failure::Overcommit { task, proc } => {
                out.push(1);
                out.extend_from_slice(&(*task as u64).to_le_bytes());
                out.extend_from_slice(&(*proc as u64).to_le_bytes());
            }
            Failure::ProcessorLost { task, proc } => {
                out.push(2);
                out.extend_from_slice(&(*task as u64).to_le_bytes());
                out.extend_from_slice(&(*proc as u64).to_le_bytes());
            }
        }
    }
    out.extend_from_slice(&(s.mem_peak_frac.len() as u64).to_le_bytes());
    for &f in &s.mem_peak_frac {
        out.extend_from_slice(&f.to_bits().to_le_bytes());
    }
    out
}

fn decode_schedule(payload: &[u8]) -> Option<Schedule> {
    let mut r = Reader { buf: payload };
    let algorithm = algo_from_tag(r.u8()? as u64)?;
    let policy = policy_from_tag(r.u8()? as u64)?;
    let valid = r.bool()?;
    let makespan = r.f64()?;
    let n = r.checked_len(8)?;
    let mut rank_order = Vec::with_capacity(n);
    for _ in 0..n {
        rank_order.push(r.len()?);
    }
    let n = r.checked_len(33)?; // fixed part of one task record
    let mut tasks = Vec::with_capacity(n);
    for _ in 0..n {
        let proc = r.len()?;
        let start = r.f64()?;
        let finish = r.f64()?;
        let res_nonneg = r.bool()?;
        let ne = r.checked_len(8)?;
        let mut evicted = Vec::with_capacity(ne);
        for _ in 0..ne {
            evicted.push(r.len()?);
        }
        tasks.push(TaskSchedule { proc, start, finish, evicted, res_nonneg });
    }
    let n = r.checked_len(17)?;
    let mut failures = Vec::with_capacity(n);
    for _ in 0..n {
        let tag = r.u8()?;
        let task = r.len()?;
        let proc = r.len()?;
        failures.push(match tag {
            0 => Failure::OutOfMemory { task },
            1 => Failure::Overcommit { task, proc },
            2 => Failure::ProcessorLost { task, proc },
            _ => return None,
        });
    }
    let n = r.checked_len(8)?;
    let mut mem_peak_frac = Vec::with_capacity(n);
    for _ in 0..n {
        mem_peak_frac.push(r.f64()?);
    }
    if !r.buf.is_empty() {
        return None; // trailing garbage
    }
    Some(Schedule { algorithm, policy, rank_order, tasks, valid, failures, makespan, mem_peak_frac })
}

/// Bounds-checked little-endian cursor; every accessor returns `None`
/// past the end, so decoding corrupt bytes can only miss, never panic.
struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.buf.len() < n {
            return None;
        }
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        Some(head)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn bool(&mut self) -> Option<bool> {
        match self.u8()? {
            0 => Some(false),
            1 => Some(true),
            _ => None, // strictness helps reject garbage early
        }
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn u128(&mut self) -> Option<u128> {
        Some(u128::from_le_bytes(self.take(16)?.try_into().ok()?))
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }

    /// A u64 that must fit `usize`.
    fn len(&mut self) -> Option<usize> {
        usize::try_from(self.u64()?).ok()
    }

    /// A length field for records of at least `elem_bytes` each: rejected
    /// (miss) when it exceeds the remaining bytes, so corrupt lengths
    /// cannot trigger huge allocations.
    fn checked_len(&mut self, elem_bytes: usize) -> Option<usize> {
        let n = self.len()?;
        if n > self.buf.len() / elem_bytes.max(1) {
            return None;
        }
        Some(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
    use crate::service::fingerprint::schedule_fingerprint;
    use crate::workflow::WorkflowBuilder;
    use std::sync::Arc;

    fn sample_cached() -> (Fingerprint, CachedSchedule) {
        let mut b = WorkflowBuilder::new("disk");
        let a = b.task("a", "t", 5.0, 10.0);
        let c = b.task("c", "t", 7.0, 20.0);
        let d = b.task("d", "t", 2.0, 15.0);
        b.edge(a, c, 3.0);
        b.edge(a, d, 4.0);
        let wf = b.build().unwrap();
        let cluster = small_cluster();
        let fp = schedule_fingerprint(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        (fp, CachedSchedule { schedule: Arc::new(s), seconds: 0.125 })
    }

    fn schedules_equal(a: &Schedule, b: &Schedule) {
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.policy, b.policy);
        assert_eq!(a.valid, b.valid);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.rank_order, b.rank_order);
        assert_eq!(a.failures, b.failures);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.proc, y.proc);
            assert_eq!(x.start.to_bits(), y.start.to_bits());
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
            assert_eq!(x.evicted, y.evicted);
            assert_eq!(x.res_nonneg, y.res_nonneg);
        }
        assert_eq!(
            a.mem_peak_frac.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            b.mem_peak_frac.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn entry_round_trips_bit_exactly() {
        let (fp, cached) = sample_cached();
        let bytes = encode_entry(fp, &cached);
        let back = decode_entry(&bytes, fp).expect("valid entry decodes");
        assert_eq!(back.seconds, cached.seconds);
        schedules_equal(&back.schedule, &cached.schedule);
    }

    #[test]
    fn wrong_fingerprint_is_a_miss() {
        let (fp, cached) = sample_cached();
        let bytes = encode_entry(fp, &cached);
        assert!(decode_entry(&bytes, Fingerprint(fp.0 ^ 1)).is_none());
    }

    #[test]
    fn wrong_version_is_a_miss() {
        let (fp, cached) = sample_cached();
        let mut bytes = encode_entry(fp, &cached);
        bytes[8] = bytes[8].wrapping_add(1); // first version byte
        assert!(decode_entry(&bytes, fp).is_none());
    }

    #[test]
    fn every_truncation_is_a_miss_not_a_panic() {
        let (fp, cached) = sample_cached();
        let bytes = encode_entry(fp, &cached);
        for cut in 0..bytes.len() {
            assert!(decode_entry(&bytes[..cut], fp).is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn flipped_payload_bytes_are_a_miss() {
        let (fp, cached) = sample_cached();
        let bytes = encode_entry(fp, &cached);
        // Flip every payload byte in turn; the hash (or a strict field
        // check) must reject each mutant.
        let payload_start = 8 + 4 + 16 + 8 + 8 + 8;
        for i in payload_start..bytes.len() {
            let mut mutant = bytes.clone();
            mutant[i] ^= 0xa5;
            assert!(decode_entry(&mutant, fp).is_none(), "flip at {i}");
        }
    }

    #[test]
    fn oversized_length_fields_do_not_allocate() {
        let (fp, cached) = sample_cached();
        // Hand-build an entry whose payload claims 2^60 rank entries but
        // passes the hash check: decode must reject via checked_len.
        let mut payload = vec![
            algo_tag(Algorithm::HeftmBl) as u8,
            policy_tag(EvictionPolicy::LargestFirst) as u8,
            1,
        ];
        payload.extend_from_slice(&1.0f64.to_bits().to_le_bytes());
        payload.extend_from_slice(&(1u64 << 60).to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        bytes.extend_from_slice(&fp.0.to_le_bytes());
        bytes.extend_from_slice(&cached.seconds.to_bits().to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&fnv64(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        assert!(decode_entry(&bytes, fp).is_none());
    }

    #[test]
    fn store_round_trips_through_files() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = DiskStore::open(&dir).unwrap();
        let (fp, cached) = sample_cached();
        assert!(store.load(fp).is_none(), "empty store misses");
        store.store(fp, &cached);
        assert_eq!(store.len(), 1);
        let back = store.load(fp).expect("stored entry loads");
        schedules_equal(&back.schedule, &cached.schedule);
        // A renamed entry (collision-shaped: valid bytes, wrong name)
        // must miss via the embedded fingerprint.
        let other = Fingerprint(fp.0 ^ 7);
        std::fs::copy(dir.join(format!("{fp}.sched")), dir.join(format!("{other}.sched"))).unwrap();
        assert!(store.load(other).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn garbage_files_miss_without_panicking() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_garbage_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = DiskStore::open(&dir).unwrap();
        let (fp, _) = sample_cached();
        for garbage in [&b""[..], b"x", b"MEMSCHEDMEMSCHEDMEMSCHED", &[0u8; 4096]] {
            std::fs::write(dir.join(format!("{fp}.sched")), garbage).unwrap();
            assert!(store.load(fp).is_none());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// One cached entry per algorithm (same workflow/cluster), so the
    /// LRU tests have several distinct fingerprints to juggle.
    fn cached_per_algo() -> Vec<(Fingerprint, CachedSchedule)> {
        let mut b = WorkflowBuilder::new("disk_lru");
        let a = b.task("a", "t", 5.0, 10.0);
        let c = b.task("c", "t", 7.0, 20.0);
        let d = b.task("d", "t", 2.0, 15.0);
        b.edge(a, c, 3.0);
        b.edge(c, d, 4.0);
        let wf = b.build().unwrap();
        let cluster = small_cluster();
        Algorithm::all()
            .iter()
            .copied()
            .map(|algo| {
                let fp = schedule_fingerprint(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
                let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
                (fp, CachedSchedule { schedule: Arc::new(s), seconds: 0.0 })
            })
            .collect()
    }

    /// Pin a `.sched` entry's mtime to `secs_ago` seconds in the past —
    /// sleeping between writes would be flaky on filesystems with
    /// coarse (e.g. 1 s) mtime granularity.
    fn age_entry(dir: &Path, fp: Fingerprint, secs_ago: u64) {
        let path = dir.join(format!("{fp}.sched"));
        let t = std::time::SystemTime::now() - std::time::Duration::from_secs(secs_ago);
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_times(std::fs::FileTimes::new().set_modified(t)).unwrap();
    }

    /// Write `entries` through an unbounded store, then pin strictly
    /// decreasing ages (entries[0] oldest).
    fn aged_store(dir: &Path, entries: &[(Fingerprint, CachedSchedule)]) {
        let unbounded = DiskStore::open(dir).unwrap();
        for e in entries {
            unbounded.store(e.0, &e.1);
        }
        for (i, e) in entries.iter().enumerate() {
            age_entry(dir, e.0, ((entries.len() - i) * 100) as u64);
        }
    }

    #[test]
    fn byte_cap_evicts_oldest_mtime_entries_first() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_lru_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let entries = cached_per_algo();
        let size = |e: &(Fingerprint, CachedSchedule)| encode_entry(e.0, &e.1).len() as u64;
        // Age the first three entries (entries[0] oldest), then write the
        // fourth through a store capped to fit exactly the two newest:
        // the post-write prune must evict the two oldest-mtime entries.
        aged_store(&dir, &entries[..3]);
        let cap = size(&entries[2]) + size(&entries[3]);
        let store = DiskStore::open_capped(&dir, Some(cap)).unwrap();
        store.store(entries[3].0, &entries[3].1);
        assert!(store.load(entries[0].0).is_none(), "oldest entry must be evicted");
        assert!(store.load(entries[1].0).is_none(), "second-oldest entry must be evicted");
        assert!(store.load(entries[2].0).is_some());
        assert!(store.load(entries[3].0).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn byte_cap_never_evicts_the_just_written_entry() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_keep_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let entries = cached_per_algo();
        aged_store(&dir, &entries[..3]);
        // A 1-byte cap: every entry is oversized, but the entry just
        // written survives (it evicts everything else instead).
        let store = DiskStore::open_capped(&dir, Some(1)).unwrap();
        assert_eq!(store.len(), 0, "open-time prune clears the over-budget store");
        store.store(entries[3].0, &entries[3].1);
        assert_eq!(store.len(), 1, "only the most recent write survives");
        assert!(store.load(entries[3].0).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_hits_refresh_recency_on_a_capped_store() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_touch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let entries = cached_per_algo();
        let size = |e: &(Fingerprint, CachedSchedule)| encode_entry(e.0, &e.1).len() as u64;
        aged_store(&dir, &entries[..3]);
        // Cap fits exactly the three resident entries (open prune is a
        // no-op). Loading the *oldest*-written entry refreshes its
        // mtime, so when the fourth write forces an eviction the victim
        // is the now-least-recently-used entries[1], not entries[0].
        let cap = size(&entries[0]) + size(&entries[1]) + size(&entries[2]);
        let store = DiskStore::open_capped(&dir, Some(cap)).unwrap();
        assert!(store.load(entries[0].0).is_some(), "hit refreshes mtime");
        store.store(entries[3].0, &entries[3].1);
        assert!(store.load(entries[0].0).is_some(), "recently used entry survives");
        assert!(store.load(entries[1].0).is_none(), "LRU victim is the unused oldest entry");
        assert!(store.load(entries[3].0).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_prunes_an_over_budget_store() {
        let dir = std::env::temp_dir().join(format!("memsched_disk_open_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let entries = cached_per_algo();
        // Fill unbounded with aged entries, then reopen with a cap
        // fitting one: the open-time prune (ROADMAP's long-lived CI
        // cache case) shrinks the store to the newest entry.
        aged_store(&dir, &entries);
        assert_eq!(DiskStore::open(&dir).unwrap().len(), entries.len());
        let newest = entries.last().unwrap();
        let cap = encode_entry(newest.0, &newest.1).len() as u64;
        let capped = DiskStore::open_capped(&dir, Some(cap)).unwrap();
        assert_eq!(capped.len(), 1);
        assert!(capped.load(newest.0).is_some(), "newest entry survives the open prune");
        assert!(capped.load(entries[0].0).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tag_round_trips_match_fingerprint_tags() {
        for &algo in Algorithm::all() {
            assert_eq!(algo_from_tag(algo_tag(algo)), Some(algo));
        }
        for policy in [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst] {
            assert_eq!(policy_from_tag(policy_tag(policy)), Some(policy));
        }
        assert_eq!(algo_from_tag(99), None);
        assert_eq!(policy_from_tag(99), None);
    }
}
