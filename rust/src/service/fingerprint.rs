//! Content-addressed fingerprints for scheduling jobs.
//!
//! A fingerprint is a 128-bit FNV-1a hash over a canonical byte encoding
//! of everything that determines a job's *computed* outputs:
//!
//! - the workflow's structure and bound weights: task count, per-task
//!   `(w_u, m_u)` bit patterns, and every edge `(src, dst, c_{u,v})` in
//!   builder order (the CSR is derived from it, so builder order is
//!   canonical);
//! - the platform: per-processor `(speed, memory, comm_buffer)` and the
//!   interconnect bandwidth;
//! - the algorithm configuration: algorithm and eviction policy;
//! - for simulation jobs, the sim layer: mode, sigma, and deviation seed.
//!
//! Deliberately *excluded*: workflow/task/processor names and task types.
//! None of them influence a schedule or a simulated execution, so two
//! differently-named instances of the same weighted DAG dedupe to one
//! computation (each job's report still carries its own names).
//!
//! f64 values are hashed by their IEEE-754 bit pattern: fingerprint
//! equality then implies bit-identical inputs to the (deterministic)
//! scheduler and simulator, which is what the schedule cache requires.

use crate::platform::Cluster;
use crate::scheduler::{Algorithm, EvictionPolicy};
use crate::workflow::Workflow;

use super::job::SimJob;
use crate::simulator::SimMode;

/// A 128-bit fingerprint, printed as 32 hex digits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u128);

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Incremental FNV-1a over 128 bits.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u128,
}

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

impl Default for Hasher {
    fn default() -> Self {
        Hasher { state: FNV128_OFFSET }
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV128_PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length-prefixed so adjacent fields cannot alias.
    pub fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> Fingerprint {
        Fingerprint(self.state)
    }
}

/// Canonical numeric tag of an algorithm (shared by the fingerprint and
/// the disk-cache codec so the two encodings can never disagree).
pub(crate) fn algo_tag(algo: Algorithm) -> u64 {
    match algo {
        Algorithm::Heft => 0,
        Algorithm::HeftmBl => 1,
        Algorithm::HeftmBlc => 2,
        Algorithm::HeftmMm => 3,
        // Tags are append-only: 0–3 predate the portfolio work and are
        // baked into existing disk caches.
        Algorithm::Peft => 4,
        Algorithm::Lookahead => 5,
        Algorithm::Dls => 6,
        Algorithm::Portfolio => 7,
    }
}

/// Inverse of [`algo_tag`]; `None` for unknown tags (corrupt files).
/// Searches [`Algorithm::variants`] (not `all()`) so the portfolio
/// meta-algorithm's own tag round-trips too.
pub(crate) fn algo_from_tag(tag: u64) -> Option<Algorithm> {
    Algorithm::variants().iter().copied().find(|&a| algo_tag(a) == tag)
}

/// Canonical numeric tag of an eviction policy (see [`algo_tag`]).
pub(crate) fn policy_tag(policy: EvictionPolicy) -> u64 {
    match policy {
        EvictionPolicy::LargestFirst => 0,
        EvictionPolicy::SmallestFirst => 1,
    }
}

/// Inverse of [`policy_tag`]; `None` for unknown tags.
pub(crate) fn policy_from_tag(tag: u64) -> Option<EvictionPolicy> {
    [EvictionPolicy::LargestFirst, EvictionPolicy::SmallestFirst]
        .into_iter()
        .find(|&p| policy_tag(p) == tag)
}

/// Fingerprint of a *schedule computation*: workflow + platform + algo
/// config. This keys the schedule cache.
pub fn schedule_fingerprint(
    wf: &Workflow,
    cluster: &Cluster,
    algo: Algorithm,
    policy: EvictionPolicy,
) -> Fingerprint {
    let mut h = Hasher::new();
    h.write_str("memsched/schedule/v1");
    // Workflow structure + weights.
    h.write_usize(wf.num_tasks());
    for t in wf.tasks() {
        h.write_f64(t.work);
        h.write_f64(t.memory);
    }
    h.write_usize(wf.num_edges());
    for e in wf.edges() {
        h.write_usize(e.src);
        h.write_usize(e.dst);
        h.write_f64(e.data);
    }
    // Platform.
    h.write_usize(cluster.len());
    for p in &cluster.processors {
        h.write_f64(p.speed);
        h.write_f64(p.memory);
        h.write_f64(p.comm_buffer);
    }
    h.write_f64(cluster.bandwidth);
    // Algorithm configuration.
    h.write_u64(algo_tag(algo));
    h.write_u64(policy_tag(policy));
    h.finish()
}

/// Fingerprint of a full *job*: the schedule fingerprint plus the
/// optional simulation layer. This keys batch-level deduplication.
pub fn job_fingerprint(schedule_fp: Fingerprint, sim: Option<&SimJob>) -> Fingerprint {
    let mut h = Hasher::new();
    h.write_str("memsched/job/v1");
    h.write(&schedule_fp.0.to_le_bytes());
    match sim {
        None => h.write_u64(0),
        Some(s) => {
            h.write_u64(match s.mode {
                SimMode::FollowStatic => 1,
                SimMode::Recompute => 2,
            });
            h.write_f64(s.sigma);
            h.write_u64(s.seed);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::workflow::WorkflowBuilder;

    fn wf(name: &str, work0: f64) -> Workflow {
        let mut b = WorkflowBuilder::new(name);
        let a = b.task("a", "t", work0, 10.0);
        let c = b.task("c", "t", 2.0, 20.0);
        b.edge(a, c, 5.0);
        b.build().unwrap()
    }

    #[test]
    fn identical_inputs_identical_fingerprints() {
        let c = small_cluster();
        let f1 = schedule_fingerprint(&wf("x", 1.0), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let f2 = schedule_fingerprint(&wf("x", 1.0), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert_eq!(f1, f2);
    }

    #[test]
    fn names_do_not_matter_weights_do() {
        let c = small_cluster();
        let base = schedule_fingerprint(&wf("x", 1.0), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let renamed =
            schedule_fingerprint(&wf("other_name", 1.0), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert_eq!(base, renamed, "names are not part of the computation");
        let reweighted =
            schedule_fingerprint(&wf("x", 1.5), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert_ne!(base, reweighted, "weights are");
    }

    #[test]
    fn config_changes_fingerprint() {
        let c = small_cluster();
        let w = wf("x", 1.0);
        let bl = schedule_fingerprint(&w, &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let mm = schedule_fingerprint(&w, &c, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        let sm = schedule_fingerprint(&w, &c, Algorithm::HeftmBl, EvictionPolicy::SmallestFirst);
        assert_ne!(bl, mm);
        assert_ne!(bl, sm);
        let scaled = c.scale_memory(0.5, "half");
        let half = schedule_fingerprint(&w, &scaled, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert_ne!(bl, half);
    }

    #[test]
    fn sim_layer_separates_jobs() {
        let c = small_cluster();
        let sfp = schedule_fingerprint(&wf("x", 1.0), &c, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let none = job_fingerprint(sfp, None);
        let rec = job_fingerprint(
            sfp,
            Some(&SimJob { mode: SimMode::Recompute, sigma: 0.1, seed: 7 }),
        );
        let stat = job_fingerprint(
            sfp,
            Some(&SimJob { mode: SimMode::FollowStatic, sigma: 0.1, seed: 7 }),
        );
        let seed2 = job_fingerprint(
            sfp,
            Some(&SimJob { mode: SimMode::Recompute, sigma: 0.1, seed: 8 }),
        );
        assert_ne!(none, rec);
        assert_ne!(rec, stat);
        assert_ne!(rec, seed2);
    }

    #[test]
    fn algo_tags_unique_and_round_trip() {
        let mut seen = std::collections::HashSet::new();
        for &a in Algorithm::variants() {
            let tag = algo_tag(a);
            assert!(seen.insert(tag), "duplicate algo tag {tag}");
            assert_eq!(algo_from_tag(tag), Some(a), "tag {tag} must round-trip");
        }
        // Pre-portfolio caches encode exactly these tags; keep them frozen.
        assert_eq!(algo_tag(Algorithm::Heft), 0);
        assert_eq!(algo_tag(Algorithm::HeftmMm), 3);
        assert_eq!(algo_from_tag(999), None);
    }

    #[test]
    fn display_is_32_hex_digits() {
        let s = Fingerprint(0xabc).to_string();
        assert_eq!(s.len(), 32);
        assert!(s.ends_with("abc"));
    }
}
