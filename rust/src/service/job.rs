//! The batch service's job and result model.
//!
//! A [`Job`] names everything one scheduling request needs: a workflow
//! source (generator spec or file), a platform, the algorithm/eviction
//! configuration, and optionally a runtime-simulation layer. A
//! [`JobResult`] is the deterministic summary streamed back as one JSONL
//! line — it deliberately contains no wall-clock fields, so batch output
//! is byte-identical regardless of worker count (timings travel on the
//! side, in [`JobResult::seconds`], for harnesses that want them).
//!
//! Execution knobs (`--jobs` worker count, `--score-threads`
//! intra-schedule scoring threads, `--cache-bytes` cache budget) are
//! deliberately **not** part of a job or its fingerprint: they describe
//! *how* to compute, never *what*, and every computed value is identical
//! under any setting.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context as _};

use crate::experiments::WorkloadSpec;
use crate::platform::Cluster;
use crate::scheduler::{Algorithm, EvictionPolicy};
use crate::ser::json::{obj, Value};
use crate::simulator::{SimMode, SimOutcome};
use crate::workflow::Workflow;

/// Where a job's workflow comes from.
#[derive(Debug, Clone)]
pub enum JobSource {
    /// Generate + bind weights from a workload spec (deterministic in the
    /// spec's seed).
    Generated(WorkloadSpec),
    /// Load from a `.json` / `.dot` workflow file.
    File(PathBuf),
}

impl JobSource {
    /// Memoization key for the service's workflow cache.
    pub fn key(&self) -> String {
        match self {
            JobSource::Generated(spec) => format!("spec:{}:seed{}", spec.id(), spec.seed),
            JobSource::File(path) => format!("file:{}", path.display()),
        }
    }

    /// Build or load the workflow.
    pub fn materialize(&self) -> anyhow::Result<Workflow> {
        match self {
            JobSource::Generated(spec) => spec.build(),
            JobSource::File(path) => crate::workflow::io::load(path),
        }
    }
}

/// Platform selection: a name/path resolved via [`Cluster::load`], or a
/// pre-built cluster shared across jobs.
#[derive(Debug, Clone)]
pub enum ClusterSpec {
    Named(String),
    Inline(Arc<Cluster>),
}

impl ClusterSpec {
    /// Display label. Resolution itself goes through
    /// [`SchedulingService`](super::SchedulingService), which memoizes
    /// named/path loads once per distinct name.
    pub fn label(&self) -> String {
        match self {
            ClusterSpec::Named(name) => name.clone(),
            ClusterSpec::Inline(c) => c.name.clone(),
        }
    }
}

/// Optional runtime-simulation layer of a job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimJob {
    pub mode: SimMode,
    /// Relative deviation sigma (paper default 0.1).
    pub sigma: f64,
    /// Deviation seed.
    pub seed: u64,
}

/// One scheduling request.
#[derive(Debug, Clone)]
pub struct Job {
    pub source: JobSource,
    pub cluster: ClusterSpec,
    pub algo: Algorithm,
    pub policy: EvictionPolicy,
    pub sim: Option<SimJob>,
}

impl Job {
    /// A static-scheduling job with the default algorithm configuration.
    pub fn new(source: JobSource, cluster: ClusterSpec) -> Job {
        Job {
            source,
            cluster,
            algo: Algorithm::HeftmBl,
            policy: EvictionPolicy::LargestFirst,
            sim: None,
        }
    }

    pub fn with_algo(mut self, algo: Algorithm) -> Job {
        self.algo = algo;
        self
    }

    pub fn with_policy(mut self, policy: EvictionPolicy) -> Job {
        self.policy = policy;
        self
    }

    pub fn with_sim(mut self, sim: SimJob) -> Job {
        self.sim = Some(sim);
        self
    }
}

/// A replay sweep: one static-scheduling triple (workflow, cluster,
/// algorithm config) replayed under many deviation points.
///
/// The service computes (or cache-hits) the static schedule **once** per
/// sweep and fans the replay points across the worker pool
/// ([`run_replay_sweeps_streaming`]); the result stream is byte-identical
/// to submitting [`flatten`](ReplaySweep::flatten)'s per-point jobs
/// through the plain batch API — the sweep kind just amortizes the
/// workflow materialization and schedule fingerprinting, and guarantees
/// the one-schedule-many-replays execution shape.
///
/// [`run_replay_sweeps_streaming`]: super::SchedulingService::run_replay_sweeps_streaming
#[derive(Debug, Clone)]
pub struct ReplaySweep {
    pub source: JobSource,
    pub cluster: ClusterSpec,
    pub algo: Algorithm,
    pub policy: EvictionPolicy,
    /// Replay points, in emission order. An empty vector yields exactly
    /// one static (no-simulation) result, like a sim-less [`Job`].
    pub points: Vec<SimJob>,
}

impl ReplaySweep {
    /// A sweep with the default algorithm configuration and no points.
    pub fn new(source: JobSource, cluster: ClusterSpec) -> ReplaySweep {
        ReplaySweep {
            source,
            cluster,
            algo: Algorithm::HeftmBl,
            policy: EvictionPolicy::LargestFirst,
            points: Vec::new(),
        }
    }

    /// Wrap a plain job as a one-point (or zero-point) sweep.
    pub fn from_job(job: Job) -> ReplaySweep {
        ReplaySweep {
            source: job.source,
            cluster: job.cluster,
            algo: job.algo,
            policy: job.policy,
            points: job.sim.into_iter().collect(),
        }
    }

    pub fn with_algo(mut self, algo: Algorithm) -> ReplaySweep {
        self.algo = algo;
        self
    }

    pub fn with_policy(mut self, policy: EvictionPolicy) -> ReplaySweep {
        self.policy = policy;
        self
    }

    pub fn with_points(mut self, points: Vec<SimJob>) -> ReplaySweep {
        self.points = points;
        self
    }

    /// Number of results this sweep emits.
    pub fn num_results(&self) -> usize {
        self.points.len().max(1)
    }

    /// The equivalent per-point job list (the sweep's semantic ground
    /// truth: the service's sweep path must emit byte-identical results
    /// for this flattening).
    pub fn flatten(&self) -> Vec<Job> {
        let sims: Vec<Option<SimJob>> = if self.points.is_empty() {
            vec![None]
        } else {
            self.points.iter().copied().map(Some).collect()
        };
        sims.into_iter()
            .map(|sim| Job {
                source: self.source.clone(),
                cluster: self.cluster.clone(),
                algo: self.algo,
                policy: self.policy,
                sim,
            })
            .collect()
    }
}

/// Defaults a job line may omit: the CLI's `--cluster` and `--seed`
/// flags for `batch --input`, the daemon's `serve --cluster/--seed` for
/// frames. Keeping them in one struct guarantees the two entry points
/// can be configured identically.
#[derive(Debug, Clone)]
pub struct ParseDefaults {
    pub cluster: String,
    pub seed: u64,
}

impl Default for ParseDefaults {
    fn default() -> Self {
        ParseDefaults { cluster: "default".into(), seed: 42 }
    }
}

/// One submission: a plain job or a replay sweep. This is the unified
/// wire unit — `batch --input` lines and `serve` frames both parse into
/// a `JobSpec` through [`JobSpec::parse`], so the two front ends share
/// one grammar, one strictness policy, and one set of error messages.
#[derive(Debug, Clone)]
pub enum JobSpec {
    Single(Job),
    Sweep(ReplaySweep),
}

impl JobSpec {
    /// Parse one job object (a `batch --input` line or a serve frame
    /// payload). Strict: unknown keys, type mismatches, and unusable
    /// combinations (`sim` + `sweep`, generator knobs on file jobs) are
    /// errors — malformed input yields a structured error, never a
    /// panic or a silent default.
    pub fn parse(v: &Value, defaults: &ParseDefaults) -> anyhow::Result<JobSpec> {
        // Mirror Args::finish's strictness: a typo'd key must error, not
        // silently fall back to a default.
        const JOB_KEYS: [&str; 10] = [
            "workflow", "model", "tasks", "input", "seed", "cluster", "algo", "eviction", "sim",
            "sweep",
        ];
        let fields =
            v.as_object().ok_or_else(|| anyhow::anyhow!("job line must be a JSON object"))?;
        for (key, _) in fields {
            if !JOB_KEYS.contains(&key.as_str()) {
                bail!("unknown job field `{key}` (expected one of {})", JOB_KEYS.join(", "));
            }
        }
        let source = match (v.get("workflow"), v.get("model")) {
            (Some(wf), None) => {
                // Generator-only knobs on a file job would be silently
                // dead; reject them like any other unusable input.
                for generator_key in ["tasks", "input", "seed"] {
                    if v.get(generator_key).is_some() {
                        bail!(
                            "`{generator_key}` only applies to generated jobs (`model`), not `workflow` files"
                        );
                    }
                }
                let path = wf
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("`workflow` must be a file path string"))?;
                JobSource::File(PathBuf::from(path))
            }
            (None, Some(model)) => {
                let family = model
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("`model` must be a model name string"))?
                    .to_string();
                let size = match v.get("tasks") {
                    None => None,
                    Some(t) => Some(t.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("`tasks` must be a non-negative integer")
                    })?),
                };
                let input = match v.get("input") {
                    None => 2,
                    Some(i) => i.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("`input` must be a non-negative integer")
                    })?,
                };
                let seed = match v.get("seed") {
                    None => defaults.seed,
                    Some(s) => {
                        s.as_u64().ok_or_else(|| anyhow::anyhow!("`seed` must be an integer"))?
                    }
                };
                JobSource::Generated(WorkloadSpec { family, size, input, seed })
            }
            _ => bail!("a job needs exactly one of `workflow` (file) or `model` (generator)"),
        };
        let cluster = ClusterSpec::Named(match v.get("cluster") {
            None => defaults.cluster.clone(),
            Some(c) => c
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`cluster` must be a string"))?
                .to_string(),
        });
        let algo: Algorithm = match v.get("algo") {
            None => Algorithm::HeftmBl,
            Some(a) => a
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`algo` must be a string"))?
                .parse()?,
        };
        let policy: EvictionPolicy = match v.get("eviction") {
            None => EvictionPolicy::LargestFirst,
            Some(p) => p
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("`eviction` must be a string"))?
                .parse()?,
        };
        let sim = match v.get("sim") {
            None => None,
            Some(s) => Some(parse_sim_point(s, defaults.seed)?),
        };
        let job = Job { source, cluster, algo, policy, sim };
        match v.get("sweep") {
            None => Ok(JobSpec::Single(job)),
            Some(s) => {
                if job.sim.is_some() {
                    bail!("a job takes `sim` (one point) or `sweep` (many points), not both");
                }
                let points = s
                    .as_array()
                    .ok_or_else(|| anyhow::anyhow!("`sweep` must be an array of sim points"))?;
                let points = points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        parse_sim_point(p, defaults.seed)
                            .with_context(|| format!("sweep point {}", i + 1))
                    })
                    .collect::<anyhow::Result<Vec<SimJob>>>()?;
                Ok(JobSpec::Sweep(ReplaySweep::from_job(job).with_points(points)))
            }
        }
    }

    /// [`parse`](JobSpec::parse) from raw text (one JSON object).
    pub fn parse_line(line: &str, defaults: &ParseDefaults) -> anyhow::Result<JobSpec> {
        let v = Value::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
        JobSpec::parse(&v, defaults)
    }

    /// Number of result lines this spec emits.
    pub fn num_results(&self) -> usize {
        match self {
            JobSpec::Single(_) => 1,
            JobSpec::Sweep(s) => s.num_results(),
        }
    }

    /// The sweep form (a single job becomes a one/zero-point sweep);
    /// byte-identical results either way.
    pub fn into_sweep(self) -> ReplaySweep {
        match self {
            JobSpec::Single(job) => ReplaySweep::from_job(job),
            JobSpec::Sweep(s) => s,
        }
    }
}

/// One simulation point (`sim` object or a `sweep` array element).
fn parse_sim_point(s: &Value, default_seed: u64) -> anyhow::Result<SimJob> {
    const SIM_KEYS: [&str; 3] = ["mode", "sigma", "seed"];
    let fields =
        s.as_object().ok_or_else(|| anyhow::anyhow!("sim point must be a JSON object"))?;
    for (key, _) in fields {
        if !SIM_KEYS.contains(&key.as_str()) {
            bail!("unknown sim field `{key}` (expected one of {})", SIM_KEYS.join(", "));
        }
    }
    let mode: SimMode = s.req_str("mode")?.parse()?;
    let sigma = match s.get("sigma") {
        None => 0.1,
        Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("`sim.sigma` must be a number"))?,
    };
    let seed = match s.get("seed") {
        None => default_seed,
        Some(x) => x.as_u64().ok_or_else(|| anyhow::anyhow!("`sim.seed` must be an integer"))?,
    };
    Ok(SimJob { mode, sigma, seed })
}

/// One algorithm's entry in a portfolio run: its schedule validity and
/// the σ=0 replay makespan it was ranked by (`NaN` → serialized `null`
/// for invalid/incomplete candidates, which are never chosen while any
/// candidate completes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PortfolioCandidate {
    pub algo: Algorithm,
    pub valid: bool,
    pub sim_makespan: f64,
    /// True iff the σ=0 replay was skipped because this candidate's
    /// analytic makespan already exceeded the incumbent's simulated one
    /// (`sim_makespan` is then `NaN`/`null`).
    pub pruned: bool,
}

/// The deterministic record of one portfolio decision: every candidate
/// in [`Algorithm::all`] order plus the committed winner. Attached to a
/// result line only when the job ran `--algo portfolio`.
#[derive(Debug, Clone, PartialEq)]
pub struct PortfolioOutcome {
    pub chosen: Algorithm,
    pub candidates: Vec<PortfolioCandidate>,
}

impl PortfolioOutcome {
    /// The `portfolio` object of a result line (stable field order —
    /// part of the wire format).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("chosen", self.chosen.as_str().into()),
            (
                "candidates",
                Value::Array(
                    self.candidates
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("algorithm", c.algo.as_str().into()),
                                ("valid", c.valid.into()),
                                ("sim_makespan", c.sim_makespan.into()),
                                ("pruned", c.pruned.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Simulation outcome summary (deterministic fields only).
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    pub mode: SimMode,
    pub completed: bool,
    pub makespan: f64,
    pub recomputations: usize,
    pub started: usize,
}

impl SimResult {
    /// The summary of one simulated execution — the single mapping site
    /// from [`SimOutcome`] shared by the service's replay path and
    /// `memsched simulate --json`.
    pub fn from_outcome(mode: SimMode, out: &SimOutcome) -> SimResult {
        SimResult {
            mode,
            completed: out.completed,
            makespan: out.makespan,
            recomputations: out.recomputations,
            started: out.started,
        }
    }

    /// The deterministic `sim` object of a result line. `memsched
    /// simulate --json` prints exactly this value, and `ci.sh --smoke`
    /// byte-compares the two — one serializer, no drift.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("mode", self.mode.label().into()),
            ("completed", self.completed.into()),
            ("makespan", self.makespan.into()),
            ("recomputations", self.recomputations.into()),
            ("started", self.started.into()),
        ])
    }
}

/// One JSONL result line (also consumed structurally by the experiments
/// harness).
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Position of the job in its batch.
    pub id: usize,
    /// Non-`None` iff the job failed to materialize/resolve; all other
    /// payload fields are then meaningless.
    pub error: Option<String>,
    pub workflow: String,
    pub tasks: usize,
    pub cluster: String,
    pub algo: Algorithm,
    pub fingerprint: String,
    /// True iff this job was deduplicated against an earlier identical
    /// job of the batch, or its schedule was already cached when the
    /// batch started. Deterministic (decided before execution).
    pub cache_hit: bool,
    pub valid: bool,
    pub makespan: f64,
    /// Makespan lower bound of the (workflow, cluster) pair
    /// ([`crate::scheduler::lower_bound::makespan_lower_bound`]) —
    /// algorithm-independent, so equal across a workload's rows.
    pub lower_bound: f64,
    /// `(makespan − lower_bound) / lower_bound`, clamped at 0
    /// ([`crate::scheduler::lower_bound::optimality_gap`]); `NaN`
    /// (serialized `null`) when the makespan itself is `NaN`.
    pub optimality_gap: f64,
    pub mem_usage: f64,
    pub procs_used: usize,
    pub evictions: usize,
    /// Wall seconds of the schedule computation (shared by cache hits).
    /// Not serialized: wall times would break byte-determinism.
    pub seconds: f64,
    /// The portfolio decision record (`--algo portfolio` jobs only).
    pub portfolio: Option<PortfolioOutcome>,
    pub sim: Option<SimResult>,
}

impl JobResult {
    pub fn failed(id: usize, error: String) -> JobResult {
        JobResult {
            id,
            error: Some(error),
            workflow: String::new(),
            tasks: 0,
            cluster: String::new(),
            algo: Algorithm::HeftmBl,
            fingerprint: String::new(),
            cache_hit: false,
            valid: false,
            makespan: f64::NAN,
            lower_bound: f64::NAN,
            optimality_gap: f64::NAN,
            mem_usage: f64::NAN,
            procs_used: 0,
            evictions: 0,
            seconds: 0.0,
            portfolio: None,
            sim: None,
        }
    }

    /// The deterministic JSON value of this result.
    pub fn to_json(&self) -> Value {
        if let Some(err) = &self.error {
            return obj(vec![("id", self.id.into()), ("error", err.as_str().into())]);
        }
        let mut fields: Vec<(&str, Value)> = vec![
            ("id", self.id.into()),
            ("workflow", self.workflow.as_str().into()),
            ("tasks", self.tasks.into()),
            ("cluster", self.cluster.as_str().into()),
            ("algorithm", self.algo.label().into()),
            ("fingerprint", self.fingerprint.as_str().into()),
            ("cache_hit", self.cache_hit.into()),
            ("valid", self.valid.into()),
            ("makespan", self.makespan.into()),
            ("lower_bound", self.lower_bound.into()),
            ("optimality_gap", self.optimality_gap.into()),
            ("mem_usage", self.mem_usage.into()),
            ("procs_used", self.procs_used.into()),
            ("evictions", self.evictions.into()),
        ];
        if let Some(p) = &self.portfolio {
            fields.push(("portfolio", p.to_json()));
        }
        if let Some(sim) = &self.sim {
            fields.push(("sim", sim.to_json()));
        }
        obj(fields)
    }

    /// One compact JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        self.to_json().to_string_compact()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_line_roundtrips_and_orders_fields() {
        let r = JobResult {
            id: 3,
            error: None,
            workflow: "wf".into(),
            tasks: 10,
            cluster: "default".into(),
            algo: Algorithm::HeftmMm,
            fingerprint: "ff".into(),
            cache_hit: true,
            valid: true,
            makespan: 12.5,
            lower_bound: 10.0,
            optimality_gap: 0.25,
            mem_usage: 0.25,
            procs_used: 3,
            evictions: 1,
            seconds: 0.5,
            portfolio: None,
            sim: Some(SimResult {
                mode: SimMode::Recompute,
                completed: true,
                makespan: 13.0,
                recomputations: 2,
                started: 10,
            }),
        };
        let line = r.to_jsonl();
        assert!(line.starts_with("{\"id\":3,\"workflow\":\"wf\""), "{line}");
        let v = Value::parse(&line).unwrap();
        assert_eq!(v.req_f64("makespan").unwrap(), 12.5);
        assert_eq!(v.req_f64("lower_bound").unwrap(), 10.0);
        assert_eq!(v.req_f64("optimality_gap").unwrap(), 0.25);
        assert_eq!(v.get("sim").unwrap().req_usize("recomputations").unwrap(), 2);
        // Wall time must not leak into the line.
        assert!(!line.contains("seconds"));
    }

    #[test]
    fn portfolio_outcome_serializes_candidates_in_order() {
        let p = PortfolioOutcome {
            chosen: Algorithm::HeftmMm,
            candidates: vec![
                PortfolioCandidate {
                    algo: Algorithm::Heft,
                    valid: false,
                    sim_makespan: f64::NAN,
                    pruned: false,
                },
                PortfolioCandidate {
                    algo: Algorithm::HeftmMm,
                    valid: true,
                    sim_makespan: 9.5,
                    pruned: false,
                },
            ],
        };
        let line = p.to_json().to_string_compact();
        assert!(line.starts_with("{\"chosen\":\"heftm-mm\""), "{line}");
        // NaN scores (invalid candidates) serialize as null, not as
        // invalid JSON.
        assert!(line.contains("\"sim_makespan\":null"), "{line}");
        assert!(line.contains("\"sim_makespan\":9.5"), "{line}");
        assert!(line.contains("\"pruned\":false"), "{line}");
        let heft = line.find("\"heft\"").unwrap();
        let mm = line.rfind("\"heftm-mm\"").unwrap();
        assert!(heft < mm, "candidates keep Algorithm::all() order: {line}");
    }

    #[test]
    fn error_results_are_minimal() {
        let r = JobResult::failed(7, "boom".into());
        assert_eq!(r.to_jsonl(), "{\"id\":7,\"error\":\"boom\"}");
    }

    #[test]
    fn sweep_flattening_expands_points_in_order() {
        let source = JobSource::File(PathBuf::from("/tmp/wf.json"));
        let cluster = ClusterSpec::Named("default".into());
        let sweep = ReplaySweep::new(source.clone(), cluster.clone())
            .with_algo(Algorithm::HeftmMm)
            .with_points(vec![
                SimJob { mode: SimMode::Recompute, sigma: 0.1, seed: 7 },
                SimJob { mode: SimMode::FollowStatic, sigma: 0.3, seed: 7 },
            ]);
        assert_eq!(sweep.num_results(), 2);
        let flat = sweep.flatten();
        assert_eq!(flat.len(), 2);
        assert!(flat.iter().all(|j| j.algo == Algorithm::HeftmMm));
        assert_eq!(flat[0].sim.unwrap().sigma, 0.1);
        assert_eq!(flat[1].sim.unwrap().mode, SimMode::FollowStatic);
        // Point-less sweeps behave like a single static job.
        let empty = ReplaySweep::new(source, cluster);
        assert_eq!(empty.num_results(), 1);
        let flat = empty.flatten();
        assert_eq!(flat.len(), 1);
        assert!(flat[0].sim.is_none());
        // A plain job round-trips through the sweep form.
        let job = flat[0].clone().with_sim(SimJob { mode: SimMode::Recompute, sigma: 0.2, seed: 1 });
        let back = ReplaySweep::from_job(job.clone()).flatten();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].sim, job.sim);
    }

    #[test]
    fn job_spec_parses_singles_and_sweeps_with_defaults() {
        let d = ParseDefaults { cluster: "memory-constrained".into(), seed: 7 };
        // Generated job: omitted seed/cluster fall back to the defaults.
        let spec = JobSpec::parse_line(r#"{"model":"chipseq","tasks":50}"#, &d).unwrap();
        assert_eq!(spec.num_results(), 1);
        let JobSpec::Single(job) = &spec else { panic!("expected a single job") };
        match &job.source {
            JobSource::Generated(w) => {
                assert_eq!(w.seed, 7);
                assert_eq!(w.input, 2);
            }
            other => panic!("unexpected source {other:?}"),
        }
        assert_eq!(job.cluster.label(), "memory-constrained");
        // Sweep: sim-point defaults (sigma 0.1, the shared seed).
        let spec = JobSpec::parse_line(
            r#"{"model":"eager","sweep":[{"mode":"recompute"},{"mode":"static","sigma":0.3,"seed":2}]}"#,
            &d,
        )
        .unwrap();
        assert_eq!(spec.num_results(), 2);
        let JobSpec::Sweep(s) = spec else { panic!("expected a sweep") };
        assert_eq!(s.points[0].sigma, 0.1);
        assert_eq!(s.points[0].seed, 7);
        assert_eq!(s.points[1].seed, 2);
        // A single job converts into a one-point sweep losslessly.
        let spec =
            JobSpec::parse_line(r#"{"model":"bacass","sim":{"mode":"recompute"}}"#, &d).unwrap();
        let sweep = spec.into_sweep();
        assert_eq!(sweep.points.len(), 1);
    }

    #[test]
    fn job_spec_rejects_malformed_input_with_errors() {
        let d = ParseDefaults::default();
        for (line, needle) in [
            ("not json", "JSON parse error"),
            ("[1,2]", "must be a JSON object"),
            (r#"{"model":"x","typo":1}"#, "unknown job field `typo`"),
            (r#"{"model":"x","workflow":"y"}"#, "exactly one of"),
            (r#"{}"#, "exactly one of"),
            (r#"{"workflow":"wf.json","seed":3}"#, "only applies to generated jobs"),
            (r#"{"model":"x","sim":{"mode":"recompute"},"sweep":[]}"#, "not both"),
            (r#"{"model":"x","sweep":[{"mode":"recompute","oops":1}]}"#, "unknown sim field"),
            (r#"{"model":"x","tasks":"many"}"#, "non-negative integer"),
        ] {
            let err = format!("{:#}", JobSpec::parse_line(line, &d).unwrap_err());
            assert!(err.contains(needle), "line {line}: error `{err}` missing `{needle}`");
        }
    }

    #[test]
    fn source_keys_distinguish() {
        let a = JobSource::Generated(WorkloadSpec {
            family: "chipseq".into(),
            size: Some(200),
            input: 1,
            seed: 5,
        });
        let b = JobSource::Generated(WorkloadSpec {
            family: "chipseq".into(),
            size: Some(200),
            input: 1,
            seed: 6,
        });
        assert_ne!(a.key(), b.key());
        let f = JobSource::File(PathBuf::from("/tmp/x.json"));
        assert!(f.key().starts_with("file:"));
    }
}
