//! Parallel scheduling service: batched jobs over a sharded
//! work-stealing pool, with a content-addressed schedule cache and a
//! deterministic result-ordering layer (see DESIGN.md §Service).
//!
//! One [`Job`] = workflow source + platform + algorithm/eviction config +
//! optional simulation layer. [`SchedulingService::run_batch`] executes a
//! batch on `workers` threads and returns one [`JobResult`] per job, in
//! submission order, with **byte-identical** JSONL output regardless of
//! the worker count:
//!
//! 1. *Materialize* (parallel): each job's workflow is built/loaded (memo
//!    by source, so e.g. four algorithms on one workload share one DAG
//!    build) and fingerprinted ([`fingerprint`]).
//! 2. *Group* (sequential, deterministic): jobs with equal fingerprints
//!    dedupe — the lowest-id job of each group computes, the rest are
//!    cache hits. Pre-cached schedules (earlier batches on the same
//!    service) are marked here too, *before* any execution, so the
//!    `cache_hit` flags in the output never depend on thread timing.
//! 3. *Execute + emit* (parallel): unique jobs run on the pool
//!    ([`pool`]); the schedule cache ([`cache`]) additionally shares
//!    identical schedule computations *across* distinct jobs (e.g. the
//!    two simulation modes of one workload). Results are emitted in
//!    submission order **as the ordered prefix completes**
//!    ([`SchedulingService::run_batch_streaming`]) — long batches
//!    stream instead of buffering until the end.
//!
//! Two orthogonal parallelism axes compose here: `workers` shards the
//! batch across jobs, while [`ServiceConfig::score`] attaches a shared
//! [`pool::ScorePool`] that parallelizes the *inside* of each
//! schedule computation (per-processor tentative scoring — the lever for
//! one huge workflow that would otherwise pin a single core;
//! [`ScoreThreadSpec::Auto`] engages it per schedule only above the
//! measured crossover). Both axes preserve byte-identical output.
//! Construction goes through one surface —
//! [`SchedulingService::from_config`] on a [`ServiceConfig`] — shared
//! by the CLI commands, the experiment suites, and the `memsched
//! serve` daemon ([`serve`]).
//!
//! On top of the per-job batch API sits the **replay engine**
//! ([`SchedulingService::run_replay_sweeps_streaming`]): a
//! [`ReplaySweep`] carries one `(workflow, cluster, algo)` triple plus a
//! vector of `(sigma, seed, mode)` replay points; the schedule is
//! materialized, fingerprinted, and computed once, and the replay points
//! fan out across the pool — the execution shape behind multi-sigma
//! deviation sweeps (`--sigmas`). Its output is byte-identical to
//! flattening each sweep into per-point jobs. The simulation side is
//! amortized the same way: one [`SimScaffold`] per sweep (a `OnceLock`
//! cell shared by the sweep's points; `scaffolds_built` in the run
//! summary counts them) and one thread-local [`SimRun`] arena per pool
//! worker, reset between points instead of reallocated (see
//! `simulator`'s module docs).
//!
//! The schedule cache optionally layers a **disk-backed store**
//! ([`disk`], `--cache-dir`): content-addressed files keyed by the
//! 128-bit schedule fingerprint, atomic rename on write, corrupt/stale
//! entries degrading to a recompute — so repeated CLI invocations and CI
//! runs share schedules across processes.
//!
//! The experiments harness submits its Quick/Full suite grids through
//! this service (`experiments::run_static_suite` /
//! `run_dynamic_suite`), and the `memsched batch` CLI exposes it as a
//! JSONL-in/JSONL-out interface.

pub mod cache;
pub mod disk;
pub mod fingerprint;
pub mod job;
pub mod pool;
pub mod serve;

pub use cache::{CacheStats, CachedSchedule, OnceMap, ScheduleCache};
pub use disk::DiskStore;
pub use fingerprint::Fingerprint;
pub use job::{
    ClusterSpec, Job, JobResult, JobSource, JobSpec, ParseDefaults, PortfolioCandidate,
    PortfolioOutcome, ReplaySweep, SimJob, SimResult,
};
pub use pool::ScorePool;
pub use serve::{ServeOptions, ServeSummary};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs;
use crate::platform::Cluster;
use crate::scheduler::lower_bound::{makespan_lower_bound, optimality_gap};
use crate::scheduler::{Algorithm, EvictionPolicy, Schedule, ScheduleRequest};
use crate::ser::json::{obj, Value};
use crate::simulator::{DeviationModel, SimConfig, SimMode, SimOutcome, SimRun, SimScaffold};
use crate::workflow::Workflow;

thread_local! {
    /// Per-worker reusable simulation arena: replay points executing on
    /// this thread reset it in place instead of reallocating run state
    /// ([`SimRun`]). Outcomes are bit-identical to fresh runs, so batch
    /// bytes stay independent of which worker executes which point.
    static SIM_ARENA: RefCell<SimRun> = RefCell::new(SimRun::new());
}

/// How many intra-schedule scoring threads to apply (the
/// `--score-threads` knob; parsed from `auto` or a number).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreThreadSpec {
    /// Exactly this many threads (1 ⇒ serial scoring).
    Fixed(usize),
    /// Decide per schedule: serial below the measured crossover
    /// ([`scheduler::auto_score_threads`](crate::scheduler::auto_score_threads)),
    /// all cores above it. Schedules are byte-identical either way.
    Auto,
}

impl Default for ScoreThreadSpec {
    fn default() -> Self {
        ScoreThreadSpec::Fixed(1)
    }
}

impl std::str::FromStr for ScoreThreadSpec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(ScoreThreadSpec::Auto);
        }
        s.parse::<usize>()
            .map(|n| ScoreThreadSpec::Fixed(n.max(1)))
            .map_err(|_| anyhow::anyhow!("invalid score-thread spec `{s}` (expected a number or `auto`)"))
    }
}

/// Declarative service configuration shared by the CLI commands and the
/// suite runners: worker count, scoring threads, and cache layers.
/// `Default` is manual: `portfolio_prune` defaults to **on**.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Batch worker threads (0 ⇒ all cores).
    pub workers: usize,
    pub score: ScoreThreadSpec,
    /// Independent intra-schedule scoring pools (`--score-pools`; 0 or
    /// 1 ⇒ one shared pool). [`pool::ScorePool::scoped_for`] serializes
    /// concurrent callers, so with `workers > 1` and large schedules the
    /// single shared pool is a structural bottleneck: worker threads
    /// queue on its caller lock. `N > 1` builds N pools and sticks each
    /// worker thread to one (round-robin), letting up to N schedule
    /// computations score in parallel. Output bytes are identical for
    /// any value; total scoring threads are `score × score_pools`, so
    /// size the product to the machine.
    pub score_pools: usize,
    /// LRU byte cap on the in-memory schedule cache (`None` = unbounded).
    pub cache_bytes: Option<usize>,
    /// Disk-backed schedule cache directory (`--cache-dir`).
    pub cache_dir: Option<PathBuf>,
    /// LRU-by-mtime byte cap on the disk cache (`--cache-dir-bytes`;
    /// `None` = unbounded). Requires `cache_dir`.
    pub cache_dir_bytes: Option<u64>,
    /// Skip a portfolio candidate's σ=0 replay once its *analytic*
    /// makespan already exceeds the incumbent's *simulated* one (on, the
    /// default). The heuristic is near-exact — the σ=0 replay tracks the
    /// analytic makespan closely but not provably from above (see
    /// DESIGN.md §Portfolio) — so this knob keeps the exhaustive replay
    /// available for verification.
    pub portfolio_prune: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 0,
            score: ScoreThreadSpec::default(),
            score_pools: 0,
            cache_bytes: None,
            cache_dir: None,
            cache_dir_bytes: None,
            portfolio_prune: true,
        }
    }
}

impl ServiceConfig {
    /// Build a service from this configuration (fails only if the cache
    /// directory cannot be created, or on an inconsistent combination).
    /// Equivalent to [`SchedulingService::from_config`].
    pub fn build(&self) -> anyhow::Result<SchedulingService> {
        SchedulingService::from_config(self.clone())
    }
}

/// Compute-once memo over a generic [`OnceMap`]: per key, one cell so
/// concurrent requesters block on a single initializer instead of
/// duplicating work. Within a batch an error is stable (every duplicate
/// of a failing source observes the same single attempt — no re-loads,
/// no worker-count-dependent mixed results); failed entries are pruned
/// at batch boundaries ([`prune_errors`](Memo::prune_errors)), so a
/// transient failure (e.g. a workflow file that appears later) can be
/// retried by a subsequent batch rather than poisoning the key for the
/// service's lifetime.
#[derive(Debug)]
struct Memo<V: Clone> {
    map: OnceMap<String, Result<V, String>>,
}

// Manual (a derive would needlessly bound `V: Default`).
impl<V: Clone> Default for Memo<V> {
    fn default() -> Self {
        Memo { map: OnceMap::new() }
    }
}

impl<V: Clone> Memo<V> {
    fn get_or_try_init<F: FnOnce() -> Result<V, String>>(&self, key: &str, init: F) -> Result<V, String> {
        // Memo entries are metadata-sized next to cached schedules, and
        // the memo is unbounded — weigh 0.
        self.map.get_or_init(&key.to_string(), init, |_| 0)
    }

    /// Drop entries whose initialization failed (called between
    /// batches, when no initializations are in flight).
    fn prune_errors(&self) {
        self.map.retain(|_, v| v.is_none_or(|r| r.is_ok()));
    }
}

/// A multi-threaded scheduling service with a persistent (per-instance)
/// schedule cache and workflow memo.
#[derive(Debug)]
pub struct SchedulingService {
    workers: usize,
    /// Intra-schedule scoring pools (empty ⇒ serial scoring). Usually a
    /// single shared pool; [`ServiceConfig::score_pools`] `> 1` builds
    /// several and each worker thread sticks to one
    /// ([`pick_score_pool`](Self::pick_score_pool)).
    score_pools: Vec<ScorePool>,
    /// Round-robin cursor handing worker threads their pool slot.
    pool_slot: AtomicUsize,
    /// Auto mode: gate the pool per schedule via the fan-in crossover
    /// heuristic ([`crate::scheduler::auto_score_threads`]).
    score_auto: bool,
    schedules: ScheduleCache,
    /// Cache configuration retained so [`rebuild_cache`]
    /// (construction-time) can recreate the cache with both layers.
    ///
    /// [`rebuild_cache`]: SchedulingService::rebuild_cache
    cache_bytes: Option<usize>,
    cache_disk: Option<Arc<DiskStore>>,
    workflows: Memo<Arc<Workflow>>,
    clusters: Memo<Arc<Cluster>>,
    /// [`SimScaffold`]s constructed: one per replay sweep (shared by all
    /// of its points via a `OnceLock`), one per plain simulation job,
    /// one per portfolio candidate replay.
    scaffolds_built: AtomicUsize,
    /// Portfolio decisions committed (one per executed `--algo
    /// portfolio` job; deduped portfolio jobs reuse the original's).
    portfolio_commits: AtomicUsize,
    /// Whether portfolio candidate replays are pruned by the analytic
    /// bound ([`ServiceConfig::portfolio_prune`]).
    portfolio_prune: bool,
    /// Portfolio candidate replays skipped by the prune.
    replays_pruned: AtomicUsize,
}

impl Default for SchedulingService {
    /// A single-worker service (same clamp as `new(0)`).
    fn default() -> Self {
        SchedulingService::new(1)
    }
}

/// Phase-1 product: everything execution needs, fingerprinted. Cloning
/// is cheap (two `Arc`s + two `Copy` fingerprints) — the replay-sweep
/// path clones one prepared sweep per replay point.
#[derive(Clone)]
struct Prepared {
    wf: Arc<Workflow>,
    cluster: Arc<Cluster>,
    sched_fp: Fingerprint,
    job_fp: Fingerprint,
    /// Makespan lower bound of the (workflow, cluster) pair — computed
    /// once per preparation (per sweep on the sweep path) and reported
    /// on every result row as `lower_bound` / `optimality_gap`.
    lower_bound: f64,
    /// Simulation-scaffold cell shared by every replay point of one
    /// sweep, so the scaffold is built exactly once per sweep (by
    /// whichever point executes first). `None` for plain jobs — each
    /// builds its own scaffold when it carries a simulation layer.
    scaffold: Option<Arc<OnceLock<Arc<SimScaffold>>>>,
}

/// Phase-3 product: the deterministic result payload of one unique job.
#[derive(Debug, Clone)]
struct Executed {
    valid: bool,
    makespan: f64,
    mem_usage: f64,
    procs_used: usize,
    evictions: usize,
    seconds: f64,
    /// The portfolio decision record (`--algo portfolio` jobs only).
    portfolio: Option<PortfolioOutcome>,
    sim: Option<SimResult>,
}

impl SchedulingService {
    /// A service executing batches on `workers` threads (0 ⇒ 1).
    pub fn new(workers: usize) -> SchedulingService {
        SchedulingService {
            workers: workers.max(1),
            score_pools: Vec::new(),
            pool_slot: AtomicUsize::new(0),
            score_auto: false,
            schedules: ScheduleCache::new(),
            cache_bytes: None,
            cache_disk: None,
            workflows: Memo::default(),
            clusters: Memo::default(),
            scaffolds_built: AtomicUsize::new(0),
            portfolio_commits: AtomicUsize::new(0),
            portfolio_prune: true,
            replays_pruned: AtomicUsize::new(0),
        }
    }

    /// A service sized to the machine.
    pub fn with_default_workers() -> SchedulingService {
        SchedulingService::new(pool::default_workers())
    }

    /// The single construction surface: build a fully-configured service
    /// from a [`ServiceConfig`] (worker count, scoring threads, cache
    /// layers). The CLI commands, the experiment suites, and the
    /// `memsched serve` daemon all construct their services here. Fails
    /// only if the cache directory cannot be created or on an
    /// inconsistent combination (`cache_dir_bytes` without `cache_dir`).
    ///
    /// Cache-cap determinism scope: every payload value (schedules,
    /// makespans, sim outcomes) stays byte-identical under any
    /// `cache_bytes` cap — evicted fingerprints recompute to the same
    /// result. But LRU stamps follow execution order, so *which* entries
    /// survive into the next batch can vary with thread timing; across
    /// **multiple batches on one capped service**, `cache_hit` flags (a
    /// residency observation, fixed per batch before execution) may
    /// therefore differ between runs. Single-batch output is always
    /// fully deterministic; leave the cap unbounded where cross-batch
    /// flag stability matters.
    pub fn from_config(cfg: ServiceConfig) -> anyhow::Result<SchedulingService> {
        let workers = if cfg.workers == 0 { pool::default_workers() } else { cfg.workers };
        let mut svc = SchedulingService::new(workers);
        svc.set_score_spec(cfg.score, cfg.score_pools);
        svc.cache_bytes = cfg.cache_bytes;
        svc.portfolio_prune = cfg.portfolio_prune;
        match (&cfg.cache_dir, cfg.cache_dir_bytes) {
            (Some(dir), cap) => {
                svc.cache_disk = Some(Arc::new(DiskStore::open_capped(dir, cap)?));
            }
            (None, Some(_)) => anyhow::bail!("--cache-dir-bytes requires --cache-dir"),
            (None, None) => {}
        }
        svc.rebuild_cache();
        Ok(svc)
    }

    /// Apply a [`ScoreThreadSpec`]: `Fixed(n)` attaches n-thread scoring
    /// pools (n ≤ 1 ⇒ serial); `Auto` sizes pools to all cores but
    /// engages them per schedule only above the measured crossover
    /// ([`crate::scheduler::auto_score_threads`]). `pools` (0 ⇒ 1)
    /// controls how many independent pools are built — see
    /// [`ServiceConfig::score_pools`]. Byte-identical output whatever
    /// the combination.
    fn set_score_spec(&mut self, spec: ScoreThreadSpec, pools: usize) {
        let threads = match spec {
            ScoreThreadSpec::Fixed(n) => n,
            ScoreThreadSpec::Auto => pool::default_workers(),
        };
        self.score_pools = if threads > 1 {
            (0..pools.max(1)).map(|_| ScorePool::new(threads)).collect()
        } else {
            Vec::new()
        };
        self.score_auto = matches!(spec, ScoreThreadSpec::Auto);
    }

    /// The scoring pool this worker thread should use: the shared pool
    /// when one exists, otherwise the thread's sticky round-robin slot
    /// among the N configured pools. Pool choice never affects output —
    /// scoring is deterministic whichever pool computes it.
    fn pick_score_pool(&self) -> Option<&ScorePool> {
        match self.score_pools.len() {
            0 => None,
            1 => Some(&self.score_pools[0]),
            n => {
                thread_local! {
                    /// This thread's slot ticket (`usize::MAX` = unassigned).
                    /// Process-global and taken modulo the pool count, so
                    /// one thread serving several services keeps a stable
                    /// slot in each.
                    static SLOT: std::cell::Cell<usize> = std::cell::Cell::new(usize::MAX);
                }
                let slot = SLOT.with(|s| {
                    if s.get() == usize::MAX {
                        s.set(self.pool_slot.fetch_add(1, Ordering::Relaxed));
                    }
                    s.get()
                });
                Some(&self.score_pools[slot % n])
            }
        }
    }

    /// Recreate the schedule cache from the retained `cache_bytes` /
    /// `cache_disk` configuration (construction-time only: replaces the
    /// cache, dropping any cached schedules).
    fn rebuild_cache(&mut self) {
        self.schedules = ScheduleCache::with_config(self.cache_bytes, self.cache_disk.clone());
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Threads applied to intra-schedule scoring (1 = serial), per pool.
    pub fn score_threads(&self) -> usize {
        self.score_pools.first().map_or(1, |p| p.threads())
    }

    /// Number of independent scoring pools (0 = serial scoring).
    pub fn score_pool_count(&self) -> usize {
        self.score_pools.len()
    }

    /// Schedule-cache counters (lookups / computed / hits).
    pub fn cache_stats(&self) -> CacheStats {
        self.schedules.stats()
    }

    /// Number of [`SimScaffold`]s constructed so far — one per replay
    /// sweep whose points actually execute (the sweep's points share a
    /// cell), plus one per executed plain simulation job. Analogous to
    /// `schedules_computed`: a sweep of k points reports 1 here. Note
    /// that batch-level job-fingerprint dedup runs first: a duplicate
    /// sweep (or duplicate points) reuses the original's results and
    /// builds nothing, exactly as it computes no schedule.
    pub fn scaffolds_built(&self) -> usize {
        self.scaffolds_built.load(Ordering::Relaxed)
    }

    /// The service's schedule-reuse counters in the canonical
    /// [`obs::Counters`](crate::obs::Counters) shape (filled from the
    /// cache statistics — present whether or not event tracing is on).
    pub fn counters(&self) -> crate::obs::Counters {
        let stats = self.cache_stats();
        crate::obs::Counters {
            schedule_requests: stats.lookups as u64,
            schedules_computed: stats.computed as u64,
            schedule_reuse_hits: stats.hits() as u64,
            disk_hits: stats.disk_hits as u64,
            scaffolds_built: self.scaffolds_built() as u64,
            portfolio_commits: self.portfolio_commits.load(Ordering::Relaxed) as u64,
            replays_pruned: self.replays_pruned.load(Ordering::Relaxed) as u64,
        }
    }

    /// The run-summary record surfacing the cache-hit / schedule-reuse
    /// counters as one JSONL object (versioned: `"schema"` is
    /// [`obs::SCHEMA_VERSION`](crate::obs::SCHEMA_VERSION), field order
    /// is stable, and the reuse counters sit in one nested `counters`
    /// object shared verbatim with the serve summary — see DESIGN.md
    /// §Observability). Emitters print it on **stderr** (or a side
    /// file) — never into the result stream, whose bytes must not
    /// depend on cache residency: a warm `--cache-dir` run reports
    /// `schedules_computed: 0` here while its JSONL results stay
    /// byte-identical to the cold run's.
    pub fn summary_json(&self, jobs: usize, result_cache_hits: usize, failed: usize) -> Value {
        obj(vec![("summary", obj(self.summary_fields(jobs, result_cache_hits, failed)))])
    }

    /// [`summary_json`](SchedulingService::summary_json) plus a
    /// `clients` array: one per-client counter object per serve-mode
    /// session, in the given order. The daemon prints this on stderr at
    /// shutdown — a warm client shows `schedules_computed: 0` here while
    /// its response bytes stay identical to a cold `memsched batch`.
    pub fn summary_json_with_clients(
        &self,
        jobs: usize,
        result_cache_hits: usize,
        failed: usize,
        clients: &[ClientSession],
    ) -> Value {
        let mut fields = self.summary_fields(jobs, result_cache_hits, failed);
        fields.push((
            "clients",
            Value::Array(clients.iter().map(ClientSession::summary_json).collect()),
        ));
        obj(vec![("summary", obj(fields))])
    }

    fn summary_fields(
        &self,
        jobs: usize,
        result_cache_hits: usize,
        failed: usize,
    ) -> Vec<(&'static str, Value)> {
        vec![
            ("schema", crate::obs::SCHEMA_VERSION.into()),
            ("jobs", jobs.into()),
            ("failed", failed.into()),
            ("result_cache_hits", result_cache_hits.into()),
            ("workers", self.workers.into()),
            // Under `auto`, `score_threads` is the pool *size*; the
            // per-schedule crossover gate may still have scored
            // every schedule serially — `score_mode` disambiguates.
            ("score_threads", self.score_threads().into()),
            ("score_mode", if self.score_auto { "auto" } else { "fixed" }.into()),
            ("counters", self.counters().to_json()),
        ]
    }

    /// Memoized workflow materialization (one build per distinct source,
    /// even when many jobs reference it concurrently).
    fn workflow(&self, source: &JobSource) -> Result<Arc<Workflow>, String> {
        self.workflows.get_or_try_init(&source.key(), || {
            source.materialize().map(Arc::new).map_err(|e| format!("{e:#}"))
        })
    }

    /// Memoized cluster resolution: named/path specs load once per
    /// distinct name; inline clusters pass straight through.
    fn cluster(&self, spec: &ClusterSpec) -> Result<Arc<Cluster>, String> {
        match spec {
            ClusterSpec::Inline(c) => Ok(c.clone()),
            ClusterSpec::Named(name) => self.clusters.get_or_try_init(name, || {
                Cluster::load(name).map(Arc::new).map_err(|e| format!("{e:#}"))
            }),
        }
    }

    /// Materialize + fingerprint one schedule computation (shared by the
    /// per-job and per-sweep preparation paths; the sweep path calls it
    /// once per sweep instead of once per replay point).
    fn prepare_schedule(
        &self,
        source: &JobSource,
        cluster: &ClusterSpec,
        algo: Algorithm,
        policy: EvictionPolicy,
    ) -> Result<(Arc<Workflow>, Arc<Cluster>, Fingerprint, f64), String> {
        let wf = self.workflow(source)?;
        let cluster = self.cluster(cluster)?;
        let sched_fp = fingerprint::schedule_fingerprint(&wf, &cluster, algo, policy);
        // Algorithm-independent, O(n + m): one bound per preparation
        // (per sweep on the sweep path), shared by all of its results.
        let lower_bound = makespan_lower_bound(&wf, &cluster);
        Ok((wf, cluster, sched_fp, lower_bound))
    }

    fn prepare(&self, job: &Job) -> Result<Prepared, String> {
        let (wf, cluster, sched_fp, lower_bound) =
            self.prepare_schedule(&job.source, &job.cluster, job.algo, job.policy)?;
        let job_fp = fingerprint::job_fingerprint(sched_fp, job.sim.as_ref());
        Ok(Prepared { wf, cluster, sched_fp, job_fp, lower_bound, scaffold: None })
    }

    /// Execute one replay point: resolve the simulation scaffold (the
    /// sweep-shared cell when present, else a fresh build) and run the
    /// point on this worker's thread-local [`SimRun`] arena.
    fn run_point(&self, prep: &Prepared, schedule: &Arc<Schedule>, cfg: &SimConfig) -> SimOutcome {
        let build = || {
            self.scaffolds_built.fetch_add(1, Ordering::Relaxed);
            if obs::enabled() {
                obs::record(obs::Event::ScaffoldBuilt { tasks: prep.wf.num_tasks() as u32 });
            }
            Arc::new(SimScaffold::new(prep.wf.clone(), prep.cluster.clone(), schedule.clone()))
        };
        let scaffold = match &prep.scaffold {
            Some(cell) => cell.get_or_init(build).clone(),
            None => build(),
        };
        let _sim_span = obs::span(obs::SpanKind::Simulate);
        if obs::enabled() {
            obs::record(obs::Event::PointReplayed);
        }
        // Summary variant: `SimResult` never carries finish_times, so
        // skip the O(n) per-point clone of them. Recompute-mode points
        // score mid-run reschedules on this worker's pool; the pooled
        // reduction is bit-identical to serial, so outcomes don't depend
        // on `--score-threads`.
        let pool = self.score_pool_for(prep);
        SIM_ARENA.with(|arena| arena.borrow_mut().simulate_summary_with(&scaffold, cfg, pool))
    }

    /// The scoring pool this execution should apply, with the auto-mode
    /// gate: small instances skip the pool (serial scoring wins below
    /// the crossover); schedules are byte-identical either way.
    fn score_pool_for(&self, prep: &Prepared) -> Option<&ScorePool> {
        if self.score_auto && crate::scheduler::auto_score_threads(&prep.wf, &prep.cluster) == 1 {
            None
        } else {
            self.pick_score_pool()
        }
    }

    /// Compute (or cache-hit) one schedule under `fp` — the single
    /// compute closure of the plain and portfolio execution paths.
    fn compute_cached(
        &self,
        fp: Fingerprint,
        algo: Algorithm,
        policy: EvictionPolicy,
        prep: &Prepared,
        score_pool: Option<&ScorePool>,
    ) -> CachedSchedule {
        self.schedules.get_or_compute_checked(fp, Some(prep.wf.num_tasks()), || {
            let tasks = prep.wf.num_tasks() as u32;
            if obs::enabled() {
                obs::record(obs::Event::ScheduleStart { tasks });
            }
            let _compute_span = obs::span(obs::SpanKind::ScheduleCompute);
            let t0 = std::time::Instant::now();
            let s = ScheduleRequest::new(&prep.wf, &prep.cluster)
                .algo(algo)
                .policy(policy)
                .score_pool(score_pool)
                .run();
            let seconds = t0.elapsed().as_secs_f64();
            if obs::enabled() {
                obs::record(obs::Event::ScheduleEnd { tasks, micros: (seconds * 1e6) as u64 });
            }
            (s, seconds)
        })
    }

    /// Run one job-level simulation point against a committed schedule.
    /// Mirrors `experiments::run_dynamic`: executions of invalid
    /// schedules are not attempted.
    fn job_sim(&self, prep: &Prepared, schedule: &Arc<Schedule>, sj: SimJob) -> SimResult {
        if !schedule.valid {
            return SimResult {
                mode: sj.mode,
                completed: false,
                makespan: f64::NAN,
                recomputations: 0,
                started: 0,
            };
        }
        let cfg = SimConfig::new(sj.mode, DeviationModel::new(sj.sigma, sj.seed));
        let out = self.run_point(prep, schedule, &cfg);
        SimResult::from_outcome(sj.mode, &out)
    }

    fn execute(&self, job: &Job, prep: &Prepared) -> Executed {
        if job.algo == Algorithm::Portfolio {
            return self.execute_portfolio(job, prep);
        }
        let _exec_span = obs::span(obs::SpanKind::Execute);
        let score_pool = self.score_pool_for(prep);
        let cached = self.compute_cached(prep.sched_fp, job.algo, job.policy, prep, score_pool);
        let schedule = &cached.schedule;
        let sim = job.sim.map(|sj| self.job_sim(prep, schedule, sj));
        Executed {
            valid: schedule.valid,
            makespan: schedule.makespan,
            mem_usage: schedule.mean_mem_usage(),
            procs_used: schedule.procs_used(),
            evictions: schedule.tasks.iter().map(|t| t.evicted.len()).sum(),
            seconds: cached.seconds,
            portfolio: None,
            sim,
        }
    }

    /// `--algo portfolio`: compute every standalone candidate (each
    /// through the shared schedule cache under its **own** algorithm's
    /// fingerprint — never the portfolio fingerprint, so candidate
    /// schedules are shared with plain jobs and warm/cold runs emit
    /// identical bytes), score each valid candidate by a deterministic
    /// σ=0 FollowStatic replay, and commit the minimum simulated
    /// makespan. Ties break to the lowest [`Algorithm::all`] index; if
    /// no candidate completes its replay, the minimum analytic makespan
    /// wins instead. The loop is serial per job — parallelism lives in
    /// the scoring pool inside each candidate computation and across
    /// jobs on the batch pool — so the decision is independent of
    /// worker count by construction.
    ///
    /// With `portfolio_prune` on (the default), a candidate's σ=0
    /// replay is skipped when its *analytic* makespan already exceeds
    /// the best simulated makespan seen so far: for the σ=0 replays in
    /// scope here the analytic value tracks the simulated one closely,
    /// so such a candidate cannot win. Pruned candidates report
    /// `sim_makespan: null` with `pruned: true` and count into
    /// [`Counters::replays_pruned`](crate::obs::Counters). Candidates
    /// are visited in [`Algorithm::all`] order, so the prune decision —
    /// like the winner — is independent of worker count.
    fn execute_portfolio(&self, job: &Job, prep: &Prepared) -> Executed {
        let _exec_span = obs::span(obs::SpanKind::Execute);
        let score_pool = self.score_pool_for(prep);
        // Candidate replays must not populate a sweep's shared scaffold
        // cell — that belongs to the winner's replay points. Score
        // through a cell-less view of the same preparation.
        let cand_prep = Prepared { scaffold: None, ..prep.clone() };
        let mut cands: Vec<(Algorithm, CachedSchedule, f64, bool)> =
            Vec::with_capacity(Algorithm::all().len());
        // Incumbent: best (lowest) simulated makespan replayed so far.
        let mut best_sim = f64::INFINITY;
        for &algo in Algorithm::all() {
            let fp = fingerprint::schedule_fingerprint(&prep.wf, &prep.cluster, algo, job.policy);
            let cached = self.compute_cached(fp, algo, job.policy, prep, score_pool);
            let mut pruned = false;
            let sim_makespan = if !cached.schedule.valid {
                f64::NAN
            } else if self.portfolio_prune && cached.schedule.makespan > best_sim {
                // Analytic bound already loses to the incumbent's
                // simulated result — skip the replay entirely.
                pruned = true;
                self.replays_pruned.fetch_add(1, Ordering::Relaxed);
                f64::NAN
            } else {
                let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(0.0, 0));
                let out = self.run_point(&cand_prep, &cached.schedule, &cfg);
                let sim = if out.completed { out.makespan } else { f64::NAN };
                if sim.is_finite() && sim < best_sim {
                    best_sim = sim;
                }
                sim
            };
            cands.push((algo, cached, sim_makespan, pruned));
        }
        // Argmin simulated makespan; strict `<` keeps the lowest index
        // on ties.
        let mut winner: Option<usize> = None;
        for (i, c) in cands.iter().enumerate() {
            if c.2.is_finite() && winner.is_none_or(|w| c.2 < cands[w].2) {
                winner = Some(i);
            }
        }
        // All candidates invalid/incomplete: fall back to the analytic
        // makespan so the row still reports the least-bad schedule.
        let winner = winner.unwrap_or_else(|| {
            let mut best = 0;
            for i in 1..cands.len() {
                let (m, b) = (cands[i].1.schedule.makespan, cands[best].1.schedule.makespan);
                if m < b || (m.is_finite() && !b.is_finite()) {
                    best = i;
                }
            }
            best
        });
        self.portfolio_commits.fetch_add(1, Ordering::Relaxed);
        if obs::enabled() {
            obs::record(obs::Event::PortfolioCommitted { algo: winner as u32 });
        }
        let outcome = PortfolioOutcome {
            chosen: cands[winner].0,
            candidates: cands
                .iter()
                .map(|&(algo, ref c, sim_makespan, pruned)| PortfolioCandidate {
                    algo,
                    valid: c.schedule.valid,
                    sim_makespan,
                    pruned,
                })
                .collect(),
        };
        let cached = &cands[winner].1;
        let schedule = &cached.schedule;
        // "Cost of this schedule": the portfolio paid for every candidate.
        let seconds: f64 = cands.iter().map(|c| c.1.seconds).sum();
        let sim = job.sim.map(|sj| self.job_sim(prep, schedule, sj));
        Executed {
            valid: schedule.valid,
            makespan: schedule.makespan,
            mem_usage: schedule.mean_mem_usage(),
            procs_used: schedule.procs_used(),
            evictions: schedule.tasks.iter().map(|t| t.evicted.len()).sum(),
            seconds,
            portfolio: Some(outcome),
            sim,
        }
    }

    /// Execute a batch; results come back in submission order and their
    /// JSONL rendering is byte-identical for any worker count.
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let mut out = Vec::with_capacity(jobs.len());
        self.run_batch_streaming(jobs, |r| out.push(r));
        out
    }

    /// Like [`run_batch`](SchedulingService::run_batch), but hands each
    /// [`JobResult`] to `sink` as soon as it is final — in submission
    /// order, while later jobs are still executing. The emitted sequence
    /// is exactly `run_batch`'s, so streaming consumers (the `memsched
    /// batch` JSONL writer, suite progress counters) see incremental,
    /// still byte-deterministic output.
    ///
    /// `sink` runs on pool worker threads (serialized — never
    /// concurrently with itself); keep it cheap or the emission lock
    /// becomes a bottleneck.
    pub fn run_batch_streaming(&self, jobs: Vec<Job>, sink: impl FnMut(JobResult) + Send) {
        // Give previously-failed sources a fresh chance (see `Memo`).
        self.workflows.prune_errors();
        self.clusters.prune_errors();
        self.prematerialize(jobs.iter().map(|j| j.source.clone()));

        // Phase 1: materialize + fingerprint.
        let prepared: Vec<(Job, Result<Prepared, String>)> = {
            let _mat_span = obs::span(obs::SpanKind::Materialize);
            pool::run_ordered(jobs, self.workers, |_, job| {
                let prep = self.prepare(&job);
                (job, prep)
            })
        };

        self.stream_prepared(prepared, sink);
    }

    /// Execute a batch of replay sweeps; results come back flattened in
    /// submission order (sweep-major, replay-point-minor), buffered.
    pub fn run_replay_sweeps(&self, sweeps: Vec<ReplaySweep>) -> Vec<JobResult> {
        let mut out = Vec::with_capacity(sweeps.iter().map(ReplaySweep::num_results).sum());
        self.run_replay_sweeps_streaming(sweeps, |r| out.push(r));
        out
    }

    /// The replay engine: each sweep's workflow is materialized and its
    /// schedule fingerprinted **once**, the static schedule is computed
    /// (or cache-/disk-hit) once per distinct fingerprint, and the replay
    /// points fan out across the worker pool. Results stream to `sink`
    /// exactly like [`run_batch_streaming`]: flattened in submission
    /// order (sweep-major, point-minor, ids counting the flattened
    /// stream) and **byte-identical** to submitting
    /// [`ReplaySweep::flatten`]'s per-point jobs through the plain batch
    /// API — the two paths share phases 2–4, so the guarantee holds by
    /// construction.
    ///
    /// [`run_batch_streaming`]: SchedulingService::run_batch_streaming
    pub fn run_replay_sweeps_streaming(
        &self,
        sweeps: Vec<ReplaySweep>,
        sink: impl FnMut(JobResult) + Send,
    ) {
        self.workflows.prune_errors();
        self.clusters.prune_errors();
        self.prematerialize(sweeps.iter().map(|s| s.source.clone()));
        let prepared = {
            let _mat_span = obs::span(obs::SpanKind::Materialize);
            self.prepare_sweeps(sweeps)
        };
        self.stream_prepared(prepared, sink);
    }

    /// Phase 1, sweep-grained: one materialize + schedule fingerprint
    /// per sweep, not per replay point — on a k-point sweep over an
    /// n-task workflow this saves k−1 O(n) fingerprint walks. The
    /// expansion into per-point prepared jobs is exactly
    /// [`ReplaySweep::flatten`].
    fn prepare_sweeps(&self, sweeps: Vec<ReplaySweep>) -> Vec<(Job, Result<Prepared, String>)> {
        type SweepPrep = (Arc<Workflow>, Arc<Cluster>, Fingerprint, f64);
        let sweep_prepared: Vec<(ReplaySweep, Result<SweepPrep, String>)> =
            pool::run_ordered(sweeps, self.workers, |_, sweep| {
                let prep =
                    self.prepare_schedule(&sweep.source, &sweep.cluster, sweep.algo, sweep.policy);
                (sweep, prep)
            });

        // Derive the cheap per-point job fingerprints from the sweep's
        // schedule fingerprint.
        let mut prepared: Vec<(Job, Result<Prepared, String>)> =
            Vec::with_capacity(sweep_prepared.iter().map(|(s, _)| s.num_results()).sum());
        for (sweep, prep) in &sweep_prepared {
            // One scaffold cell per sweep: every point of the sweep
            // shares it, so the simulation scaffold is built exactly
            // once per sweep however many points fan out (the
            // `scaffolds_built` counter in the run summary tracks this).
            let scaffold_cell = Arc::new(OnceLock::new());
            for job in sweep.flatten() {
                let p = match prep {
                    Err(e) => Err(e.clone()),
                    Ok((wf, cluster, sched_fp, lower_bound)) => Ok(Prepared {
                        wf: wf.clone(),
                        cluster: cluster.clone(),
                        sched_fp: *sched_fp,
                        job_fp: fingerprint::job_fingerprint(*sched_fp, job.sim.as_ref()),
                        lower_bound: *lower_bound,
                        scaffold: Some(scaffold_cell.clone()),
                    }),
                };
                prepared.push((job, p));
            }
        }
        prepared
    }

    /// Serve-mode submission path: run one client's [`JobSpec`] on the
    /// shared pool and stream its results to `sink`, with result ids
    /// continuing the client's stream and `cache_hit` flags replaying
    /// the client's **own** submission history — the response bytes are
    /// identical to what a cold `memsched batch` emits for the client's
    /// submitted lines, however warm the shared schedule caches are.
    /// Cache warmth (cross-client and cross-process reuse) shows up only
    /// in the per-client counters, never in result bytes.
    ///
    /// Callers must serialize invocations per service for the
    /// `schedules_computed` delta to be attributed correctly (the serve
    /// dispatcher runs one submission at a time; parallelism lives
    /// inside the submission, on the worker pool).
    pub fn run_client_spec(
        &self,
        session: &mut ClientSession,
        spec: JobSpec,
        mut sink: impl FnMut(JobResult) + Send,
    ) {
        // Same batch-boundary hygiene as the batch entry points.
        self.workflows.prune_errors();
        self.clusters.prune_errors();
        let sweeps = vec![spec.into_sweep()];
        self.prematerialize(sweeps.iter().map(|s| s.source.clone()));
        let prepared = self.prepare_sweeps(sweeps);
        let fps: Vec<u128> =
            prepared.iter().filter_map(|(_, p)| p.as_ref().ok().map(|p| p.job_fp.0)).collect();
        let offset = session.next_id;
        let submitted = prepared.len();
        let computed_before = self.cache_stats().computed;

        let (mut results, mut cache_hits, mut failed) = (0usize, 0usize, 0usize);
        {
            let seen = &session.seen;
            self.stream_prepared_with(
                prepared,
                |p| seen.contains(&p.job_fp.0),
                |mut r| {
                    r.id += offset;
                    results += 1;
                    if r.cache_hit {
                        cache_hits += 1;
                    }
                    if r.error.is_some() {
                        failed += 1;
                    }
                    sink(r);
                },
            );
        }

        session.next_id += submitted;
        session.seen.extend(fps);
        session.counters.accepted += 1;
        session.counters.results += results;
        session.counters.result_cache_hits += cache_hits;
        session.counters.failed += failed;
        session.counters.schedules_computed += self.cache_stats().computed - computed_before;
    }

    /// Phase 0: pre-materialize unique sources in parallel. Without
    /// this, a suite-style grid (the same workload under several
    /// algorithms, jobs adjacent in submission order) lands one job
    /// per worker and they all block on a single memo cell — phase 1
    /// would degrade to the serial sum of the workflow builds.
    fn prematerialize(&self, sources: impl Iterator<Item = JobSource>) {
        let mut seen = std::collections::HashSet::new();
        let unique_sources: Vec<JobSource> = sources.filter(|s| seen.insert(s.key())).collect();
        pool::run_ordered(unique_sources, self.workers, |_, source| {
            let _ = self.workflow(&source);
        });
    }

    /// Phases 2–4, shared by the per-job and replay-sweep paths: group,
    /// execute uniques on the pool, drain the ordered prefix into the
    /// sink. Everything downstream of here sees only `(Job, Prepared)`
    /// pairs, which is why the two submission kinds emit byte-identical
    /// streams for equal flattened inputs.
    fn stream_prepared(
        &self,
        prepared: Vec<(Job, Result<Prepared, String>)>,
        sink: impl FnMut(JobResult) + Send,
    ) {
        self.stream_prepared_with(prepared, |p| self.schedules.contains(p.sched_fp), sink);
    }

    /// [`stream_prepared`](SchedulingService::stream_prepared) with an
    /// injectable residency observation: `resident` decides, per
    /// prepared job and **before any execution**, whether the job is
    /// reported as a pre-batch `cache_hit`. The batch paths observe the
    /// in-memory schedule cache; the serve-mode client path replays the
    /// client's own submission history instead, so a shared warm daemon
    /// answers with the exact bytes a cold `memsched batch` would emit.
    fn stream_prepared_with(
        &self,
        prepared: Vec<(Job, Result<Prepared, String>)>,
        resident: impl Fn(&Prepared) -> bool,
        sink: impl FnMut(JobResult) + Send,
    ) {
        // Phases 2–4 under one Stream span (grouping, pool execution,
        // ordered drain — the whole streaming tail of a batch).
        let _stream_span = obs::span(obs::SpanKind::Stream);
        // Phase 2: deterministic grouping. The lowest-id job of each
        // fingerprint group is the computer; `cache_hit` flags are fixed
        // here, before execution, from (group position, cache state).
        let mut representative: HashMap<u128, usize> = HashMap::new();
        let mut pre_cached: HashMap<u128, bool> = HashMap::new();
        for (i, (_, prep)) in prepared.iter().enumerate() {
            if let Ok(p) = prep {
                representative.entry(p.job_fp.0).or_insert(i);
                pre_cached.entry(p.job_fp.0).or_insert_with(|| resident(p));
            }
        }
        let mut compute_order: Vec<usize> = Vec::new();
        let mut deduped = 0usize;
        for (i, (_, prep)) in prepared.iter().enumerate() {
            if let Ok(p) = prep {
                if representative[&p.job_fp.0] == i {
                    compute_order.push(i);
                } else {
                    deduped += 1;
                }
            }
        }
        // Deduplicated jobs are cache hits that never reach the map.
        self.schedules.note_deduped(deduped);

        // Phase 3 + 4 fused: execute unique jobs on the pool; each
        // completion drains the ready prefix of the (submission-ordered)
        // result stream into the sink. A job's payload is its
        // fingerprint representative's `Executed` slot, and
        // `representative[i] <= i`, so the prefix test below can only
        // wait on slots of earlier-or-equal jobs.
        let slot_of: HashMap<usize, usize> =
            compute_order.iter().enumerate().map(|(slot, &i)| (i, slot)).collect();
        let slots: Vec<Mutex<Option<Executed>>> =
            (0..compute_order.len()).map(|_| Mutex::new(None)).collect();
        // (next job index to emit, sink) behind one lock: emission is
        // serialized and in order by construction.
        let emitter = Mutex::new((0usize, sink));

        let assemble = |i: usize, job: &Job, p: &Prepared| -> JobResult {
            let slot = slot_of[&representative[&p.job_fp.0]];
            let ex = slots[slot]
                .lock()
                .unwrap()
                .clone()
                .expect("drained only when the representative slot is filled");
            JobResult {
                id: i,
                error: None,
                workflow: p.wf.name.clone(),
                tasks: p.wf.num_tasks(),
                cluster: p.cluster.name.clone(),
                algo: job.algo,
                fingerprint: p.job_fp.to_string(),
                cache_hit: representative[&p.job_fp.0] != i || pre_cached[&p.job_fp.0],
                valid: ex.valid,
                makespan: ex.makespan,
                lower_bound: p.lower_bound,
                optimality_gap: optimality_gap(ex.makespan, p.lower_bound),
                mem_usage: ex.mem_usage,
                procs_used: ex.procs_used,
                evictions: ex.evictions,
                seconds: ex.seconds,
                portfolio: ex.portfolio,
                sim: ex.sim,
            }
        };
        // Workers drain opportunistically (`block = false`): if another
        // worker already holds the emission lock it will re-scan the
        // prefix itself, and the final blocking drain below catches any
        // residue — so nobody queues up behind a slow sink instead of
        // returning to the pool for more work.
        let drain = |block: bool| {
            let guard = if block {
                Some(emitter.lock().unwrap())
            } else {
                emitter.try_lock().ok()
            };
            let Some(mut guard) = guard else {
                return;
            };
            let emitter = &mut *guard;
            while emitter.0 < prepared.len() {
                let i = emitter.0;
                let (job, prep) = &prepared[i];
                let result = match prep {
                    Err(e) => JobResult::failed(i, e.clone()),
                    Ok(p) => {
                        let slot = slot_of[&representative[&p.job_fp.0]];
                        let ready = slots[slot].lock().unwrap().is_some();
                        if !ready {
                            return; // prefix not ready yet
                        }
                        assemble(i, job, p)
                    }
                };
                (emitter.1)(result);
                emitter.0 += 1;
            }
        };

        let work: Vec<(usize, usize)> = compute_order.iter().copied().enumerate().collect();
        let prepared_ref = &prepared;
        pool::run_ordered(work, self.workers, |_, (slot, i)| {
            let (job, prep) = &prepared_ref[i];
            let prep = prep.as_ref().expect("compute_order only holds prepared jobs");
            let ex = self.execute(job, prep);
            *slots[slot].lock().unwrap() = Some(ex);
            drain(false);
        });
        // Blocking tail drain: trailing failed jobs (which never touch
        // the pool), all-deduped batches, the empty-compute-order case,
        // and any prefix skipped by contended opportunistic drains.
        drain(true);
        debug_assert_eq!(emitter.lock().unwrap().0, prepared.len(), "every job emitted");
    }
}

/// Per-client serve-mode counters, reported in the daemon's shutdown
/// summary ([`SchedulingService::summary_json_with_clients`]) and in
/// per-client disconnect records. Counters never influence result
/// bytes.
#[derive(Debug, Clone, Default)]
pub struct ClientCounters {
    /// Submissions accepted into the client's queue (job or sweep
    /// frames that parsed).
    pub accepted: usize,
    /// Submissions rejected by backpressure (queue at
    /// `--max-queued-per-client`).
    pub rejected: usize,
    /// Result lines streamed back.
    pub results: usize,
    /// Results flagged `cache_hit` (duplicates within the client's own
    /// stream).
    pub result_cache_hits: usize,
    /// Results that were structured job errors.
    pub failed: usize,
    /// Schedules this client's submissions actually computed — in-memory,
    /// disk, and cross-client reuse all keep this at 0 for warm
    /// workloads.
    pub schedules_computed: usize,
}

/// One serve-mode client's submission state: result-id numbering and
/// the job-fingerprint history that keeps its `cache_hit` flags
/// byte-identical to a cold `memsched batch` over the same lines
/// (see [`SchedulingService::run_client_spec`]).
#[derive(Debug)]
pub struct ClientSession {
    /// Display name (`c0`, `c1`, … in accept order; `stdio`).
    pub name: String,
    /// Next result id of the client's stream (each submission's results
    /// continue the numbering, exactly like lines of one batch file).
    next_id: usize,
    /// Job fingerprints of every prepared submission so far.
    seen: std::collections::HashSet<u128>,
    pub counters: ClientCounters,
}

impl ClientSession {
    pub fn new(name: impl Into<String>) -> ClientSession {
        ClientSession {
            name: name.into(),
            next_id: 0,
            seen: std::collections::HashSet::new(),
            counters: ClientCounters::default(),
        }
    }

    /// The per-client summary object (an element of the daemon
    /// summary's `clients` array). Admission/stream fields sit at the
    /// top level; the schedule-reuse counter nests under `counters`,
    /// mirroring the global summary's shape (DESIGN.md §Observability).
    pub fn summary_json(&self) -> Value {
        let c = &self.counters;
        obj(vec![
            ("name", self.name.as_str().into()),
            ("accepted", c.accepted.into()),
            ("rejected", c.rejected.into()),
            ("results", c.results.into()),
            ("result_cache_hits", c.result_cache_hits.into()),
            ("failed", c.failed.into()),
            ("counters", obj(vec![("schedules_computed", c.schedules_computed.into())])),
        ])
    }
}

/// Render a batch's results as JSONL (one compact line per job, in job
/// order). This is the byte-deterministic wire format of the service.
pub fn to_jsonl(results: &[JobResult]) -> String {
    let mut out = String::with_capacity(results.len() * 160);
    for r in results {
        out.push_str(&r.to_jsonl());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::WorkloadSpec;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::Algorithm;
    use crate::simulator::SimMode;

    fn spec_job(family: &str, input: usize, algo: Algorithm, cluster: &Arc<Cluster>) -> Job {
        Job::new(
            JobSource::Generated(WorkloadSpec { family: family.into(), size: None, input, seed: 5 }),
            ClusterSpec::Inline(cluster.clone()),
        )
        .with_algo(algo)
    }

    #[test]
    fn duplicates_dedupe_to_one_computation() {
        let cluster = Arc::new(small_cluster());
        let job = spec_job("bacass", 1, Algorithm::HeftmBl, &cluster);
        let svc = SchedulingService::new(2);
        let results = svc.run_batch(vec![job.clone(), job.clone(), job]);
        assert_eq!(results.len(), 3);
        assert!(!results[0].cache_hit);
        assert!(results[1].cache_hit && results[2].cache_hit);
        assert_eq!(results[0].makespan, results[1].makespan);
        assert_eq!(results[0].fingerprint, results[2].fingerprint);
        assert_eq!(svc.cache_stats().computed, 1);
    }

    #[test]
    fn second_batch_hits_the_persistent_cache() {
        let cluster = Arc::new(small_cluster());
        let svc = SchedulingService::new(1);
        let r1 = svc.run_batch(vec![spec_job("bacass", 1, Algorithm::HeftmMm, &cluster)]);
        assert!(!r1[0].cache_hit);
        let r2 = svc.run_batch(vec![spec_job("bacass", 1, Algorithm::HeftmMm, &cluster)]);
        assert!(r2[0].cache_hit, "pre-cached schedule must be flagged");
        assert_eq!(svc.cache_stats().computed, 1);
        assert_eq!(r1[0].makespan, r2[0].makespan);
    }

    #[test]
    fn sim_modes_share_one_schedule_computation() {
        let cluster = Arc::new(small_cluster());
        let base = spec_job("chipseq", 0, Algorithm::HeftmBl, &cluster);
        let rec = base.clone().with_sim(SimJob { mode: SimMode::Recompute, sigma: 0.1, seed: 9 });
        let stat =
            base.clone().with_sim(SimJob { mode: SimMode::FollowStatic, sigma: 0.1, seed: 9 });
        let svc = SchedulingService::new(2);
        let results = svc.run_batch(vec![rec, stat]);
        assert!(results.iter().all(|r| r.error.is_none()));
        assert!(results.iter().all(|r| r.sim.is_some()));
        // Two distinct jobs, one underlying schedule.
        assert_eq!(svc.cache_stats().computed, 1);
        assert_eq!(svc.cache_stats().hits(), 1);
        assert_eq!(results[0].makespan, results[1].makespan);
    }

    #[test]
    fn failing_jobs_report_errors_without_poisoning_the_batch() {
        let cluster = Arc::new(small_cluster());
        let bad = Job::new(
            JobSource::Generated(WorkloadSpec {
                family: "no_such_model".into(),
                size: None,
                input: 0,
                seed: 1,
            }),
            ClusterSpec::Inline(cluster.clone()),
        );
        let good = spec_job("eager", 0, Algorithm::Heft, &cluster);
        let svc = SchedulingService::new(2);
        let results = svc.run_batch(vec![bad, good]);
        assert!(results[0].error.as_deref().unwrap().contains("no_such_model"));
        assert!(results[1].error.is_none());
        let text = to_jsonl(&results);
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().contains("\"error\""));
    }

    #[test]
    fn transient_load_failures_are_retried_across_batches() {
        // Per-process dir: concurrent test runs must not share state.
        let dir = std::env::temp_dir().join(format!("memsched_service_retry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("late.json");
        let _ = std::fs::remove_file(&path);
        let cluster = ClusterSpec::Inline(Arc::new(small_cluster()));
        let job = Job::new(JobSource::File(path.clone()), cluster);
        let svc = SchedulingService::new(1);
        let r1 = svc.run_batch(vec![job.clone()]);
        assert!(r1[0].error.is_some(), "missing file must fail the job");
        // The file appears later: the same service must not have
        // poisoned the memo entry with the old error.
        let mut b = crate::workflow::WorkflowBuilder::new("late");
        let a = b.task("a", "t", 1.0, 10.0);
        let c = b.task("c", "t", 2.0, 20.0);
        b.edge(a, c, 3.0);
        crate::workflow::io::save(&b.build().unwrap(), &path).unwrap();
        let r2 = svc.run_batch(vec![job]);
        assert!(r2[0].error.is_none(), "stale error: {:?}", r2[0].error);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn named_cluster_resolution() {
        let job = Job::new(
            JobSource::Generated(WorkloadSpec {
                family: "methylseq".into(),
                size: None,
                input: 0,
                seed: 2,
            }),
            ClusterSpec::Named("memory-constrained".into()),
        );
        let svc = SchedulingService::new(1);
        let r = svc.run_batch(vec![job]);
        assert!(r[0].error.is_none());
        assert_eq!(r[0].cluster, "memory-constrained");
    }

    #[test]
    fn streaming_emits_in_submission_order_and_matches_run_batch() {
        let cluster = Arc::new(small_cluster());
        let mut jobs = Vec::new();
        for &algo in Algorithm::all() {
            jobs.push(spec_job("chipseq", 1, algo, &cluster));
            jobs.push(spec_job("eager", 2, algo, &cluster));
        }
        // A failing job in the middle and a duplicate at the end.
        jobs.insert(3, Job::new(
            JobSource::Generated(WorkloadSpec {
                family: "nope".into(),
                size: None,
                input: 0,
                seed: 1,
            }),
            ClusterSpec::Inline(cluster.clone()),
        ));
        jobs.push(jobs[0].clone());

        let svc_stream = SchedulingService::new(4);
        let mut streamed = Vec::new();
        svc_stream.run_batch_streaming(jobs.clone(), |r| streamed.push(r));
        assert_eq!(streamed.len(), jobs.len());
        assert!(streamed.iter().enumerate().all(|(i, r)| r.id == i), "order must be by id");

        let svc_buffered = SchedulingService::new(1);
        let buffered = svc_buffered.run_batch(jobs);
        assert_eq!(to_jsonl(&streamed), to_jsonl(&buffered));
    }

    #[test]
    fn score_threads_preserve_batch_bytes() {
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("methylseq", 1, algo, &cluster))
                .collect()
        };
        let serial = SchedulingService::new(2);
        let r_serial = serial.run_batch(jobs(()));
        let scored = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Fixed(4),
            ..ServiceConfig::default()
        })
        .unwrap();
        assert_eq!(scored.score_threads(), 4);
        let r_scored = scored.run_batch(jobs(()));
        assert_eq!(to_jsonl(&r_serial), to_jsonl(&r_scored));
    }

    #[test]
    fn score_pools_preserve_batch_bytes() {
        // Per-worker scoring pools (the `--score-pools` contention
        // knob) must not change a single output byte vs the shared
        // single pool, or vs serial scoring.
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("methylseq", 1, algo, &cluster))
                .collect()
        };
        let baseline = SchedulingService::new(2).run_batch(jobs(()));
        let pooled = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Fixed(2),
            score_pools: 2,
            ..ServiceConfig::default()
        })
        .unwrap();
        assert_eq!(pooled.score_pool_count(), 2);
        assert_eq!(pooled.score_threads(), 2);
        assert_eq!(to_jsonl(&baseline), to_jsonl(&pooled.run_batch(jobs(()))));
        // Serial scoring ignores the pool count entirely.
        let serial = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Fixed(1),
            score_pools: 3,
            ..ServiceConfig::default()
        })
        .unwrap();
        assert_eq!(serial.score_pool_count(), 0);
        assert_eq!(to_jsonl(&baseline), to_jsonl(&serial.run_batch(jobs(()))));
    }

    #[test]
    fn replay_sweeps_match_flattened_batch_bytes() {
        let cluster = Arc::new(small_cluster());
        let points: Vec<SimJob> = [0.1, 0.3]
            .into_iter()
            .flat_map(|sigma| {
                [SimMode::Recompute, SimMode::FollowStatic]
                    .into_iter()
                    .map(move |mode| SimJob { mode, sigma, seed: 9 })
            })
            .collect();
        let mut sweeps = Vec::new();
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
            sweeps.push(
                ReplaySweep::new(
                    JobSource::Generated(WorkloadSpec {
                        family: "chipseq".into(),
                        size: None,
                        input: 1,
                        seed: 5,
                    }),
                    ClusterSpec::Inline(cluster.clone()),
                )
                .with_algo(algo)
                .with_points(points.clone()),
            );
        }
        // A point-less (static) sweep and a failing sweep ride along.
        sweeps.push(ReplaySweep::new(
            JobSource::Generated(WorkloadSpec { family: "eager".into(), size: None, input: 0, seed: 5 }),
            ClusterSpec::Inline(cluster.clone()),
        ));
        sweeps.push(ReplaySweep::new(
            JobSource::Generated(WorkloadSpec { family: "nope".into(), size: None, input: 0, seed: 5 }),
            ClusterSpec::Inline(cluster.clone()),
        ));

        let flattened: Vec<Job> = sweeps.iter().flat_map(|s| s.flatten()).collect();
        let sweep_svc = SchedulingService::new(4);
        let mut streamed = Vec::new();
        sweep_svc.run_replay_sweeps_streaming(sweeps.clone(), |r| streamed.push(r));
        assert_eq!(streamed.len(), flattened.len());
        assert!(streamed.iter().enumerate().all(|(i, r)| r.id == i), "flattened id order");

        let flat_svc = SchedulingService::new(1);
        let baseline = flat_svc.run_batch(flattened);
        assert_eq!(to_jsonl(&streamed), to_jsonl(&baseline), "sweep path must match flat path");

        // The replay engine's core guarantee: one schedule computation
        // per successful sweep, however many replay points it carries.
        assert_eq!(sweep_svc.cache_stats().computed, 3);
        // 2 sweeps × 4 points + 1 static = 9 schedule requests.
        assert_eq!(sweep_svc.cache_stats().lookups, 9);
        assert_eq!(sweep_svc.cache_stats().hits(), 6);

        // Tentpole acceptance: the sweep path builds one simulation
        // scaffold per sweep that actually simulates, while the flat
        // baseline builds one per executed sim job.
        let valid_sim_sweeps = [0..4usize, 4..8]
            .into_iter()
            .filter(|r| streamed[r.clone()].iter().any(|j| j.valid && j.sim.is_some()))
            .count();
        assert_eq!(sweep_svc.scaffolds_built(), valid_sim_sweeps);
        let valid_sim_points =
            baseline.iter().filter(|r| r.error.is_none() && r.valid && r.sim.is_some()).count();
        assert_eq!(flat_svc.scaffolds_built(), valid_sim_points);

        // Buffered variant (fresh service: cache_hit flags are part of
        // the bytes and depend on pre-batch cache state).
        let buffered = SchedulingService::new(2).run_replay_sweeps(sweeps);
        assert_eq!(to_jsonl(&buffered), to_jsonl(&streamed));
    }

    #[test]
    fn scaffold_built_once_per_sweep() {
        let cluster = Arc::new(small_cluster());
        let points: Vec<SimJob> = [0.1, 0.2, 0.3]
            .into_iter()
            .flat_map(|sigma| {
                [SimMode::Recompute, SimMode::FollowStatic]
                    .into_iter()
                    .map(move |mode| SimJob { mode, sigma, seed: 9 })
            })
            .collect();
        let sweep = ReplaySweep::new(
            JobSource::Generated(WorkloadSpec {
                family: "chipseq".into(),
                size: None,
                input: 0,
                seed: 3,
            }),
            ClusterSpec::Inline(cluster.clone()),
        )
        .with_points(points.clone());
        let svc = SchedulingService::new(4);
        let results = svc.run_replay_sweeps(vec![sweep.clone()]);
        assert_eq!(results.len(), points.len());
        assert!(results.iter().all(|r| r.valid && r.sim.is_some()));
        assert_eq!(svc.scaffolds_built(), 1, "one scaffold per sweep, not per point");
        assert_eq!(svc.cache_stats().computed, 1);
        // The run-summary record surfaces the counter.
        let line = svc.summary_json(results.len(), 0, 0).to_string_compact();
        assert!(line.contains("\"scaffolds_built\":1"), "{line}");

        // The flat per-point path rebuilds one scaffold per executed job.
        let flat = SchedulingService::new(2);
        let flat_results = flat.run_batch(sweep.flatten());
        assert_eq!(to_jsonl(&flat_results), to_jsonl(&results));
        assert_eq!(flat.scaffolds_built(), points.len());
    }

    #[test]
    fn auto_score_mode_preserves_batch_bytes() {
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("bacass", 1, algo, &cluster))
                .collect()
        };
        let cfg = |score| ServiceConfig { workers: 2, score, ..ServiceConfig::default() };
        let serial = SchedulingService::from_config(cfg(ScoreThreadSpec::Fixed(1))).unwrap();
        let auto = SchedulingService::from_config(cfg(ScoreThreadSpec::Auto)).unwrap();
        assert_eq!(to_jsonl(&serial.run_batch(jobs(()))), to_jsonl(&auto.run_batch(jobs(()))));
    }

    #[test]
    fn portfolio_jobs_commit_the_best_replayed_candidate() {
        let cluster = Arc::new(small_cluster());
        let svc = SchedulingService::new(2);
        let results = svc.run_batch(vec![spec_job("chipseq", 1, Algorithm::Portfolio, &cluster)]);
        let r = &results[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.algo, Algorithm::Portfolio);
        let p = r.portfolio.as_ref().expect("portfolio rows carry the decision record");
        assert_eq!(
            p.candidates.iter().map(|c| c.algo).collect::<Vec<_>>(),
            Algorithm::all().to_vec(),
            "one candidate per standalone algorithm, in registry order"
        );
        let chosen = p.candidates.iter().find(|c| c.algo == p.chosen).unwrap();
        assert!(chosen.valid && chosen.sim_makespan.is_finite());
        for c in &p.candidates {
            if c.sim_makespan.is_finite() {
                assert!(
                    chosen.sim_makespan <= c.sim_makespan,
                    "{:?} ({}) beat the committed {:?} ({})",
                    c.algo,
                    c.sim_makespan,
                    p.chosen,
                    chosen.sim_makespan
                );
            }
        }
        // The row's payload is the winner's schedule, with a valid gap.
        assert!(r.valid);
        assert!(r.lower_bound > 0.0 && r.makespan + 1e-9 >= r.lower_bound);
        assert!(r.optimality_gap >= 0.0 && r.optimality_gap.is_finite());
        assert_eq!(svc.counters().portfolio_commits, 1);
        // Non-portfolio rows never carry the record.
        let plain = svc.run_batch(vec![spec_job("chipseq", 1, Algorithm::HeftmBl, &cluster)]);
        assert!(plain[0].portfolio.is_none());
    }

    /// The analytic-bound replay prune must never change the committed
    /// decision: prune on (the default) and prune off agree on the
    /// chosen algorithm and on every replay both runs performed, and
    /// pruned candidates are exactly the rows reporting no simulated
    /// makespan.
    #[test]
    fn portfolio_prune_preserves_the_decision() {
        let cluster = Arc::new(small_cluster());
        let job = |_: ()| spec_job("chipseq", 1, Algorithm::Portfolio, &cluster);
        let pruned_svc = SchedulingService::new(1);
        let plain_svc = SchedulingService::from_config(ServiceConfig {
            workers: 1,
            portfolio_prune: false,
            ..ServiceConfig::default()
        })
        .unwrap();
        let pr = &pruned_svc.run_batch(vec![job(())])[0];
        let pl = &plain_svc.run_batch(vec![job(())])[0];
        let pp = pr.portfolio.as_ref().unwrap();
        let np = pl.portfolio.as_ref().unwrap();
        assert_eq!(pp.chosen, np.chosen, "pruning changed the committed algorithm");
        assert_eq!(pr.makespan.to_bits(), pl.makespan.to_bits());
        assert_eq!(pp.candidates.len(), np.candidates.len());
        let mut pruned_rows = 0;
        for (a, b) in pp.candidates.iter().zip(&np.candidates) {
            assert_eq!(a.algo, b.algo);
            assert_eq!(a.valid, b.valid);
            assert!(!b.pruned, "prune-off run must replay every valid candidate");
            if a.pruned {
                pruned_rows += 1;
                assert!(a.sim_makespan.is_nan(), "pruned rows report no simulated makespan");
                assert!(a.valid, "only valid candidates are ever pruned");
            } else {
                assert_eq!(
                    a.sim_makespan.to_bits(),
                    b.sim_makespan.to_bits(),
                    "replays the pruned run did perform must match bit-exactly"
                );
            }
        }
        assert_eq!(pruned_svc.counters().replays_pruned, pruned_rows);
        assert_eq!(plain_svc.counters().replays_pruned, 0);
        // A pruned σ=0 replay also skips its scaffold build.
        assert!(pruned_svc.scaffolds_built() + pruned_rows as usize == plain_svc.scaffolds_built());
    }

    #[test]
    fn score_thread_spec_parses() {
        assert_eq!("auto".parse::<ScoreThreadSpec>().unwrap(), ScoreThreadSpec::Auto);
        assert_eq!("AUTO".parse::<ScoreThreadSpec>().unwrap(), ScoreThreadSpec::Auto);
        assert_eq!("4".parse::<ScoreThreadSpec>().unwrap(), ScoreThreadSpec::Fixed(4));
        assert_eq!("0".parse::<ScoreThreadSpec>().unwrap(), ScoreThreadSpec::Fixed(1));
        assert!("several".parse::<ScoreThreadSpec>().is_err());
        assert_eq!(ScoreThreadSpec::default(), ScoreThreadSpec::Fixed(1));
    }

    #[test]
    fn disk_cache_dir_shares_schedules_across_services() {
        let dir = std::env::temp_dir().join(format!("memsched_svc_disk_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("methylseq", 0, algo, &cluster))
                .collect()
        };
        let disk_cfg = || ServiceConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            ..ServiceConfig::default()
        };
        let cold = SchedulingService::from_config(disk_cfg()).unwrap();
        let cold_out = to_jsonl(&cold.run_batch(jobs(())));
        assert_eq!(cold.cache_stats().computed, Algorithm::all().len());
        assert_eq!(cold.cache_stats().disk_hits, 0);

        // A fresh service ("new process") on the same directory loads
        // every schedule from disk and emits byte-identical results.
        let warm = SchedulingService::from_config(disk_cfg()).unwrap();
        let warm_out = to_jsonl(&warm.run_batch(jobs(())));
        assert_eq!(warm_out, cold_out, "warm disk cache must not change output bytes");
        assert_eq!(warm.cache_stats().computed, 0, "warm run computes nothing");
        assert_eq!(warm.cache_stats().disk_hits, Algorithm::all().len());

        // The summary record carries the reuse counters.
        let summary = warm.summary_json(Algorithm::all().len(), 0, 0);
        let line = summary.to_string_compact();
        assert!(line.contains("\"schedules_computed\":0"), "{line}");
        assert!(line.contains(&format!("\"disk_hits\":{}", Algorithm::all().len())), "{line}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn byte_cap_and_disk_layer_compose() {
        let dir = std::env::temp_dir().join(format!("memsched_svc_compose_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Arc::new(small_cluster());
        let job = spec_job("eager", 1, Algorithm::HeftmBl, &cluster);
        let cfg = || ServiceConfig {
            workers: 1,
            cache_bytes: Some(1 << 30),
            cache_dir: Some(dir.clone()),
            ..ServiceConfig::default()
        };
        let a = SchedulingService::from_config(cfg()).unwrap();
        a.run_batch(vec![job.clone()]);
        // Both layers configured together: the disk layer serves a fresh
        // service even with the in-memory byte cap active.
        let b = SchedulingService::from_config(cfg()).unwrap();
        b.run_batch(vec![job]);
        assert_eq!(b.cache_stats().computed, 0, "disk layer must survive the byte cap");
        assert_eq!(b.cache_stats().disk_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// [`ServiceConfig::build`] is exactly
    /// [`SchedulingService::from_config`] on the same configuration (the
    /// one construction surface since the `with_*` builders' removal).
    #[test]
    fn service_config_build_matches_from_config() {
        let base = std::env::temp_dir().join(format!("memsched_svc_cfg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("chipseq", 2, algo, &cluster))
                .collect()
        };
        let cfg = |dir: &str| ServiceConfig {
            workers: 2,
            score: ScoreThreadSpec::Auto,
            cache_bytes: Some(1 << 20),
            cache_dir: Some(base.join(dir)),
            cache_dir_bytes: Some(1 << 20),
            ..ServiceConfig::default()
        };
        // Separate dirs: both services start cold.
        let built = cfg("built").build().unwrap();
        let configured = SchedulingService::from_config(cfg("cfg")).unwrap();
        assert_eq!(built.workers(), configured.workers());
        assert_eq!(built.score_threads(), configured.score_threads());
        let r_built = built.run_batch(jobs(()));
        let r_configured = configured.run_batch(jobs(()));
        assert_eq!(to_jsonl(&r_built), to_jsonl(&r_configured));
        assert_eq!(built.cache_stats().computed, configured.cache_stats().computed);
        // The summary records agree on every configuration-derived field.
        assert_eq!(
            built.summary_json(4, 0, 0).to_string_compact(),
            configured.summary_json(4, 0, 0).to_string_compact()
        );
        // An inconsistent combination fails identically through both.
        let bad = ServiceConfig { cache_dir_bytes: Some(1), ..ServiceConfig::default() };
        assert!(bad.build().is_err());
        assert!(SchedulingService::from_config(bad).is_err());
        std::fs::remove_dir_all(&base).ok();
    }

    /// The serve-mode client path answers with the exact bytes a cold
    /// batch emits for the same lines, however warm the shared caches
    /// are — warmth lands in the counters instead.
    #[test]
    fn client_sessions_replay_cold_batch_bytes_on_a_warm_service() {
        let defaults = ParseDefaults::default();
        let cluster = Arc::new(small_cluster());
        let lines = [
            r#"{"model":"bacass","input":1,"seed":5}"#,
            r#"{"model":"bacass","input":1,"seed":5,"algo":"heftm-mm"}"#,
            // Duplicate of the first line: cache_hit within the client.
            r#"{"model":"bacass","input":1,"seed":5}"#,
            r#"{"model":"bacass","input":1,"seed":5,"sweep":[{"mode":"recompute","seed":9},{"mode":"static","seed":9}]}"#,
        ];
        let parse_all = |svc_cluster: &Arc<Cluster>| -> Vec<JobSpec> {
            lines
                .iter()
                .map(|l| {
                    let spec = JobSpec::parse_line(l, &defaults).unwrap();
                    // Pin the inline test cluster (named specs would hit
                    // the preset loader).
                    match spec {
                        JobSpec::Single(mut j) => {
                            j.cluster = ClusterSpec::Inline(svc_cluster.clone());
                            JobSpec::Single(j)
                        }
                        JobSpec::Sweep(mut s) => {
                            s.cluster = ClusterSpec::Inline(svc_cluster.clone());
                            JobSpec::Sweep(s)
                        }
                    }
                })
                .collect()
        };

        // Baseline: one cold service, all lines as one sweep batch.
        let cold = SchedulingService::new(2);
        let baseline = cold
            .run_replay_sweeps(parse_all(&cluster).into_iter().map(JobSpec::into_sweep).collect());

        // Serve-mode: a first client warms the shared service, then a
        // second client submits the same lines one frame at a time.
        let shared = SchedulingService::new(2);
        let mut first = ClientSession::new("c0");
        let mut first_out = Vec::new();
        for spec in parse_all(&cluster) {
            shared.run_client_spec(&mut first, spec, |r| first_out.push(r));
        }
        assert_eq!(to_jsonl(&first_out), to_jsonl(&baseline), "cold client == cold batch");
        assert!(first.counters.schedules_computed > 0);

        let mut second = ClientSession::new("c1");
        let mut second_out = Vec::new();
        for spec in parse_all(&cluster) {
            shared.run_client_spec(&mut second, spec, |r| second_out.push(r));
        }
        assert_eq!(to_jsonl(&second_out), to_jsonl(&baseline), "warm client == cold batch");
        assert_eq!(second.counters.schedules_computed, 0, "warm client computes nothing");
        assert_eq!(second.counters.results, baseline.len());
        assert_eq!(second.counters.failed, 0);
        // Only the intra-client duplicate line is a result cache hit —
        // cross-client warmth must not leak into flags.
        assert_eq!(second.counters.result_cache_hits, first.counters.result_cache_hits);
        let total = first.counters.results + second.counters.results;
        let clients = vec![first, second];
        let summary = shared.summary_json_with_clients(total, 0, 0, &clients).to_string_compact();
        assert!(summary.contains("\"name\":\"c1\""), "{summary}");
        assert!(summary.contains("\"schedules_computed\":0"), "{summary}");
    }

    #[test]
    fn cache_byte_cap_keeps_results_correct() {
        let cluster = Arc::new(small_cluster());
        let jobs = |_: ()| -> Vec<Job> {
            Algorithm::all()
                .iter()
                .copied()
                .map(|algo| spec_job("bacass", 0, algo, &cluster))
                .collect()
        };
        let unbounded = SchedulingService::new(2);
        let r_unbounded = unbounded.run_batch(jobs(()));
        // A 1-byte budget evicts aggressively; outputs must not change.
        let capped = SchedulingService::from_config(ServiceConfig {
            workers: 2,
            cache_bytes: Some(1),
            ..ServiceConfig::default()
        })
        .unwrap();
        let r_capped = capped.run_batch(jobs(()));
        assert_eq!(to_jsonl(&r_unbounded), to_jsonl(&r_capped));
    }
}
