//! Sharded work-stealing worker pool on `std::thread` (no rayon/tokio in
//! this offline tree).
//!
//! Jobs are distributed round-robin over per-worker deques ("shards").
//! Each worker drains its own shard from the front and, when empty,
//! steals from the *back* of the other shards — the classic deque
//! discipline that keeps stolen work coarse and owner work cache-warm.
//! Results are written into per-job slots, so the output vector is always
//! in submission order regardless of worker count or steal interleaving:
//! this is the ordering layer the batch service's byte-identical JSONL
//! guarantee rests on.
//!
//! Job closures must be deterministic functions of `(index, item)`; the
//! pool adds no other source of nondeterminism to their outputs.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Number of workers to use when the caller does not specify one.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, item)` over every item on `workers` threads and return
/// the results in submission order.
///
/// `workers` is clamped to `[1, items.len()]`; with one worker the items
/// run inline on the calling thread (no spawn overhead).
pub fn run_ordered<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    // Round-robin sharding over per-worker deques.
    let mut queues: Vec<VecDeque<(usize, T)>> = (0..workers).map(|_| VecDeque::new()).collect();
    for (i, x) in items.into_iter().enumerate() {
        queues[i % workers].push_back((i, x));
    }
    let shards: Vec<Mutex<VecDeque<(usize, T)>>> = queues.into_iter().map(Mutex::new).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    // Distinct names for the borrows captured by the worker closures, so
    // `slots` itself stays owned and can be consumed after the scope.
    let f_ref = &f;
    let shards_ref = &shards;
    let slots_ref = &slots;
    std::thread::scope(|scope| {
        for w in 0..workers {
            scope.spawn(move || loop {
                // Own shard first (front), then steal from the back of the
                // others. No shard is ever refilled, so an empty sweep
                // means this worker is done.
                let mut task = shards_ref[w].lock().unwrap().pop_front();
                if task.is_none() {
                    for off in 1..workers {
                        let victim = (w + off) % workers;
                        task = shards_ref[victim].lock().unwrap().pop_back();
                        if task.is_some() {
                            break;
                        }
                    }
                }
                match task {
                    Some((i, x)) => {
                        let r = f_ref(i, x);
                        *slots_ref[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every pool job produces a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_submission_order() {
        for workers in [1, 2, 4, 7] {
            let items: Vec<usize> = (0..100).collect();
            let out = run_ordered(items, workers, |i, x| {
                assert_eq!(i, x);
                x * 10
            });
            assert_eq!(out, (0..100).map(|x| x * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let ran = AtomicUsize::new(0);
        let out = run_ordered((0..257).collect::<Vec<usize>>(), 4, |_, x| {
            ran.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(ran.load(Ordering::Relaxed), 257);
        assert_eq!(out.len(), 257);
    }

    #[test]
    fn stealing_balances_skewed_work() {
        // One shard receives all the slow jobs (ids ≡ 0 mod workers);
        // stealing must still let everything finish and stay ordered.
        let out = run_ordered((0..32).collect::<Vec<usize>>(), 4, |i, x| {
            if i % 4 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x + 1
        });
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let out: Vec<usize> = run_ordered(Vec::<usize>::new(), 4, |_, x| x);
        assert!(out.is_empty());
        let out = run_ordered(vec![9usize], 8, |_, x| x * 2);
        assert_eq!(out, vec![18]);
    }

    #[test]
    fn workers_exceeding_jobs_clamped() {
        let out = run_ordered(vec![1usize, 2], 64, |_, x| x);
        assert_eq!(out, vec![1, 2]);
    }
}
