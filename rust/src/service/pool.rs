//! Worker pools on `std::thread` (no rayon/tokio in this offline tree).
//!
//! Two primitives live here:
//!
//! - [`run_ordered`] — a sharded work-stealing batch pool. Jobs are
//!   distributed round-robin over per-worker deques ("shards"). Each
//!   worker drains its own shard from the front and, when empty, steals
//!   from the *back* of the other shards — the classic deque discipline
//!   that keeps stolen work coarse and owner work cache-warm. Results
//!   are written into per-job slots, so the output vector is always in
//!   submission order regardless of worker count or steal interleaving:
//!   this is the ordering layer the batch service's byte-identical JSONL
//!   guarantee rests on.
//!
//! - [`ScorePool`] — a persistent scoped parallel-for pool for the
//!   scheduler's *intra-schedule* hot loop (parallel tentative scoring
//!   across processors, see `scheduler::engine`). Spawning scoped
//!   threads per task would dwarf the scoring work (a 30k-task schedule
//!   issues 30k fan-outs), so `ScorePool` keeps its workers alive across
//!   calls: they spin briefly between jobs (the gap between two tasks of
//!   one schedule is a commit, microseconds) and fall back to a condvar
//!   only when idle for real. Dispatch is therefore a couple of atomic
//!   operations on the hot path.
//!
//! Job closures must be deterministic functions of their index; the
//! pools add no other source of nondeterminism to their outputs.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of workers to use when the caller does not specify one.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, item)` over every item on `workers` threads and return
/// the results in submission order.
///
/// `workers` is clamped to `[1, items.len()]`; with one worker the items
/// run inline on the calling thread (no spawn overhead).
pub fn run_ordered<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return items
            .into_iter()
            .enumerate()
            .map(|(i, x)| {
                let _job = crate::obs::span(crate::obs::SpanKind::WorkerJob);
                f(i, x)
            })
            .collect();
    }

    // Round-robin sharding over per-worker deques.
    let mut queues: Vec<VecDeque<(usize, T)>> = (0..workers).map(|_| VecDeque::new()).collect();
    for (i, x) in items.into_iter().enumerate() {
        queues[i % workers].push_back((i, x));
    }
    let shards: Vec<Mutex<VecDeque<(usize, T)>>> = queues.into_iter().map(Mutex::new).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    // Distinct names for the borrows captured by the worker closures, so
    // `slots` itself stays owned and can be consumed after the scope.
    let f_ref = &f;
    let shards_ref = &shards;
    let slots_ref = &slots;
    std::thread::scope(|scope| {
        for w in 0..workers {
            scope.spawn(move || loop {
                // Own shard first (front), then steal from the back of the
                // others. No shard is ever refilled, so an empty sweep
                // means this worker is done.
                let mut task = shards_ref[w].lock().unwrap().pop_front();
                if task.is_none() {
                    for off in 1..workers {
                        let victim = (w + off) % workers;
                        task = shards_ref[victim].lock().unwrap().pop_back();
                        if task.is_some() {
                            break;
                        }
                    }
                }
                match task {
                    Some((i, x)) => {
                        // Per-worker busy time: the per-tid share of this
                        // span's total is that worker's utilization.
                        let job = crate::obs::span(crate::obs::SpanKind::WorkerJob);
                        let r = f_ref(i, x);
                        drop(job);
                        *slots_ref[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every pool job produces a result"))
        .collect()
}

/// Pointer to the caller-stack closure of a scoped job, type-erased.
///
/// A raw pointer rather than a (lifetime-lying) `&'static` reference:
/// workers may legitimately hold their `Arc<ScopedJob>` a little past
/// `scoped_for`'s return (having observed `next >= n` they only read
/// counters), and a dangling *reference* inside a live struct would
/// violate reference validity rules even if never used. The pointer is
/// only dereferenced between a successful chunk claim (`next < n`) and
/// the matching `done` increment, and `scoped_for` does not return
/// before `done == n` — so every dereference happens while the real
/// closure is still alive on the caller's stack.
struct ErasedFn(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (callable from any thread through a
// shared reference), and the dereference discipline above guarantees
// liveness; the pointer itself is just an address.
unsafe impl Send for ErasedFn {}
unsafe impl Sync for ErasedFn {}

/// One scoped parallel-for call in flight.
struct ScopedJob {
    f: ErasedFn,
    n: usize,
    /// Next chunk index to claim.
    next: AtomicUsize,
    /// Chunks fully executed (panicked ones included — the caller's
    /// completion wait must terminate either way).
    done: AtomicUsize,
    /// Any chunk panicked; the submitting caller re-raises after the
    /// job is fully retired and cleared.
    panicked: AtomicBool,
}

impl ScopedJob {
    /// Claim-and-run loop shared by workers and the submitting caller.
    ///
    /// Panics in the closure are caught and recorded, never allowed to
    /// break the protocol: a worker dying between claim and `done`
    /// would strand the caller in its completion wait, and a caller
    /// unwinding out of `scoped_for` would leave the erased pointer
    /// installed for workers to dereference after the closure is gone.
    fn run_chunks(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            // SAFETY: `i < n` was claimed uniquely, so the submitting
            // `scoped_for` is still blocked on `done == n` and the
            // closure behind the pointer is alive (see `ErasedFn`).
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                (*self.f.0)(i)
            }));
            if outcome.is_err() {
                self.panicked.store(true, Ordering::Release);
            }
            self.done.fetch_add(1, Ordering::Release);
        }
    }
}

struct PoolShared {
    /// Current job; replaced under the mutex, observed via `epoch`.
    job: Mutex<Option<Arc<ScopedJob>>>,
    /// Bumped once per installed job; workers spin on it between jobs.
    epoch: AtomicU64,
    shutdown: AtomicBool,
    /// Wakes workers that gave up spinning (paired with `job`).
    wake: Condvar,
}

/// How many spin iterations a worker tolerates between jobs before
/// blocking on the condvar. Successive tasks of one schedule arrive
/// within microseconds, so the spin window keeps the whole schedule on
/// the fast path while bounding idle burn to well under a millisecond.
const SPIN_LIMIT: u32 = 20_000;

/// A persistent scoped parallel-for pool.
///
/// [`ScorePool::scoped_for`]`(n, f)` runs `f(0..n)` across the pool's
/// threads (the caller participates, so a pool of `t` threads applies
/// `t` cores) and returns once every index completed. Closures may
/// borrow from the caller's stack — the call is fully scoped. Concurrent
/// callers are serialized; the pool adds no nondeterminism (callers
/// decide what each index writes, typically disjoint slots reduced
/// serially afterwards).
pub struct ScorePool {
    shared: Arc<PoolShared>,
    /// Serializes `scoped_for` callers (e.g. service workers sharing one
    /// pool): one scoped job at a time keeps the worker protocol simple.
    caller: Mutex<()>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for ScorePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScorePool").field("threads", &self.threads).finish()
    }
}

impl ScorePool {
    /// A pool applying `threads` total threads per call (the submitting
    /// caller counts as one, so `threads - 1` workers are spawned).
    /// `threads` is clamped to ≥ 1; a 1-thread pool runs everything
    /// inline on the caller.
    pub fn new(threads: usize) -> ScorePool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            job: Mutex::new(None),
            epoch: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            wake: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("score-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn score worker")
            })
            .collect();
        ScorePool { shared, caller: Mutex::new(()), threads, handles }
    }

    /// Total threads applied per `scoped_for` call (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i < n` across the pool and the calling
    /// thread; returns when all completed. `f` may borrow locals.
    pub fn scoped_for(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.threads == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // Poison-tolerant: the caller mutex guards no data, only
        // serialization, and a previous caller may have (deliberately)
        // unwound out of this function after its closure panicked.
        let _serialize = self.caller.lock().unwrap_or_else(|e| e.into_inner());
        // SAFETY: erase the closure's lifetime into a raw pointer. Sound
        // because this function only returns after `done == n` (every
        // claimed chunk finished) and no new chunk can be claimed once
        // `next >= n`, so no dereference outlives the real borrow.
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let job = Arc::new(ScopedJob {
            f: ErasedFn(erased as *const (dyn Fn(usize) + Sync)),
            n,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        {
            let mut slot = self.shared.job.lock().unwrap();
            *slot = Some(job.clone());
            self.shared.epoch.fetch_add(1, Ordering::Release);
            self.shared.wake.notify_all();
        }
        // The caller works too, with the same panic-capturing protocol
        // (an unwind here must not skip the job teardown below).
        job.run_chunks();
        // Wait for straggler workers still executing claimed chunks.
        let mut spins = 0u32;
        while job.done.load(Ordering::Acquire) < n {
            spins += 1;
            if spins > SPIN_LIMIT {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        *self.shared.job.lock().unwrap() = None;
        // Re-raise only after the job is retired and cleared: every
        // chunk ran (or unwound) and no worker can reach the closure.
        if job.panicked.load(Ordering::Acquire) {
            panic!("ScorePool: a scoped closure panicked (see stderr for the original panic)");
        }
    }
}

impl Drop for ScorePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _slot = self.shared.job.lock().unwrap();
            self.shared.wake.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut seen = 0u64;
    let mut spins = 0u32;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let epoch = shared.epoch.load(Ordering::Acquire);
        if epoch != seen {
            seen = epoch;
            spins = 0;
            let job = shared.job.lock().unwrap().clone();
            if let Some(job) = job {
                // The submitting caller keeps the closure alive until
                // `done == n` (see `ScopedJob` docs).
                job.run_chunks();
            }
            continue;
        }
        spins += 1;
        if spins < SPIN_LIMIT {
            std::hint::spin_loop();
            continue;
        }
        // Idle for real: block until the next job (or shutdown).
        let guard = shared.job.lock().unwrap();
        if shared.epoch.load(Ordering::Acquire) != seen || shared.shutdown.load(Ordering::Acquire)
        {
            continue;
        }
        let _guard = shared.wake.wait(guard).unwrap();
        spins = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_submission_order() {
        for workers in [1, 2, 4, 7] {
            let items: Vec<usize> = (0..100).collect();
            let out = run_ordered(items, workers, |i, x| {
                assert_eq!(i, x);
                x * 10
            });
            assert_eq!(out, (0..100).map(|x| x * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let ran = AtomicUsize::new(0);
        let out = run_ordered((0..257).collect::<Vec<usize>>(), 4, |_, x| {
            ran.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(ran.load(Ordering::Relaxed), 257);
        assert_eq!(out.len(), 257);
    }

    #[test]
    fn stealing_balances_skewed_work() {
        // One shard receives all the slow jobs (ids ≡ 0 mod workers);
        // stealing must still let everything finish and stay ordered.
        let out = run_ordered((0..32).collect::<Vec<usize>>(), 4, |i, x| {
            if i % 4 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x + 1
        });
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_inputs() {
        let out: Vec<usize> = run_ordered(Vec::<usize>::new(), 4, |_, x| x);
        assert!(out.is_empty());
        let out = run_ordered(vec![9usize], 8, |_, x| x * 2);
        assert_eq!(out, vec![18]);
    }

    #[test]
    fn workers_exceeding_jobs_clamped() {
        let out = run_ordered(vec![1usize, 2], 64, |_, x| x);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn scoped_for_runs_every_index_once() {
        for threads in [1, 2, 4] {
            let pool = ScorePool::new(threads);
            assert_eq!(pool.threads(), threads.max(1));
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            pool.scoped_for(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn scoped_for_borrows_caller_stack_state() {
        // The whole point of the scoped API: closures borrow locals.
        let pool = ScorePool::new(3);
        let input: Vec<u64> = (0..100).collect();
        let out: Vec<Mutex<u64>> = (0..100).map(|_| Mutex::new(0)).collect();
        pool.scoped_for(100, &|i| {
            *out[i].lock().unwrap() = input[i] * 3;
        });
        for (i, slot) in out.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), i as u64 * 3);
        }
    }

    #[test]
    fn scoped_for_is_cheap_to_reissue() {
        // The engine issues one scoped call per task; thousands of
        // back-to-back calls must work (workers spin between them).
        let pool = ScorePool::new(4);
        let counter = AtomicUsize::new(0);
        for _ in 0..2000 {
            pool.scoped_for(4, &|_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 8000);
    }

    #[test]
    fn scoped_for_zero_and_one_chunk() {
        let pool = ScorePool::new(4);
        pool.scoped_for(0, &|_| panic!("no chunks to run"));
        let ran = AtomicUsize::new(0);
        pool.scoped_for(1, &|i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_callers_serialize_on_one_pool() {
        // Several service workers sharing one score pool: calls must not
        // interleave chunks of different jobs into the wrong closure.
        let pool = ScorePool::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..4 {
                let (pool, total) = (&pool, &total);
                s.spawn(move || {
                    for _ in 0..50 {
                        let local = AtomicUsize::new(0);
                        pool.scoped_for(8, &|_| {
                            local.fetch_add(1, Ordering::Relaxed);
                        });
                        assert_eq!(local.load(Ordering::Relaxed), 8, "caller {t}");
                        total.fetch_add(8, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 8);
    }

    #[test]
    fn dropping_an_idle_pool_joins_workers() {
        let pool = ScorePool::new(3);
        pool.scoped_for(5, &|_| {});
        drop(pool); // must not hang on sleeping workers
    }

    #[test]
    fn scoped_closure_panics_propagate_without_hanging() {
        let pool = ScorePool::new(3);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped_for(8, &|i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(outcome.is_err(), "a chunk panic must re-raise on the caller");
        // The pool stays usable: no stranded chunks, no poisoned state.
        let ran = AtomicUsize::new(0);
        pool.scoped_for(4, &|_| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 4);
    }
}
