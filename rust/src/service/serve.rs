//! `memsched serve`: a long-running scheduler daemon with streaming
//! admission (see DESIGN.md §Serve).
//!
//! Clients connect over a Unix socket (or the process's stdio) and
//! exchange length-delimited JSON frames ([`crate::ser::frame`]). Each
//! request frame is either a **job line** — the exact grammar of a
//! `memsched batch --input` line, parsed by the shared
//! [`JobSpec`] parser — or a **control object** `{"ctl": ...}`:
//!
//! | request                | response                              |
//! |------------------------|---------------------------------------|
//! | job / sweep line       | one result frame per result line      |
//! | `{"ctl":"drain"}`      | `{"ok":"drained"}` after all earlier  |
//! |                        | submissions' results                  |
//! | `{"ctl":"ping"}`       | `{"ok":"pong"}` immediately           |
//! | `{"ctl":"stats"}`      | one `{"stats": ...}` frame: live      |
//! |                        | global counters + per-client summaries|
//! | `{"ctl":"shutdown"}`   | `{"ok":"shutting down"}`; the daemon  |
//! |                        | drains every queue and exits          |
//!
//! Malformed frames (bad JSON, unknown fields, oversized payloads)
//! answer with a structured `{"error": ...}` frame — connection and
//! process stay alive; only an unframable stream (bad magic, truncation)
//! drops that one connection. Result frames carry **exactly** the JSONL
//! line bytes `memsched batch` would emit for the same submitted lines:
//! per-client ids continue across frames and `cache_hit` flags replay
//! the client's own history ([`SchedulingService::run_client_spec`]),
//! so a shared warm daemon is byte-indistinguishable from a cold batch
//! — cache warmth shows up only in the per-client counters.
//!
//! **Admission** is fair-share: one queue per client, capped at
//! [`ServeOptions::max_queued_per_client`] (overflow rejects with an
//! error frame instead of buffering unboundedly), drained round-robin
//! by a single dispatcher thread — one submission at a time, each
//! fanning out internally across the service's worker pool. **Shutdown**
//! (SIGTERM/SIGINT via [`install_signal_handlers`], or a `shutdown`
//! frame) stops admission, drains every queued submission, and returns
//! cleanly — `memsched serve` then exits 0.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::os::unix::net::UnixListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::obs;
use crate::ser::frame::{self, FrameError};
use crate::ser::json::{obj, Value};

use super::{ClientSession, JobSpec, ParseDefaults, SchedulingService};

/// Poll interval for the accept loop and the dispatcher's signal check.
const POLL: Duration = Duration::from_millis(25);

/// Process-wide graceful-shutdown flag, set only by the real signal
/// handler (`shutdown` frames flip per-serve state instead, so embedded
/// servers — tests — never leak shutdown across runs).
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: libc::c_int) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers that request a graceful drain (the
/// daemon finishes queued work, then exits). Call once, from `main`.
pub fn install_signal_handlers() {
    let handler: extern "C" fn(libc::c_int) = on_signal;
    unsafe {
        libc::signal(libc::SIGTERM, handler as libc::sighandler_t);
        libc::signal(libc::SIGINT, handler as libc::sighandler_t);
    }
}

/// Daemon knobs (all CLI-exposed).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Frame-payload cap (`--max-frame-bytes`); oversized frames are
    /// rejected with an error frame, the connection stays framed.
    pub max_frame_bytes: usize,
    /// Per-client admission-queue cap (`--max-queued-per-client`).
    pub max_queued_per_client: usize,
    /// Defaults applied to job lines that omit `cluster`/`seed` —
    /// mirror `batch --cluster/--seed` for byte-identical parses.
    pub defaults: ParseDefaults,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_frame_bytes: frame::DEFAULT_MAX_FRAME_BYTES,
            max_queued_per_client: 1024,
            defaults: ParseDefaults::default(),
        }
    }
}

/// What a serve run did: one [`ClientSession`] per client, in
/// disconnect order (clients still connected at shutdown last, by
/// accept order).
#[derive(Debug)]
pub struct ServeSummary {
    pub clients: Vec<ClientSession>,
}

impl ServeSummary {
    pub fn total_results(&self) -> usize {
        self.clients.iter().map(|c| c.counters.results).sum()
    }

    pub fn total_cache_hits(&self) -> usize {
        self.clients.iter().map(|c| c.counters.result_cache_hits).sum()
    }

    pub fn total_failed(&self) -> usize {
        self.clients.iter().map(|c| c.counters.failed).sum()
    }
}

/// One queued client request.
enum QueueItem {
    Spec(JobSpec),
    /// Barrier: acked (`{"ok":"drained"}`) strictly after every earlier
    /// submission's results have been written.
    Drain,
    /// Live metrics snapshot, answered by the dispatcher (readers never
    /// touch the service). Queued like a drain so the reply observes
    /// every earlier submission of this client.
    Stats,
}

type SharedWriter = Arc<Mutex<Box<dyn Write + Send>>>;

struct ClientSlot {
    id: u64,
    name: String,
    queue: VecDeque<QueueItem>,
    writer: SharedWriter,
    /// Taken (`None`) only while the dispatcher executes this client's
    /// work; a slot is reaped only with its session present, so a
    /// session can never be lost mid-submission.
    session: Option<ClientSession>,
    /// Backpressure rejections recorded by the reader (merged into the
    /// session's counters at reap, since the session may be taken).
    rejected: usize,
    /// Reader saw EOF or a terminal frame error.
    closed: bool,
}

struct ServeState {
    clients: Vec<ClientSlot>,
    /// Round-robin cursor: the smallest client id not yet preferred.
    cursor: u64,
    next_client: u64,
    /// `shutdown` frame received (per-serve; the signal flag is global).
    shutdown: bool,
    /// Sessions of disconnected-and-drained clients.
    finished: Vec<ClientSession>,
}

type Shared = Arc<(Mutex<ServeState>, Condvar)>;

fn new_shared() -> Shared {
    Arc::new((
        Mutex::new(ServeState {
            clients: Vec::new(),
            cursor: 0,
            next_client: 0,
            shutdown: false,
            finished: Vec::new(),
        }),
        Condvar::new(),
    ))
}

/// Round-robin pick: the eligible client (non-empty queue, session at
/// rest) with the smallest id ≥ cursor, wrapping to the smallest
/// overall.
fn pick(state: &ServeState) -> Option<usize> {
    let eligible = |c: &ClientSlot| !c.queue.is_empty() && c.session.is_some();
    let mut first: Option<usize> = None;
    let mut first_ge: Option<usize> = None;
    for (i, c) in state.clients.iter().enumerate() {
        if !eligible(c) {
            continue;
        }
        if first.map_or(true, |f| c.id < state.clients[f].id) {
            first = Some(i);
        }
        if c.id >= state.cursor && first_ge.map_or(true, |f| c.id < state.clients[f].id) {
            first_ge = Some(i);
        }
    }
    first_ge.or(first)
}

fn send_payload(writer: &SharedWriter, payload: &[u8]) {
    // Write errors mean the client vanished; its reader will observe
    // EOF and close the slot — nothing useful to do here.
    let mut w = writer.lock().unwrap();
    let _ = frame::write_frame(&mut *w, payload);
    let _ = w.flush();
}

fn send_error(writer: &SharedWriter, msg: &str) {
    send_payload(writer, obj(vec![("error", msg.into())]).to_string_compact().as_bytes());
}

fn send_ok(writer: &SharedWriter, what: &str) {
    send_payload(writer, obj(vec![("ok", what.into())]).to_string_compact().as_bytes());
}

/// Register a connection: create its slot and spawn its reader thread
/// (detached — it parks in `read` until the peer sends or hangs up, and
/// dies with the process).
fn register_client(
    shared: &Shared,
    reader: impl Read + Send + 'static,
    writer: SharedWriter,
    name: Option<String>,
    opts: &ServeOptions,
) {
    let (lock, cvar) = &**shared;
    let id = {
        let mut state = lock.lock().unwrap();
        let id = state.next_client;
        state.next_client += 1;
        let name = name.unwrap_or_else(|| format!("c{id}"));
        state.clients.push(ClientSlot {
            id,
            name: name.clone(),
            queue: VecDeque::new(),
            writer: writer.clone(),
            session: Some(ClientSession::new(name)),
            rejected: 0,
            closed: false,
        });
        id
    };
    cvar.notify_all();
    let shared = shared.clone();
    let opts = opts.clone();
    std::thread::spawn(move || reader_loop(reader, writer, shared, id, opts));
}

/// Per-connection reader: decode frames, admit work, answer protocol
/// errors. Never touches the scheduling service.
fn reader_loop(
    mut reader: impl Read,
    writer: SharedWriter,
    shared: Shared,
    client_id: u64,
    opts: ServeOptions,
) {
    let (lock, cvar) = &*shared;
    loop {
        match frame::read_frame(&mut reader, opts.max_frame_bytes) {
            Ok(None) => break,
            Ok(Some(payload)) => {
                let Ok(text) = std::str::from_utf8(&payload) else {
                    send_error(&writer, "frame payload is not UTF-8");
                    continue;
                };
                let v = match Value::parse(text) {
                    Ok(v) => v,
                    Err(e) => {
                        send_error(&writer, &format!("bad frame payload: {e}"));
                        continue;
                    }
                };
                if let Some(ctl) = v.get("ctl") {
                    match ctl.as_str() {
                        Some("shutdown") => {
                            lock.lock().unwrap().shutdown = true;
                            cvar.notify_all();
                            send_ok(&writer, "shutting down");
                        }
                        Some("ping") => send_ok(&writer, "pong"),
                        Some(kind @ ("drain" | "stats")) => {
                            // Barrier-like items are always admitted
                            // (they free or merely observe the queue;
                            // rejecting a drain could deadlock a
                            // well-behaved client).
                            let item = if kind == "drain" {
                                QueueItem::Drain
                            } else {
                                QueueItem::Stats
                            };
                            let mut state = lock.lock().unwrap();
                            if let Some(c) =
                                state.clients.iter_mut().find(|c| c.id == client_id)
                            {
                                c.queue.push_back(item);
                            }
                            drop(state);
                            cvar.notify_all();
                        }
                        other => send_error(
                            &writer,
                            &format!(
                                "unknown ctl {:?} (expected shutdown, ping, drain, stats)",
                                other.unwrap_or("<non-string>")
                            ),
                        ),
                    }
                    continue;
                }
                match JobSpec::parse(&v, &opts.defaults) {
                    Err(e) => send_error(&writer, &format!("bad job line: {e:#}")),
                    Ok(spec) => {
                        let mut state = lock.lock().unwrap();
                        let shutting_down = state.shutdown || SHUTDOWN.load(Ordering::SeqCst);
                        let Some(c) = state.clients.iter_mut().find(|c| c.id == client_id)
                        else {
                            break;
                        };
                        if shutting_down {
                            c.rejected += 1;
                            drop(state);
                            if obs::enabled() {
                                obs::record(obs::Event::FrameRejected {
                                    client: client_id as u32,
                                });
                            }
                            send_error(&writer, "rejected: daemon is shutting down");
                        } else if c.queue.len() >= opts.max_queued_per_client {
                            // Backpressure: structured rejection instead
                            // of unbounded buffering.
                            c.rejected += 1;
                            let queued = c.queue.len();
                            drop(state);
                            if obs::enabled() {
                                obs::record(obs::Event::FrameRejected {
                                    client: client_id as u32,
                                });
                            }
                            send_error(
                                &writer,
                                &format!(
                                    "rejected: client queue is full ({queued} queued, cap {})",
                                    opts.max_queued_per_client
                                ),
                            );
                        } else {
                            c.queue.push_back(QueueItem::Spec(spec));
                            drop(state);
                            if obs::enabled() {
                                obs::record(obs::Event::FrameAdmitted {
                                    client: client_id as u32,
                                });
                            }
                            cvar.notify_all();
                        }
                    }
                }
            }
            Err(e) if e.recoverable() => send_error(&writer, &e.to_string()),
            Err(e) => {
                // Unframable stream: report best-effort and drop this
                // connection (the daemon itself stays up).
                send_error(&writer, &e.to_string());
                break;
            }
        }
    }
    let mut state = lock.lock().unwrap();
    if let Some(c) = state.clients.iter_mut().find(|c| c.id == client_id) {
        c.closed = true;
    }
    drop(state);
    cvar.notify_all();
}

/// Move closed, fully-drained clients out of the active set.
fn reap(state: &mut ServeState) {
    let mut i = 0;
    while i < state.clients.len() {
        let c = &state.clients[i];
        if c.closed && c.queue.is_empty() && c.session.is_some() {
            let slot = state.clients.remove(i);
            let mut session = slot.session.unwrap();
            session.counters.rejected += slot.rejected;
            state.finished.push(session);
        } else {
            i += 1;
        }
    }
}

/// The `{"ctl":"stats"}` reply: live global counters plus one summary
/// per client session — finished sessions first (disconnect order), then
/// the live ones in slot order. `asking` is the session the dispatcher
/// checked out of its slot to serve this very request (serial dispatch:
/// it is the only one absent from the slots).
fn stats_json(svc: &SchedulingService, state: &ServeState, asking: &ClientSession) -> Value {
    let mut clients: Vec<Value> =
        state.finished.iter().map(ClientSession::summary_json).collect();
    for slot in &state.clients {
        match &slot.session {
            Some(s) => clients.push(s.summary_json()),
            None => clients.push(asking.summary_json()),
        }
    }
    obj(vec![(
        "stats",
        obj(vec![
            ("schema", crate::obs::SCHEMA_VERSION.into()),
            ("tracing", crate::obs::enabled().into()),
            ("counters", svc.counters().to_json()),
            ("clients", Value::Array(clients)),
        ]),
    )])
}

/// The dispatcher: runs on the calling thread until shutdown (or, in
/// stdio mode, until the one client disconnects and drains). One
/// submission executes at a time — fairness comes from the round-robin
/// queue pick, parallelism from the service's worker pool inside each
/// submission; serial dispatch is also what makes the per-client
/// `schedules_computed` attribution exact.
fn dispatch(svc: &SchedulingService, shared: &Shared, stdio_mode: bool) -> Vec<ClientSession> {
    let (lock, cvar) = &**shared;
    let mut state = lock.lock().unwrap();
    loop {
        reap(&mut state);
        let shutting_down = state.shutdown || SHUTDOWN.load(Ordering::SeqCst);
        if let Some(pos) = pick(&state) {
            let slot = &mut state.clients[pos];
            let id = slot.id;
            let item = slot.queue.pop_front().unwrap();
            let mut session = slot.session.take().unwrap();
            let writer = slot.writer.clone();
            state.cursor = id + 1;
            // Stats snapshots need the lock-protected session set, so the
            // reply is rendered before the state guard drops (serial
            // dispatch: only this client's session is checked out).
            let stats_payload = match item {
                QueueItem::Stats => {
                    Some(stats_json(svc, &state, &session).to_string_compact())
                }
                _ => None,
            };
            drop(state);
            if obs::enabled() {
                obs::record(obs::Event::DispatchPick { client: id as u32 });
            }
            let dispatch_span = obs::span(obs::SpanKind::Dispatch);
            match item {
                QueueItem::Spec(spec) => {
                    // Result frames carry exactly the JSONL line bytes
                    // `memsched batch` emits for the same lines.
                    svc.run_client_spec(&mut session, spec, |r| {
                        send_payload(&writer, r.to_jsonl().as_bytes());
                    });
                }
                QueueItem::Drain => send_ok(&writer, "drained"),
                QueueItem::Stats => {
                    send_payload(&writer, stats_payload.unwrap().as_bytes())
                }
            }
            drop(dispatch_span);
            state = lock.lock().unwrap();
            if let Some(c) = state.clients.iter_mut().find(|c| c.id == id) {
                c.session = Some(session);
            } else {
                // Unreachable (reap requires the session present), but
                // never lose a session's counters.
                state.finished.push(session);
            }
            continue;
        }
        if shutting_down {
            break;
        }
        if stdio_mode && state.clients.is_empty() {
            break;
        }
        // Idle: wait for admission (condvar) or a signal (timeout poll).
        state = cvar.wait_timeout(state, POLL).unwrap().0;
    }
    // Shutdown: queues are drained (pick() found nothing). Collect the
    // remaining (still-connected) sessions after the finished ones.
    let mut out = std::mem::take(&mut state.finished);
    for slot in state.clients.drain(..) {
        if let Some(mut session) = slot.session {
            session.counters.rejected += slot.rejected;
            out.push(session);
        }
    }
    out
}

/// Serve an already-bound listener until shutdown. The test-facing
/// entry point: `memsched serve --socket` wraps it via [`serve_unix`].
pub fn serve_listener(
    svc: &SchedulingService,
    listener: UnixListener,
    opts: &ServeOptions,
) -> anyhow::Result<ServeSummary> {
    listener.set_nonblocking(true)?;
    let shared = new_shared();
    let done = Arc::new(AtomicBool::new(false));

    // Detached acceptor: polls so it can observe `done` and exit
    // instead of pinning the listener forever.
    {
        let shared = shared.clone();
        let done = done.clone();
        let opts = opts.clone();
        std::thread::spawn(move || {
            while !done.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let writer: SharedWriter = match stream.try_clone() {
                            Ok(w) => Arc::new(Mutex::new(Box::new(w))),
                            Err(_) => continue,
                        };
                        register_client(&shared, stream, writer, None, &opts);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(POLL);
                    }
                    Err(_) => std::thread::sleep(POLL),
                }
            }
        });
    }

    let clients = dispatch(svc, &shared, false);
    done.store(true, Ordering::SeqCst);
    Ok(ServeSummary { clients })
}

/// Bind `path` (removing any stale socket file first), serve until
/// shutdown, remove the socket file.
pub fn serve_unix(
    svc: &SchedulingService,
    path: &Path,
    opts: &ServeOptions,
) -> anyhow::Result<ServeSummary> {
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .map_err(|e| anyhow::anyhow!("binding {}: {e}", path.display()))?;
    let summary = serve_listener(svc, listener, opts);
    let _ = std::fs::remove_file(path);
    summary
}

/// Serve one client over the process's stdin/stdout (`--stdio`); returns
/// when stdin closes (and the queue is drained) or on shutdown.
pub fn serve_stdio(svc: &SchedulingService, opts: &ServeOptions) -> anyhow::Result<ServeSummary> {
    let shared = new_shared();
    let writer: SharedWriter = Arc::new(Mutex::new(Box::new(std::io::stdout())));
    register_client(&shared, std::io::stdin(), writer, Some("stdio".into()), opts);
    let clients = dispatch(svc, &shared, true);
    Ok(ServeSummary { clients })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: u64, queued: usize) -> ClientSlot {
        let mut queue = VecDeque::new();
        for _ in 0..queued {
            queue.push_back(QueueItem::Drain);
        }
        ClientSlot {
            id,
            name: format!("c{id}"),
            queue,
            writer: Arc::new(Mutex::new(Box::new(std::io::sink()))),
            session: Some(ClientSession::new(format!("c{id}"))),
            rejected: 0,
            closed: false,
        }
    }

    fn state_with(clients: Vec<ClientSlot>) -> ServeState {
        ServeState { clients, cursor: 0, next_client: 0, shutdown: false, finished: Vec::new() }
    }

    #[test]
    fn round_robin_alternates_between_backlogged_clients() {
        // Two clients with deep queues must alternate strictly, however
        // much work either has queued — that's the fair-share property.
        let mut state = state_with(vec![slot(0, 3), slot(1, 1), slot(2, 2)]);
        let mut served = Vec::new();
        while let Some(pos) = pick(&state) {
            let id = state.clients[pos].id;
            state.clients[pos].queue.pop_front();
            state.cursor = id + 1;
            served.push(id);
        }
        assert_eq!(served, vec![0, 1, 2, 0, 2, 0]);
    }

    #[test]
    fn pick_skips_executing_and_empty_clients() {
        let mut state = state_with(vec![slot(0, 1), slot(1, 1), slot(2, 0)]);
        // Client 0 is mid-execution (session taken): never picked.
        state.clients[0].session = None;
        assert_eq!(pick(&state).map(|p| state.clients[p].id), Some(1));
        state.clients[1].queue.clear();
        assert!(pick(&state).is_none());
    }

    #[test]
    fn reap_merges_rejections_and_keeps_busy_clients() {
        let mut state = state_with(vec![slot(0, 0), slot(1, 2), slot(2, 0)]);
        state.clients[0].closed = true;
        state.clients[0].rejected = 3;
        state.clients[1].closed = true; // still has queued work
        reap(&mut state);
        assert_eq!(state.clients.len(), 2);
        assert_eq!(state.finished.len(), 1);
        assert_eq!(state.finished[0].name, "c0");
        assert_eq!(state.finished[0].counters.rejected, 3);
    }
}
