//! Task-parameter deviation model (paper §VI-A-3).
//!
//! The runtime system applies a normally distributed random deviation to
//! each task's estimated execution time and memory requirement: the
//! estimate is the mean and the relative standard deviation is `sigma`
//! (the paper uses 10%, matching observed prediction errors [6], [8], [9]).
//!
//! Deviations are *per task* and deterministic in `(seed, task id)`, so
//! the with- and without-recomputation runs of the same experiment see
//! identical actual values.

use crate::util::rng::Rng;
use crate::workflow::{TaskId, Workflow};

/// Deviation generator.
#[derive(Debug, Clone, Copy)]
pub struct DeviationModel {
    /// Relative standard deviation (0.1 = 10%).
    pub sigma: f64,
    pub seed: u64,
}

impl DeviationModel {
    pub fn new(sigma: f64, seed: u64) -> DeviationModel {
        DeviationModel { sigma, seed }
    }

    /// No deviation at all (static re-runs).
    pub fn none(seed: u64) -> DeviationModel {
        DeviationModel { sigma: 0.0, seed }
    }

    /// Actual (work, memory) for task `u` given estimates.
    /// Truncated below at 1% of the estimate (resources are positive).
    pub fn actual(&self, u: TaskId, est_work: f64, est_memory: f64) -> (f64, f64) {
        if self.sigma == 0.0 {
            return (est_work, est_memory);
        }
        let mut rng = Rng::new(self.seed ^ (u as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let w = rng.normal_with(est_work, self.sigma * est_work).max(0.01 * est_work);
        let m = rng.normal_with(est_memory, self.sigma * est_memory).max(0.01 * est_memory);
        (w, m)
    }

    /// Apply to a whole workflow: the "ground truth" run.
    pub fn deviate_workflow(&self, wf: &Workflow) -> Workflow {
        let mut out = wf.clone();
        for u in 0..wf.num_tasks() {
            let t = wf.task(u);
            let (w, m) = self.actual(u, t.work, t.memory);
            out.set_task_params(u, w, m);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::WorkflowBuilder;

    #[test]
    fn deterministic_per_task() {
        let d = DeviationModel::new(0.1, 42);
        let (w1, m1) = d.actual(7, 100.0, 1e9);
        let (w2, m2) = d.actual(7, 100.0, 1e9);
        assert_eq!(w1, w2);
        assert_eq!(m1, m2);
        let (w3, _) = d.actual(8, 100.0, 1e9);
        assert_ne!(w1, w3);
    }

    #[test]
    fn zero_sigma_is_identity() {
        let d = DeviationModel::none(1);
        assert_eq!(d.actual(3, 50.0, 2e9), (50.0, 2e9));
    }

    #[test]
    fn ten_percent_sigma_statistics() {
        let d = DeviationModel::new(0.1, 9);
        let n = 5000;
        let ws: Vec<f64> = (0..n).map(|u| d.actual(u, 100.0, 1.0).0).collect();
        let mean = ws.iter().sum::<f64>() / n as f64;
        let sd = (ws.iter().map(|w| (w - mean) * (w - mean)).sum::<f64>() / n as f64).sqrt();
        assert!((mean - 100.0).abs() < 1.0, "mean {mean}");
        assert!((sd - 10.0).abs() < 1.0, "sd {sd}");
        assert!(ws.iter().all(|&w| w > 0.0));
    }

    #[test]
    fn deviate_workflow_changes_params_only() {
        let mut b = WorkflowBuilder::new("w");
        let a = b.task("a", "t", 100.0, 1e9);
        let c = b.task("c", "t", 100.0, 1e9);
        b.edge(a, c, 5.0);
        let wf = b.build().unwrap();
        let d = DeviationModel::new(0.1, 3);
        let dv = d.deviate_workflow(&wf);
        assert_eq!(dv.num_tasks(), 2);
        assert_eq!(dv.edge(0).data, 5.0); // edges untouched
        assert_ne!(dv.task(0).work, 100.0);
    }
}
