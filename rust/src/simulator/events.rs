//! Event queues for the discrete-event replay loop.
//!
//! The replay frontier holds at most one finish event per processor —
//! [`SimRun`](super::SimRun) starts at most one task per processor and
//! pushes exactly one finish event per start — so the queue never
//! exceeds `k = |cluster|` entries (6–72 on the preset clusters). At
//! that size a binary heap's `O(log k)` push/pop is a handful of
//! branches and the heap stays in one cache line, which is why
//! [`EventQueueKind::Heap`] is the default. The calendar queue
//! ([`EventQueueKind::Calendar`]) is the classic alternative for large
//! frontiers (`O(1)` amortized when events spread evenly over buckets);
//! it is kept selectable so `bench_replay` can measure both on the same
//! grid — see DESIGN.md's replay-core section for the comparison.
//!
//! Both variants pop in the exact same total order — ascending
//! `(time bits, task id)` — so outcomes are bit-identical whichever is
//! selected; `calendar_pops_in_heap_order` pins that below.

use crate::workflow::TaskId;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Which event-queue implementation a [`super::SimRun`] drives its
/// discrete-event loop with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EventQueueKind {
    /// `BinaryHeap` keyed on `(time bits, task)` — the default: the
    /// frontier is bounded by the processor count, where a heap wins.
    #[default]
    Heap,
    /// Calendar (bucketed) queue: events hash into day-wide buckets by
    /// `floor(t / width)`; pops scan the current day for the minimum.
    Calendar,
}

/// Number of calendar buckets. Fixed: the frontier is small (≤ one
/// event per processor), so resizing heuristics would never trigger.
const CALENDAR_BUCKETS: usize = 64;

/// A classic calendar queue over `(time bits, task)` events.
///
/// Days are absolute (`floor(t / width)`), mapped onto a fixed ring of
/// [`CALENDAR_BUCKETS`] slots; a slot may alias events of several days,
/// so pops filter by the current day and fall back to a direct
/// minimum-day jump after one fruitless cycle (sparse far-future
/// events). Within a day the minimum `(bits, task)` entry is selected,
/// which makes the pop order identical to the heap's.
#[derive(Debug, Default)]
pub struct CalendarQueue {
    /// `buckets[d % CALENDAR_BUCKETS]` holds the events of day `d`
    /// (plus aliased events of other days).
    buckets: Vec<Vec<(u64, TaskId)>>,
    /// Bucket width in simulated time units.
    width: f64,
    /// Absolute day cursor: no remaining event lies before this day.
    day: u64,
    len: usize,
}

impl CalendarQueue {
    fn day_of(width: f64, key: u64) -> u64 {
        (f64::from_bits(key) / width) as u64
    }

    /// Empty the queue (keeping bucket allocations) and re-derive the
    /// bucket width from the expected event horizon.
    fn reset(&mut self, horizon: f64) {
        let width = horizon / CALENDAR_BUCKETS as f64;
        self.width = if width.is_finite() && width > 0.0 { width } else { 1.0 };
        self.buckets.resize_with(CALENDAR_BUCKETS, Vec::new);
        for b in &mut self.buckets {
            b.clear();
        }
        self.day = 0;
        self.len = 0;
    }

    fn push(&mut self, key: u64, v: TaskId) {
        let d = Self::day_of(self.width, key);
        // Events are pushed at or after the current simulated time, so
        // `d >= self.day` in practice; stay correct if a caller doesn't.
        if d < self.day {
            self.day = d;
        }
        let slot = (d % self.buckets.len() as u64) as usize;
        self.buckets[slot].push((key, v));
        self.len += 1;
    }

    /// Remove and return the minimum `(key, task)` event of day `d`, if
    /// its slot holds any event of that day.
    fn take_min_of_day(&mut self, d: u64) -> Option<(u64, TaskId)> {
        let width = self.width;
        let slot = (d % self.buckets.len() as u64) as usize;
        let bucket = &mut self.buckets[slot];
        let mut best: Option<usize> = None;
        for (i, &ev) in bucket.iter().enumerate() {
            if Self::day_of(width, ev.0) == d && best.is_none_or(|b| ev < bucket[b]) {
                best = Some(i);
            }
        }
        let i = best?;
        self.len -= 1;
        Some(bucket.swap_remove(i))
    }

    fn pop(&mut self) -> Option<(u64, TaskId)> {
        if self.len == 0 {
            return None;
        }
        // Scan forward at most one ring cycle from the day cursor.
        for _ in 0..self.buckets.len() {
            if let Some(ev) = self.take_min_of_day(self.day) {
                return Some(ev);
            }
            self.day += 1;
        }
        // Every remaining event lies beyond a full cycle: jump straight
        // to the earliest populated day.
        let width = self.width;
        let min_day = self
            .buckets
            .iter()
            .flatten()
            .map(|&(key, _)| Self::day_of(width, key))
            .min()
            .expect("len > 0 implies a populated bucket");
        self.day = min_day;
        self.take_min_of_day(min_day)
    }
}

/// The replay loop's event queue, in the caller-selected implementation
/// ([`super::SimRun::set_event_queue`]). Both variants pop in ascending
/// `(time bits, task id)` order — bit-identical outcomes either way.
#[derive(Debug)]
pub enum EventQueue {
    Heap(BinaryHeap<Reverse<(u64, TaskId)>>),
    Calendar(CalendarQueue),
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::Heap(BinaryHeap::new())
    }
}

impl EventQueue {
    pub fn new(kind: EventQueueKind) -> EventQueue {
        match kind {
            EventQueueKind::Heap => EventQueue::Heap(BinaryHeap::new()),
            EventQueueKind::Calendar => EventQueue::Calendar(CalendarQueue::default()),
        }
    }

    pub fn kind(&self) -> EventQueueKind {
        match self {
            EventQueue::Heap(_) => EventQueueKind::Heap,
            EventQueue::Calendar(_) => EventQueueKind::Calendar,
        }
    }

    /// Empty the queue for a fresh run, keeping allocations. `horizon`
    /// (the planned makespan) sizes the calendar's bucket width; the
    /// heap ignores it.
    pub fn reset(&mut self, horizon: f64) {
        match self {
            EventQueue::Heap(h) => h.clear(),
            EventQueue::Calendar(c) => c.reset(horizon),
        }
    }

    pub fn push(&mut self, key: u64, v: TaskId) {
        match self {
            EventQueue::Heap(h) => h.push(Reverse((key, v))),
            EventQueue::Calendar(c) => c.push(key, v),
        }
    }

    /// Pop the earliest event, ties broken by task id.
    pub fn pop(&mut self) -> Option<(u64, TaskId)> {
        match self {
            EventQueue::Heap(h) => h.pop().map(|Reverse(ev)| ev),
            EventQueue::Calendar(c) => c.pop(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            EventQueue::Heap(h) => h.is_empty(),
            EventQueue::Calendar(c) => c.len == 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-times in [0, 4·horizon) — some beyond the
    /// nominal horizon, like late finish events under deviation.
    fn lcg_times(n: usize, horizon: f64) -> Vec<f64> {
        let mut x = 0x2545_f491_4f6c_dd1du64;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 11) as f64 / (1u64 << 53) as f64 * 4.0 * horizon
            })
            .collect()
    }

    fn drain(q: &mut EventQueue) -> Vec<(u64, TaskId)> {
        let mut out = Vec::new();
        while let Some(ev) = q.pop() {
            out.push(ev);
        }
        out
    }

    #[test]
    fn calendar_pops_in_heap_order() {
        let horizon = 100.0;
        let times = lcg_times(500, horizon);
        let mut heap = EventQueue::new(EventQueueKind::Heap);
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        heap.reset(horizon);
        cal.reset(horizon);
        for (v, &t) in times.iter().enumerate() {
            heap.push(t.to_bits(), v);
            cal.push(t.to_bits(), v);
        }
        assert_eq!(drain(&mut heap), drain(&mut cal));
        assert!(heap.is_empty() && cal.is_empty());
    }

    #[test]
    fn calendar_interleaved_push_pop_matches_heap() {
        // The replay loop's actual shape: pop the minimum, push a few
        // events at or after the popped time.
        let horizon = 50.0;
        let mut heap = EventQueue::new(EventQueueKind::Heap);
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        heap.reset(horizon);
        cal.reset(horizon);
        let mut x = 7u64;
        let mut next_id = 0usize;
        for t in [0.5, 1.0, 3.0, 40.0] {
            heap.push(t.to_bits(), next_id);
            cal.push(t.to_bits(), next_id);
            next_id += 1;
        }
        for _ in 0..200 {
            let a = heap.pop();
            let b = cal.pop();
            assert_eq!(a, b);
            let Some((bits, _)) = a else { break };
            let now = f64::from_bits(bits);
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            for _ in 0..(x % 3) {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                let dt = (x >> 11) as f64 / (1u64 << 53) as f64 * horizon;
                heap.push((now + dt).to_bits(), next_id);
                cal.push((now + dt).to_bits(), next_id);
                next_id += 1;
            }
        }
    }

    #[test]
    fn calendar_handles_sparse_far_future_events() {
        // Two events hundreds of days apart: the pop after the first
        // must take the min-day jump path, not spin day by day.
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        cal.reset(64.0); // width 1.0
        cal.push(0.5f64.to_bits(), 0);
        cal.push(100_000.25f64.to_bits(), 1);
        assert_eq!(cal.pop(), Some((0.5f64.to_bits(), 0)));
        assert_eq!(cal.pop(), Some((100_000.25f64.to_bits(), 1)));
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn calendar_degenerate_horizon_falls_back_to_unit_width() {
        for horizon in [0.0, -3.0, f64::INFINITY, f64::NAN] {
            let mut cal = EventQueue::new(EventQueueKind::Calendar);
            cal.reset(horizon);
            cal.push(2.0f64.to_bits(), 0);
            cal.push(1.0f64.to_bits(), 1);
            assert_eq!(cal.pop(), Some((1.0f64.to_bits(), 1)));
            assert_eq!(cal.pop(), Some((2.0f64.to_bits(), 0)));
        }
    }

    #[test]
    fn reset_clears_between_runs() {
        let mut cal = EventQueue::new(EventQueueKind::Calendar);
        cal.reset(10.0);
        cal.push(5.0f64.to_bits(), 3);
        cal.reset(10.0);
        assert!(cal.is_empty());
        assert_eq!(cal.pop(), None);
        cal.push(1.0f64.to_bits(), 4);
        assert_eq!(cal.pop(), Some((1.0f64.to_bits(), 4)));
    }
}
