//! The runtime system (paper §V, §VI-A-3): discrete-event simulation of a
//! workflow execution in which actual task parameters deviate from the
//! estimates the scheduler used.
//!
//! Two execution modes:
//!
//! - [`SimMode::FollowStatic`] — the original schedule is followed: each
//!   processor executes its assigned tasks in planned order, waiting for
//!   busy processors and unfinished predecessors; if a task no longer fits
//!   in memory, the execution **fails** (the schedule was invalidated by
//!   the deviations);
//! - [`SimMode::Recompute`] — the runtime reveals a task's actual
//!   parameters when it arrives and warns the scheduler when they deviate
//!   significantly (> threshold) or no longer fit; the scheduler then
//!   recomputes the placements of all not-yet-started tasks on the fly
//!   (via [`Engine::resume`]) from a snapshot of the current platform
//!   state.
//!
//! The four §VI-A-3 issue types are all represented: *processor blocked*
//! and *predecessor not finished* are handled by waiting; *not enough
//! memory* fails or triggers recomputation depending on the mode; a *task
//! taking significantly less (or more) time than expected* triggers
//! recomputation.
//!
//! ## Execution shape: scaffold + run
//!
//! The adaptive evaluation replays one static schedule under thousands of
//! deviation points (sigma sweeps, seed grids). Everything that is a pure
//! function of `(workflow, cluster, schedule)` — the rank-position table,
//! the per-processor planned task queues, the per-task estimate tables —
//! is therefore hoisted into an immutable, `Send + Sync` [`SimScaffold`]
//! built **once** per schedule, while all mutable execution state (task
//! states, memory residency, finish times, the event heap) lives in a
//! reusable [`SimRun`] arena that `reset()`s between points instead of
//! reallocating. The replay engine builds one scaffold per sweep and fans
//! the points out across workers, each carrying a thread-local `SimRun`
//! (see `service::SchedulingService::run_replay_sweeps_streaming`);
//! [`simulate`] remains as a thin compatibility shim (scaffold build +
//! one run) with bit-identical outcomes.
//!
//! ## The replay fast path
//!
//! Three structures keep the per-event inner loop off the workflow's
//! edge table entirely on the common path:
//!
//! - **Hoisted edge partitions** — the scaffold precomputes, per task,
//!   the local/remote split of its in-edges against the *initial* plan
//!   (CSR slices of local `(edge, size)` pairs and remote
//!   `(edge, producer, size)` triples, plus the summed remote input
//!   size), and the `(edge, child, size)` view of its out-edges. A run
//!   consults a per-task dirty overlay ([`SimRun`]`::part_dirty`) that
//!   only a recompute can set; clean tasks — every task of a
//!   FollowStatic point — never call `wf.edge()` at start or finish.
//! - **Ready counters** — instead of scanning all parents per queue
//!   head, each task carries a remaining-unfinished-parents countdown
//!   seeded from its in-degree and decremented per out-edge at finish
//!   events; memory-deferred tasks are woken by an O(1) epoch bump per
//!   finish rather than an O(n) flag clear.
//! - **A pluggable event queue** ([`events`]) — binary heap by default
//!   (the frontier holds at most one event per processor), with a
//!   calendar-queue alternative selectable for measurement; both pop in
//!   the same total order, so outcomes are bit-identical.

pub mod deviation;
pub mod events;

pub use deviation::DeviationModel;
pub use events::{EventQueue, EventQueueKind};

use crate::obs;
use crate::platform::{Cluster, ProcId};
use crate::scheduler::engine::{Engine, ResumeParts, Schedule, ScoreBuffers, SelectorState, TaskSchedule};
use crate::scheduler::state::{PendingSet, PlatformState};
use crate::service::pool::ScorePool;
use crate::workflow::{EdgeId, TaskId, Workflow};
use std::sync::{Arc, OnceLock};

/// Execution mode of the runtime system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Follow the static schedule; abort on memory violations.
    FollowStatic,
    /// Recompute the schedule on significant deviations.
    Recompute,
}

impl SimMode {
    /// Canonical wire label (accepted back by the `FromStr` impl).
    pub fn label(self) -> &'static str {
        match self {
            SimMode::FollowStatic => "static",
            SimMode::Recompute => "recompute",
        }
    }
}

impl std::str::FromStr for SimMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "follow-static" | "follow_static" => Ok(SimMode::FollowStatic),
            "recompute" | "dynamic" => Ok(SimMode::Recompute),
            other => anyhow::bail!("unknown simulation mode `{other}` (expected static, recompute)"),
        }
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub mode: SimMode,
    pub deviation: DeviationModel,
    /// Relative deviation that triggers a recomputation (paper: 10%).
    pub recompute_threshold: f64,
}

impl SimConfig {
    pub fn new(mode: SimMode, deviation: DeviationModel) -> SimConfig {
        SimConfig { mode, deviation, recompute_threshold: 0.1 }
    }
}

/// Why an execution failed.
#[derive(Debug, Clone, PartialEq)]
pub enum SimFailure {
    /// A task did not fit in memory on its processor (FollowStatic), or
    /// could not be placed anywhere even after recomputation.
    OutOfMemory { task: TaskId, proc: ProcId },
    /// Evicted files exceeded the communication buffer.
    BufferOverflow { task: TaskId, proc: ProcId },
}

/// Sentinel in [`SimOutcome::finish_times`] for tasks that never started.
///
/// Finish times are non-negative by construction, so `-1.0` is
/// unambiguous — and unlike the previous `NaN` marker it keeps `==` (and
/// therefore slice/`Vec` equality in parity tests) well-behaved.
pub const NEVER_STARTED: f64 = -1.0;

/// Result of one simulated execution.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// True iff every task executed within the memory constraints.
    pub completed: bool,
    /// Total execution time (meaningful only if `completed`).
    pub makespan: f64,
    pub failure: Option<SimFailure>,
    /// Number of schedule recomputations performed.
    pub recomputations: usize,
    /// Tasks that started before failure/completion.
    pub started: usize,
    /// Actual per-task finish times ([`NEVER_STARTED`] where the task
    /// never started — see [`SimOutcome::finish_time`]).
    pub finish_times: Vec<f64>,
}

impl SimOutcome {
    /// `Some(finish time)` of task `v`, `None` if it never started —
    /// including on summary outcomes ([`SimRun::simulate_summary`]),
    /// whose `finish_times` vector is empty.
    pub fn finish_time(&self, v: TaskId) -> Option<f64> {
        self.finish_times.get(v).copied().filter(|&t| t >= 0.0)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum TState {
    NotStarted,
    Running,
    Done,
}

/// Simulate executing `schedule` of `wf_est` (estimated weights) under the
/// deviation model in `cfg`.
///
/// Compatibility shim over the scaffold/run split: builds a
/// [`SimScaffold`] and performs one [`SimRun`]. Because the scaffold
/// owns `Arc`s, the shim clones its three inputs once per call (vs the
/// pre-split simulator, which cloned only the workflow) — negligible
/// next to one discrete-event execution, but callers replaying one
/// schedule at many deviation points should build the scaffold once and
/// reuse a `SimRun` arena instead. Outcomes are bit-identical either
/// way.
pub fn simulate(
    wf_est: &Workflow,
    cluster: &Cluster,
    schedule: &Schedule,
    cfg: &SimConfig,
) -> SimOutcome {
    let scaffold = SimScaffold::new(
        Arc::new(wf_est.clone()),
        Arc::new(cluster.clone()),
        Arc::new(schedule.clone()),
    );
    SimRun::new().simulate(&scaffold, cfg)
}

/// Everything schedule-invariant about a simulated execution, hoisted out
/// of the per-point loop: the workflow/cluster/schedule triple plus the
/// derived tables every run re-used to recompute inline — rank positions,
/// per-processor planned queues (over the pristine plan, all tasks
/// unstarted), and per-task estimate tables. Immutable and `Send + Sync`,
/// so one scaffold is shared by all workers replaying a sweep.
#[derive(Debug)]
pub struct SimScaffold {
    wf: Arc<Workflow>,
    cluster: Arc<Cluster>,
    schedule: Arc<Schedule>,
    /// Position of each task in `schedule.rank_order`.
    rank_pos: Vec<usize>,
    /// Per-processor queues of *all* tasks in plan order (planned start,
    /// then rank position; reversed for `pop()` from the back) — the
    /// queue state of a fresh run before any task starts.
    initial_queues: Vec<Vec<TaskId>>,
    /// Estimated work per task (`w_u`, the deviation model's mean).
    est_work: Vec<f64>,
    /// Estimated memory per task (`m_u`).
    est_mem: Vec<f64>,
    /// Total outgoing data per task (`sum of c_{u,v}` over children).
    total_out: Vec<f64>,
    /// CSR partition of each task's in-edges against the initial plan:
    /// inputs produced on the task's own processor ([`in_local`]
    /// slices)...
    ///
    /// [`in_local`]: SimScaffold::in_local
    in_local: Vec<(EdgeId, f64)>,
    in_local_start: Vec<usize>,
    /// ...and inputs produced elsewhere, with their producer
    /// ([`in_remote`](SimScaffold::in_remote) slices).
    in_remote: Vec<(EdgeId, TaskId, f64)>,
    in_remote_start: Vec<usize>,
    /// Per-task total remote input size, summed in in-edge order — the
    /// exact addition sequence of the former per-attempt walk, so the
    /// hoisted sum is bit-identical to the derived one.
    remote_in: Vec<f64>,
    /// CSR out-edges as `(edge, child, size)` triples. Plan-independent:
    /// usable by dirty and clean tasks alike (finish events, recompute
    /// snapshots, ready-counter decrements).
    out_tri: Vec<(EdgeId, TaskId, f64)>,
    out_start: Vec<usize>,
    /// Static in-degrees seeding each run's ready counters.
    in_deg: Vec<u32>,
    /// Algorithm-specific selector state (PEFT's OCT table, DLS's static
    /// levels) built lazily from the scaffold's *estimates* — a pure
    /// function of `(workflow, cluster, algorithm)`, so it is computed at
    /// most once per scaffold and shared by every resumed engine instead
    /// of being rebuilt per recompute trigger. FollowStatic sweeps (and
    /// algorithms without selector state) never pay for it.
    selector: OnceLock<SelectorState>,
}

impl SimScaffold {
    /// Build the scaffold for one `(workflow, cluster, schedule)` triple.
    pub fn new(wf: Arc<Workflow>, cluster: Arc<Cluster>, schedule: Arc<Schedule>) -> SimScaffold {
        let n = wf.num_tasks();
        assert_eq!(schedule.tasks.len(), n, "schedule does not cover this workflow");
        let mut rank_pos = vec![0usize; n];
        for (i, &v) in schedule.rank_order.iter().enumerate() {
            rank_pos[v] = i;
        }
        let mut initial_queues: Vec<Vec<TaskId>> = vec![Vec::new(); cluster.len()];
        for v in 0..n {
            initial_queues[schedule.tasks[v].proc].push(v);
        }
        for q in &mut initial_queues {
            q.sort_by(|&a, &b| {
                schedule.tasks[a]
                    .start
                    .partial_cmp(&schedule.tasks[b].start)
                    .unwrap()
                    .then(rank_pos[a].cmp(&rank_pos[b]))
            });
            q.reverse();
        }
        let est_work = wf.tasks().iter().map(|t| t.work).collect();
        let est_mem = wf.tasks().iter().map(|t| t.memory).collect();
        let total_out = (0..n).map(|v| wf.total_out_data(v)).collect();
        // Local/remote in-edge partition under the initial placements
        // (the overwhelmingly common case at runtime: FollowStatic never
        // deviates from them, Recompute only after a recompute).
        let mut in_local = Vec::new();
        let mut in_local_start = Vec::with_capacity(n + 1);
        let mut in_remote = Vec::new();
        let mut in_remote_start = Vec::with_capacity(n + 1);
        let mut remote_in = vec![0.0f64; n];
        in_local_start.push(0);
        in_remote_start.push(0);
        for v in 0..n {
            let j = schedule.tasks[v].proc;
            for &e in wf.in_edge_ids(v) {
                let edge = wf.edge(e);
                if schedule.tasks[edge.src].proc == j {
                    in_local.push((e, edge.data));
                } else {
                    remote_in[v] += edge.data;
                    in_remote.push((e, edge.src, edge.data));
                }
            }
            in_local_start.push(in_local.len());
            in_remote_start.push(in_remote.len());
        }
        let mut out_tri = Vec::with_capacity(wf.edges().len());
        let mut out_start = Vec::with_capacity(n + 1);
        out_start.push(0);
        for v in 0..n {
            for &e in wf.out_edge_ids(v) {
                let edge = wf.edge(e);
                out_tri.push((e, edge.dst, edge.data));
            }
            out_start.push(out_tri.len());
        }
        let in_deg = (0..n).map(|v| wf.in_degree(v) as u32).collect();
        SimScaffold {
            wf,
            cluster,
            schedule,
            rank_pos,
            initial_queues,
            est_work,
            est_mem,
            total_out,
            in_local,
            in_local_start,
            in_remote,
            in_remote_start,
            remote_in,
            out_tri,
            out_start,
            in_deg,
            selector: OnceLock::new(),
        }
    }

    pub fn workflow(&self) -> &Arc<Workflow> {
        &self.wf
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn schedule(&self) -> &Arc<Schedule> {
        &self.schedule
    }

    /// In-edges of `v` produced on `v`'s initial processor.
    fn in_local(&self, v: TaskId) -> &[(EdgeId, f64)] {
        &self.in_local[self.in_local_start[v]..self.in_local_start[v + 1]]
    }

    /// In-edges of `v` produced elsewhere, as `(edge, producer, size)`.
    fn in_remote(&self, v: TaskId) -> &[(EdgeId, TaskId, f64)] {
        &self.in_remote[self.in_remote_start[v]..self.in_remote_start[v + 1]]
    }

    /// Out-edges of `v` as `(edge, child, size)` (plan-independent).
    fn out_tri(&self, v: TaskId) -> &[(EdgeId, TaskId, f64)] {
        &self.out_tri[self.out_start[v]..self.out_start[v + 1]]
    }

    /// The hoisted selector state for this schedule's algorithm, built on
    /// first use from the scaffold's estimated weights.
    ///
    /// Bit-identity: a resumed engine consults PEFT's OCT rows only for
    /// *unstarted* tasks, and every strict descendant of an unstarted task
    /// is itself unstarted (a task arrives only after all its parents
    /// finished) — so those rows, which depend only on descendant work,
    /// are identical whether built from estimates or from the partially
    /// revealed `known` weights. DLS static levels are defined over the
    /// estimates by contract (see DESIGN.md).
    pub fn selector(&self) -> &SelectorState {
        self.selector.get_or_init(|| {
            SelectorState::build(self.schedule.algorithm, &self.wf, &self.cluster)
        })
    }
}

/// The mutable half of a simulated execution: a reusable arena holding
/// every per-run vector (task states, memory residency, finish times,
/// queues, event heap, scratch buffers). [`SimRun::simulate`] resets the
/// arena in place — after the first run on a given scaffold shape,
/// subsequent points perform no topology/queue allocation (the plan's
/// eviction lists and the rebuilt queues reuse their buffers; only the
/// returned `finish_times` vector and recompute-triggered engine calls
/// allocate).
///
/// One arena serves scaffolds of any size (vectors are resized on
/// reset), which is what lets the service keep a single thread-local
/// `SimRun` per worker across heterogeneous sweeps.
#[derive(Debug, Default)]
pub struct SimRun {
    /// `known` clone source; when the Arc is unchanged the clone is kept
    /// and only its task params are restored (Recompute mode only).
    known_src: Option<Arc<Workflow>>,
    /// Estimates, overwritten with actuals as tasks arrive (what a
    /// recomputation "knows"; maintained only in Recompute mode).
    known: Option<Workflow>,
    /// Current plan; starts as the scaffold's schedule, replaced by
    /// recomputations.
    plan: Vec<TaskSchedule>,
    plan_src: Option<Arc<Schedule>>,
    /// Whether `plan` diverged from `plan_src` (a recompute happened).
    plan_dirty: bool,
    // Runtime state -------------------------------------------------------
    time: f64,
    proc_free: Vec<f64>,
    running: Vec<Option<TaskId>>,
    avail_mem: Vec<f64>,
    avail_buf: Vec<f64>,
    pending: Vec<PendingSet>,
    buffered: Vec<PendingSet>,
    comm_rt: Vec<f64>, // k×k
    state_of: Vec<TState>,
    st_act: Vec<f64>,
    ft_act: Vec<f64>,
    /// Transient memory held by a running task (freed at finish).
    held: Vec<f64>,
    /// Per-processor queues of unstarted tasks in plan order (reversed;
    /// pop from the back).
    queues: Vec<Vec<TaskId>>,
    /// Finish events keyed on `(finish-time bits, task)`; implementation
    /// selectable via [`set_event_queue`](SimRun::set_event_queue).
    events: EventQueue,
    recomputations: usize,
    started: usize,
    /// Guards against recompute→fail→recompute loops per task.
    recompute_tried: Vec<bool>,
    /// Ready counters: remaining unfinished parents per task, seeded
    /// from the scaffold's in-degrees and decremented per out-edge at
    /// finish events; a task is dependency-ready at 0. Replaces the
    /// O(in-degree) all-parents scan per queue-head inspection.
    unfinished: Vec<u32>,
    /// Epoch stamp of the finish event each memory-deferred task is
    /// waiting out: deferred iff `deferred_at[v] == finish_epoch`.
    /// Advancing the epoch (one increment per finish event) un-defers
    /// everything at once — the former `Vec<bool>` wholesale clear cost
    /// O(n) per finish.
    deferred_at: Vec<u64>,
    finish_epoch: u64,
    /// Overlay over the scaffold's hoisted in-edge partitions: true iff
    /// a recompute moved `v` or one of its parents off the initial
    /// placements, invalidating the hoisted split for `v`. All-false at
    /// reset and for the whole of a FollowStatic run.
    part_dirty: Vec<bool>,
    // Scratch buffers (reused across `try_start` calls) ------------------
    scratch_local: Vec<(EdgeId, f64)>,
    scratch_remote: Vec<(EdgeId, TaskId, f64)>,
    scratch_evict: Vec<(EdgeId, f64)>,
    /// Arena backing `recompute`'s engine resume: the platform snapshot,
    /// fixed-placement buffer, and scoring arena circulate between the
    /// run and the engine instead of being rebuilt per trigger.
    resume: ResumeArena,
    /// Parity/bench knob: rebuild the selector state from the scaffold's
    /// estimates on every recompute instead of borrowing the hoisted
    /// copy. Identical outcomes by construction (same inputs); exists so
    /// tests and `bench_recompute` can pin/measure that claim.
    rebuild_selector: bool,
    // Hot-loop contract counters (tests only): every `wf.edge()` touch
    // must be accounted to exactly one declared partition walk.
    #[cfg(test)]
    edge_touches: usize,
    #[cfg(test)]
    walked_in_edges: usize,
}

/// Reusable resources for [`SimRun::recompute`]'s engine resume. `state`
/// and `fixed` are refilled in place from the run's live bookkeeping at
/// each trigger; `buffers` is the engine's scoring arena, handed back by
/// [`Engine::run_into_plan`] after every run.
#[derive(Debug, Default)]
struct ResumeArena {
    state: Option<PlatformState>,
    fixed: Vec<Option<TaskSchedule>>,
    buffers: ScoreBuffers,
}

/// Total-order bits for a non-negative f64 (times are ≥ 0).
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

/// `v.clear() + resize` — reuses the allocation, unlike `vec![val; n]`.
fn reset_vec<T: Clone>(v: &mut Vec<T>, n: usize, val: T) {
    v.clear();
    v.resize(n, val);
}

/// Overwrite `dst` with `src`, reusing both the outer vector and each
/// task's `evicted` buffer when the lengths line up.
fn copy_plan(src: &[TaskSchedule], dst: &mut Vec<TaskSchedule>) {
    if dst.len() == src.len() {
        for (d, s) in dst.iter_mut().zip(src) {
            // Exhaustive destructuring: adding a TaskSchedule field
            // breaks this copy loudly instead of going stale on reset.
            let TaskSchedule { proc, start, finish, evicted, res_nonneg } = s;
            d.proc = *proc;
            d.start = *start;
            d.finish = *finish;
            d.res_nonneg = *res_nonneg;
            d.evicted.clone_from(evicted);
        }
    } else {
        dst.clear();
        dst.extend(src.iter().cloned());
    }
}

impl SimRun {
    /// An empty arena; sized lazily by the first [`simulate`](SimRun::simulate).
    pub fn new() -> SimRun {
        SimRun::default()
    }

    /// Select the event-queue implementation for subsequent runs. Both
    /// variants pop in the same total order ([`events`]), so outcomes
    /// are bit-identical either way; the heap default wins at replay's
    /// frontier size (at most one event per processor) and this knob
    /// exists so `bench_replay` can measure the alternative.
    pub fn set_event_queue(&mut self, kind: EventQueueKind) {
        if self.events.kind() != kind {
            self.events = EventQueue::new(kind);
        }
    }

    pub fn event_queue_kind(&self) -> EventQueueKind {
        self.events.kind()
    }

    /// Rebuild the selector state per recompute trigger instead of
    /// borrowing the scaffold's hoisted copy (see the field doc).
    pub fn set_rebuild_selector(&mut self, rebuild: bool) {
        self.rebuild_selector = rebuild;
    }

    /// Execute one replay point of `sc` under `cfg`, resetting the arena
    /// in place first. Bit-identical to the [`simulate`] shim for the
    /// same inputs, whatever ran in this arena before.
    pub fn simulate(&mut self, sc: &SimScaffold, cfg: &SimConfig) -> SimOutcome {
        self.simulate_with(sc, cfg, None)
    }

    /// [`simulate`](SimRun::simulate) with an optional [`ScorePool`]
    /// accelerating the scoring loops of any recompute-triggered engine
    /// resumes. The pooled reduction is deterministic (min finish, ties
    /// to the lowest processor id — see [`Engine::with_parallel_scoring`]),
    /// so outcomes are bit-identical for any pool size, including `None`.
    pub fn simulate_with(
        &mut self,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> SimOutcome {
        self.reset(sc, cfg);
        let (completed, failure) = self.exec(sc, cfg, pool);
        self.outcome(completed, failure, true)
    }

    /// [`simulate`](SimRun::simulate) without materializing the per-task
    /// finish times: `finish_times` comes back **empty** (every other
    /// field is bit-identical). For hot replay loops — the service's
    /// sweep path — that only consume the summary fields, this skips an
    /// O(n) clone per point.
    pub fn simulate_summary(&mut self, sc: &SimScaffold, cfg: &SimConfig) -> SimOutcome {
        self.simulate_summary_with(sc, cfg, None)
    }

    /// [`simulate_summary`](SimRun::simulate_summary) with an optional
    /// [`ScorePool`] for recompute-triggered engine resumes (see
    /// [`simulate_with`](SimRun::simulate_with)).
    pub fn simulate_summary_with(
        &mut self,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> SimOutcome {
        self.reset(sc, cfg);
        let (completed, failure) = self.exec(sc, cfg, pool);
        self.outcome(completed, failure, false)
    }

    /// Reinitialize every piece of run state from the scaffold. Total:
    /// nothing observable survives from the previous point (the arena
    /// only carries allocations across).
    fn reset(&mut self, sc: &SimScaffold, cfg: &SimConfig) {
        let n = sc.wf.num_tasks();
        let k = sc.cluster.len();
        self.time = 0.0;
        self.recomputations = 0;
        self.started = 0;
        reset_vec(&mut self.proc_free, k, 0.0);
        reset_vec(&mut self.running, k, None);
        self.avail_mem.clear();
        self.avail_mem.extend(sc.cluster.processors.iter().map(|p| p.memory));
        self.avail_buf.clear();
        self.avail_buf.extend(sc.cluster.processors.iter().map(|p| p.comm_buffer));
        for p in &mut self.pending {
            p.clear();
        }
        self.pending.resize_with(k, PendingSet::default);
        for p in &mut self.buffered {
            p.clear();
        }
        self.buffered.resize_with(k, PendingSet::default);
        reset_vec(&mut self.comm_rt, k * k, 0.0);
        reset_vec(&mut self.state_of, n, TState::NotStarted);
        reset_vec(&mut self.st_act, n, NEVER_STARTED);
        reset_vec(&mut self.ft_act, n, NEVER_STARTED);
        reset_vec(&mut self.held, n, 0.0);
        reset_vec(&mut self.recompute_tried, n, false);
        // Ready counters restart from the static in-degrees; `u64::MAX`
        // never equals a restarting epoch (≤ n finish events per run).
        self.unfinished.clear();
        self.unfinished.extend_from_slice(&sc.in_deg);
        reset_vec(&mut self.deferred_at, n, u64::MAX);
        self.finish_epoch = 0;
        // Partitions start clean: the plan is restored to the scaffold's
        // schedule below whenever the previous point dirtied it, so a
        // FollowStatic point following a Recompute point on this arena
        // sees pristine hoisted partitions.
        reset_vec(&mut self.part_dirty, n, false);
        self.events.reset(sc.schedule.makespan);
        #[cfg(test)]
        {
            self.edge_touches = 0;
            self.walked_in_edges = 0;
        }
        // Queues restart from the scaffold's pristine planned queues;
        // `clone_from` reuses each queue's buffer.
        self.queues.resize_with(k, Vec::new);
        for (q, init) in self.queues.iter_mut().zip(&sc.initial_queues) {
            q.clone_from(init);
        }
        // The plan needs restoring only when the schedule changed or the
        // previous point's recomputations overwrote it.
        let same_schedule = self.plan_src.as_ref().is_some_and(|s| Arc::ptr_eq(s, &sc.schedule));
        if !same_schedule || self.plan_dirty {
            copy_plan(&sc.schedule.tasks, &mut self.plan);
            self.plan_src = Some(sc.schedule.clone());
            self.plan_dirty = false;
        }
        // `known` is only consulted by recomputations; FollowStatic runs
        // skip the workflow clone entirely.
        if cfg.mode == SimMode::Recompute {
            let same_wf = self.known_src.as_ref().is_some_and(|s| Arc::ptr_eq(s, &sc.wf));
            if same_wf {
                let known = self.known.as_mut().expect("known_src set together with known");
                for v in 0..n {
                    let t = sc.wf.task(v);
                    known.set_task_params(v, t.work, t.memory);
                }
            } else {
                self.known = Some(sc.wf.as_ref().clone());
                self.known_src = Some(sc.wf.clone());
            }
        }
    }

    /// Rebuild per-processor queues of unstarted tasks in plan order
    /// (planned start, then rank position; stored reversed for pop()).
    fn rebuild_queues(&mut self, sc: &SimScaffold) {
        let SimRun { queues, plan, state_of, .. } = self;
        for q in queues.iter_mut() {
            q.clear();
        }
        for v in 0..plan.len() {
            if state_of[v] == TState::NotStarted {
                queues[plan[v].proc].push(v);
            }
        }
        for q in queues.iter_mut() {
            q.sort_by(|&a, &b| {
                plan[a]
                    .start
                    .partial_cmp(&plan[b].start)
                    .unwrap()
                    .then(sc.rank_pos[a].cmp(&sc.rank_pos[b]))
            });
            q.reverse();
        }
    }

    /// Attempt to start task `v` on its planned processor. Returns:
    /// - `Ok(true)`  — started;
    /// - `Ok(false)` — recomputation happened instead (Recompute mode);
    /// - `Err(f)`    — execution failed.
    fn try_start(
        &mut self,
        v: TaskId,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> Result<bool, SimFailure> {
        let j = self.plan[v].proc;
        // Reveal actual parameters (the task "arrives in the system").
        let (est_work, est_mem) = (sc.est_work[v], sc.est_mem[v]);
        let (w_act, m_act) = cfg.deviation.actual(v, est_work, est_mem);
        if cfg.mode == SimMode::Recompute {
            self.known.as_mut().unwrap().set_task_params(v, w_act, m_act);
        }

        // Local/remote partition of v's in-edges. Clean tasks — always,
        // in FollowStatic mode — read the scaffold's hoisted slices and
        // precomputed remote sum; dirty tasks (placements moved by a
        // recompute) re-derive the partition with ONE walk into the
        // scratch buffers, which the arrival and producer-free phases
        // below reuse. Either way nothing in this function touches
        // `wf.edge()` more than once per in-edge. (The scratch buffers
        // are moved out and restored on every exit path.)
        let dirty = self.part_dirty[v];
        let mut local_buf = std::mem::take(&mut self.scratch_local);
        let mut remote_buf = std::mem::take(&mut self.scratch_remote);
        let remote_in: f64;
        if dirty {
            #[cfg(test)]
            {
                self.walked_in_edges += sc.wf.in_degree(v);
            }
            local_buf.clear();
            remote_buf.clear();
            let mut sum = 0.0f64;
            for &e in sc.wf.in_edge_ids(v) {
                #[cfg(test)]
                {
                    self.edge_touches += 1;
                }
                let edge = sc.wf.edge(e);
                if self.plan[edge.src].proc == j {
                    local_buf.push((e, edge.data));
                } else {
                    sum += edge.data;
                    remote_buf.push((e, edge.src, edge.data));
                }
            }
            remote_in = sum;
        } else {
            remote_in = sc.remote_in[v];
        }
        let local: &[(EdgeId, f64)] = if dirty { &local_buf } else { sc.in_local(v) };
        let remote: &[(EdgeId, TaskId, f64)] = if dirty { &remote_buf } else { sc.in_remote(v) };
        let out = sc.total_out[v];

        // Planned evictions first (skip files already gone).
        let mut evict = std::mem::take(&mut self.scratch_evict);
        evict.clear();
        let mut buf_left = self.avail_buf[j];
        let mut mem_gain = 0.0f64;
        // `Some(true)` = buffer overflow on a planned eviction,
        // `Some(false)` = not enough memory.
        let mut problem: Option<bool> = None;
        for idx in 0..self.plan[v].evicted.len() {
            let e = self.plan[v].evicted[idx];
            if let Some(size) = self.pending[j].get(e) {
                if size > buf_left {
                    problem = Some(true);
                    break;
                }
                buf_left -= size;
                mem_gain += size;
                evict.push((e, size));
            }
        }
        if problem.is_none() {
            let mut res = self.avail_mem[j] + mem_gain - m_act - remote_in - out;
            if res < 0.0 && cfg.mode == SimMode::Recompute {
                // Additional greedy evictions (the scheduler would have
                // planned these, had it known the actual memory).
                for (e, size) in self.pending[j].candidates(sc.schedule.policy) {
                    if res >= 0.0 {
                        break;
                    }
                    if local.iter().any(|&(le, _)| le == e)
                        || evict.iter().any(|&(ee, _)| ee == e)
                        || size > buf_left
                    {
                        continue;
                    }
                    buf_left -= size;
                    res += size;
                    evict.push((e, size));
                }
            }
            if res < 0.0 {
                problem = Some(false);
            }
        }
        if let Some(buffer) = problem {
            self.scratch_local = local_buf;
            self.scratch_remote = remote_buf;
            self.scratch_evict = evict;
            return self.memory_problem(v, j, buffer, sc, cfg, pool);
        }

        // Commit the start. -------------------------------------------------
        for &(e, size) in &evict {
            self.pending[j].remove(e);
            self.avail_mem[j] += size;
            self.buffered[j].insert(e, size);
            self.avail_buf[j] -= size;
        }
        // Remote inputs arrive, advancing channel ready times (mirrors
        // the scheduler's bookkeeping).
        let k = self.queues.len();
        let mut arrival = 0.0f64;
        for &(_, src, data) in remote {
            let pu = self.plan[src].proc;
            debug_assert_ne!(pu, j, "remote partition entry on the consumer's processor");
            let channel = self.comm_rt[pu * k + j].max(self.ft_act[src]);
            let t = channel + data / sc.cluster.bandwidth;
            self.comm_rt[pu * k + j] = t;
            arrival = arrival.max(t);
        }
        let st = self.proc_free[j].max(arrival).max(self.time);
        let dur = sc.cluster.exec_time(w_act, j);
        // Producer-side frees for the same remote inputs (files are sent
        // now) — reusing the partition; this used to be a third
        // `in_edge_ids` walk re-deriving each producer's placement.
        for &(e, src, _) in remote {
            let pu = self.plan[src].proc;
            let freed = if let Some(size) = self.pending[pu].remove(e) {
                self.avail_mem[pu] += size;
                true
            } else if let Some(size) = self.buffered[pu].remove(e) {
                self.avail_buf[pu] += size;
                false
            } else {
                false
            };
            if freed && obs::enabled() {
                obs::record(obs::Event::MemLevel {
                    proc: pu as u32,
                    t: self.time,
                    used: sc.cluster.processors[pu].memory - self.avail_mem[pu],
                });
            }
        }
        self.avail_mem[j] -= m_act + remote_in + out;
        self.held[v] = m_act + remote_in;
        self.st_act[v] = st;
        self.ft_act[v] = st + dur;
        self.state_of[v] = TState::Running;
        self.running[j] = Some(v);
        self.proc_free[j] = st + dur;
        self.started += 1;
        self.events.push(time_key(st + dur), v);
        self.scratch_local = local_buf;
        self.scratch_remote = remote_buf;
        self.scratch_evict = evict;
        if obs::enabled() {
            obs::record(obs::Event::TaskStart { task: v as u32, proc: j as u32, t: st, dur });
            obs::record(obs::Event::MemLevel {
                proc: j as u32,
                t: st,
                used: sc.cluster.processors[j].memory - self.avail_mem[j],
            });
        }

        // Significant execution-time/memory deviation → warn the scheduler.
        if cfg.mode == SimMode::Recompute {
            let rel = (w_act - est_work).abs() / est_work.max(1e-12);
            let mel = (m_act - est_mem).abs() / est_mem.max(1e-12);
            if rel > cfg.recompute_threshold || mel > cfg.recompute_threshold {
                self.recompute(sc, pool);
            }
        }
        Ok(true)
    }

    /// Handle a memory violation at `v`'s start.
    ///
    /// In Recompute mode the scheduler is warned first (one recomputation
    /// per attempt). In both modes, if other tasks are still running the
    /// start is *deferred* — their completion returns transients and ships
    /// pending files, which is also how the static bookkeeping (freeing at
    /// assignment, §IV-B) and the execution (freeing at runtime) reconcile.
    /// Only when no progress is possible is the execution declared invalid
    /// (§VI-A-3: "not enough memory").
    fn memory_problem(
        &mut self,
        v: TaskId,
        j: ProcId,
        buffer: bool,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> Result<bool, SimFailure> {
        if cfg.mode == SimMode::Recompute && !self.recompute_tried[v] {
            self.recompute_tried[v] = true;
            self.recompute(sc, pool);
            return Ok(false);
        }
        if !self.events.is_empty() {
            // Tasks are still running: waiting may free memory. Defer v
            // until the next finish event — stamping the current epoch;
            // the epoch bump at that event wakes it. (`recompute_tried`
            // stays set: one recomputation per memory issue — repeated
            // recomputes per retry would cost O(n·k) each for no new
            // information.)
            self.deferred_at[v] = self.finish_epoch;
            self.rebuild_queues(sc); // restore v (it was pre-popped)
            return Ok(false);
        }
        Err(if buffer {
            SimFailure::BufferOverflow { task: v, proc: j }
        } else {
            SimFailure::OutOfMemory { task: v, proc: j }
        })
    }

    /// Recompute the placements of all unstarted tasks from the current
    /// platform state (paper §V).
    ///
    /// The adaptive fast path: the platform snapshot, the fixed-placement
    /// buffer, and the engine's scoring arena come out of [`ResumeArena`]
    /// and are refilled in place (no per-trigger clones of the pending/
    /// buffered sets beyond `clone_from`'s reuse); the selector state is
    /// borrowed from the scaffold; the scoring loop optionally fans out
    /// over `pool`. All of it is bit-identical to the naive rebuild.
    fn recompute(&mut self, sc: &SimScaffold, pool: Option<&ScorePool>) {
        let _span = obs::span(obs::SpanKind::Recompute);
        let k = self.queues.len();
        let n = self.plan.len();
        // Snapshot the platform into the arena-backed state.
        let mut state = match self.resume.state.take() {
            Some(mut st) => {
                st.reset(&sc.cluster);
                st
            }
            None => PlatformState::new(&sc.cluster),
        };
        for j in 0..k {
            let ps = &mut state.procs[j];
            ps.ready_time = self.proc_free[j].max(self.time);
            ps.avail_mem = self.avail_mem[j];
            ps.avail_buf = self.avail_buf[j];
            ps.pending.clone_from_set(&self.pending[j]);
            ps.buffered.clone_from_set(&self.buffered[j]);
            // Outputs of running tasks are already reserved in avail_mem
            // but not yet in the pending set; pre-insert them so Step 1
            // sees them when placing their children.
            if let Some(r) = self.running[j] {
                for &(e, _, data) in sc.out_tri(r) {
                    ps.pending.insert(e, data);
                }
            }
            for to in 0..k {
                let dt = self.comm_rt[j * k + to];
                if dt > 0.0 {
                    state.push_comm(j, to, dt);
                }
            }
        }
        // Fixed placements: everything started keeps its actual times.
        // Refill the arena buffer in place, reusing each slot's eviction
        // list; track the earliest rank position among unstarted tasks so
        // the engine can skip straight past the fixed prefix.
        let mut fixed = std::mem::take(&mut self.resume.fixed);
        fixed.resize(n, None);
        let mut first_unfixed = n;
        for v in 0..n {
            if self.state_of[v] == TState::NotStarted {
                fixed[v] = None;
                first_unfixed = first_unfixed.min(sc.rank_pos[v]);
            } else {
                let src = &self.plan[v];
                match &mut fixed[v] {
                    Some(d) => {
                        d.proc = src.proc;
                        d.start = self.st_act[v];
                        d.finish = self.ft_act[v];
                        d.res_nonneg = src.res_nonneg;
                        d.evicted.clone_from(&src.evicted);
                    }
                    slot => {
                        *slot = Some(TaskSchedule {
                            proc: src.proc,
                            start: self.st_act[v],
                            finish: self.ft_act[v],
                            evicted: src.evicted.clone(),
                            res_nonneg: src.res_nonneg,
                        });
                    }
                }
            }
        }
        let rebuilt;
        let selector: &SelectorState = if self.rebuild_selector {
            rebuilt = SelectorState::build(sc.schedule.algorithm, &sc.wf, &sc.cluster);
            &rebuilt
        } else {
            sc.selector()
        };
        let buffers = std::mem::take(&mut self.resume.buffers);
        let mut engine = Engine::resume_with(
            self.known.as_ref().expect("Recompute mode maintains `known`"),
            sc.cluster.as_ref(),
            sc.schedule.algorithm,
            sc.schedule.policy,
            state,
            fixed,
            buffers,
        )
        .with_selector_state(selector)
        .with_fixed_prefix(first_unfixed);
        if let Some(pool) = pool {
            engine = engine.with_parallel_scoring(pool);
        }
        let parts = engine.run_into_plan(&sc.schedule.rank_order, &mut self.plan);
        self.resume.state = Some(parts.state);
        self.resume.fixed = parts.fixed;
        self.resume.buffers = parts.buffers;
        self.plan_dirty = true;
        self.rebuild_queues(sc);
        self.refresh_partition_overlay(sc);
        self.recomputations += 1;
        if obs::enabled() {
            obs::record(obs::Event::RecomputeTriggered { t: self.time });
        }
    }

    /// Recompute the dirty overlay over the scaffold's hoisted in-edge
    /// partitions: task `v` is dirty iff its own placement or any
    /// parent's differs from the *initial* plan the scaffold partitioned
    /// against. Exact, not cumulative — a later recompute that moves a
    /// task back to its initial processor cleans it again. O(n + m),
    /// negligible next to the engine re-run that precedes it.
    fn refresh_partition_overlay(&mut self, sc: &SimScaffold) {
        let init = &sc.schedule.tasks;
        for v in 0..self.plan.len() {
            self.part_dirty[v] = self.plan[v].proc != init[v].proc;
        }
        for u in 0..self.plan.len() {
            if self.plan[u].proc != init[u].proc {
                for &(_, child, _) in sc.out_tri(u) {
                    self.part_dirty[child] = true;
                }
            }
        }
    }

    /// Sweep all idle processors; start whatever is startable.
    fn try_starts(
        &mut self,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> Result<(), SimFailure> {
        let k = self.queues.len();
        let mut progress = true;
        while progress {
            progress = false;
            for j in 0..k {
                if self.running[j].is_some() {
                    continue;
                }
                // Drop queue entries whose placement moved (recompute).
                while let Some(&v) = self.queues[j].last() {
                    if self.state_of[v] != TState::NotStarted || self.plan[v].proc != j {
                        self.queues[j].pop();
                    } else {
                        break;
                    }
                }
                let Some(&v) = self.queues[j].last() else { continue };
                if self.unfinished[v] != 0 {
                    continue; // predecessor not finished: wait
                }
                if self.deferred_at[v] == self.finish_epoch {
                    continue; // waiting for memory until the next event
                }
                // Pop before attempting: any recompute inside try_start
                // rebuilds the queues from scratch (and re-inserts v if it
                // did not start), so the stale entry must be gone first.
                self.queues[j].pop();
                match self.try_start(v, sc, cfg, pool)? {
                    true => {
                        progress = true;
                    }
                    false => {
                        // Recompute happened; rescan all processors.
                        progress = true;
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    fn finish_task(&mut self, v: TaskId, sc: &SimScaffold) {
        let j = self.plan[v].proc;
        debug_assert_eq!(self.running[j], Some(v));
        self.running[j] = None;
        self.state_of[v] = TState::Done;
        // Free the transient (task memory + remote inputs).
        self.avail_mem[j] += self.held[v];
        // Local inputs leave the pending set — via the hoisted partition
        // while the placements still match the initial plan, one walk
        // otherwise.
        if !self.part_dirty[v] {
            for &(e, _) in sc.in_local(v) {
                if let Some(size) = self.pending[j].remove(e) {
                    self.avail_mem[j] += size;
                }
            }
        } else {
            #[cfg(test)]
            {
                self.walked_in_edges += sc.wf.in_degree(v);
            }
            for &e in sc.wf.in_edge_ids(v) {
                #[cfg(test)]
                {
                    self.edge_touches += 1;
                }
                let edge = sc.wf.edge(e);
                if self.plan[edge.src].proc == j {
                    if let Some(size) = self.pending[j].remove(e) {
                        self.avail_mem[j] += size;
                    }
                }
            }
        }
        // Outputs become pending files (space already reserved at
        // start), and each child's ready counter ticks down — the
        // O(out-degree) share of the ready-counter scheme.
        for &(e, child, data) in sc.out_tri(v) {
            self.pending[j].insert(e, data);
            self.unfinished[child] -= 1;
        }
        if obs::enabled() {
            obs::record(obs::Event::TaskFinish { task: v as u32, proc: j as u32, t: self.time });
            obs::record(obs::Event::MemLevel {
                proc: j as u32,
                t: self.time,
                used: sc.cluster.processors[j].memory - self.avail_mem[j],
            });
        }
    }

    fn exec(
        &mut self,
        sc: &SimScaffold,
        cfg: &SimConfig,
        pool: Option<&ScorePool>,
    ) -> (bool, Option<SimFailure>) {
        let n = sc.wf.num_tasks();
        let mut done = 0usize;
        loop {
            if let Err(f) = self.try_starts(sc, cfg, pool) {
                return (false, Some(f));
            }
            let Some((tk, v)) = self.events.pop() else {
                break;
            };
            self.time = f64::from_bits(tk);
            self.finish_task(v, sc);
            // Freed memory: the epoch bump wakes every deferred task in
            // O(1) (deferral is `deferred_at[v] == finish_epoch`).
            self.finish_epoch += 1;
            done += 1;
            if done == n {
                break;
            }
        }
        (done == n, None)
    }

    fn outcome(
        &self,
        completed: bool,
        failure: Option<SimFailure>,
        with_finish_times: bool,
    ) -> SimOutcome {
        let makespan = self.ft_act.iter().copied().filter(|&f| f >= 0.0).fold(0.0, f64::max);
        SimOutcome {
            completed,
            makespan,
            failure,
            recomputations: self.recomputations,
            started: self.started,
            finish_times: if with_finish_times { self.ft_act.clone() } else { Vec::new() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};

    fn sample(samples: usize, seed: u64) -> (Workflow, Cluster) {
        let model = crate::generator::models::chipseq();
        let wf = crate::generator::expand(&model, samples).unwrap();
        let data = crate::traces::HistoricalData::synthesize(
            &crate::traces::task_types(&wf),
            &crate::traces::TraceConfig::default(),
            seed,
        );
        (crate::traces::bind_weights(&wf, &data, 2), small_cluster())
    }

    #[test]
    fn zero_deviation_follows_schedule() {
        let (wf, cluster) = sample(6, 1);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::none(1));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        assert_eq!(out.recomputations, 0);
        assert_eq!(out.started, wf.num_tasks());
        // Runtime makespan tracks the planned one closely (identical
        // parameters; only comm bookkeeping order differs).
        let rel = (out.makespan - s.makespan).abs() / s.makespan;
        assert!(rel < 0.05, "plan {} vs sim {}", s.makespan, out.makespan);
    }

    #[test]
    fn deviations_change_makespan_deterministically() {
        let (wf, cluster) = sample(6, 2);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(0.1, 7));
        let a = simulate(&wf, &cluster, &s, &cfg);
        let b = simulate(&wf, &cluster, &s, &cfg);
        if a.completed {
            assert_eq!(a.makespan, b.makespan);
            assert_ne!(a.makespan, 0.0);
        }
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn recompute_mode_no_worse_than_static() {
        // Constrained memories: upward deviations break static schedules.
        let (wf, cluster) = sample(10, 3);
        let tight = cluster.scale_memory(0.12, "tight");
        let s = ScheduleRequest::new(&wf, &tight).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
        if !s.valid {
            return; // instance unschedulable even statically; not this test
        }
        let dev = DeviationModel::new(0.1, 11);
        let stat = simulate(&wf, &tight, &s, &SimConfig::new(SimMode::FollowStatic, dev));
        let dynr = simulate(&wf, &tight, &s, &SimConfig::new(SimMode::Recompute, dev));
        assert!(dynr.completed || !stat.completed);
    }

    #[test]
    fn recompute_triggered_by_large_deviation() {
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        // 30% sigma guarantees many tasks cross the 10% threshold.
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        assert!(out.recomputations > 0);
    }

    #[test]
    fn finish_times_respect_dependencies() {
        let (wf, cluster) = sample(5, 6);
        let s =
            ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBlc).policy(EvictionPolicy::LargestFirst).run();
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.1, 13));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        for e in wf.edges() {
            assert!(
                out.finish_times[e.dst] > out.finish_times[e.src] - 1e-9,
                "child finished before parent"
            );
        }
    }

    #[test]
    fn all_algorithms_simulate_cleanly_small() {
        let (wf, cluster) = sample(4, 8);
        for &algo in Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            for mode in [SimMode::FollowStatic, SimMode::Recompute] {
                let cfg = SimConfig::new(mode, DeviationModel::new(0.05, 21));
                let out = simulate(&wf, &cluster, &s, &cfg);
                // Memory-aware schedules must survive in recompute mode;
                // HEFT (memory-oblivious) may legitimately die at runtime
                // — that is the paper's core observation.
                if algo.memory_aware() && s.valid && mode == SimMode::Recompute {
                    assert!(out.completed, "{algo:?} {mode:?}: {:?}", out.failure);
                }
                // Either way the simulation must terminate cleanly with a
                // coherent outcome.
                assert!(out.completed || out.failure.is_some(), "{algo:?} {mode:?} stalled");
            }
        }
    }

    fn outcomes_bit_equal(a: &SimOutcome, b: &SimOutcome) {
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.failure, b.failure);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.recomputations, b.recomputations);
        assert_eq!(a.started, b.started);
        assert_eq!(
            a.finish_times.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            b.finish_times.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn scaffold_run_matches_simulate_shim_bit_exactly() {
        // One scaffold + one reused arena across points vs the per-point
        // shim, across both modes and several sigmas/seeds — the parity
        // contract the replay engine is built on.
        let (wf, cluster) = sample(8, 9);
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            let scaffold = SimScaffold::new(
                Arc::new(wf.clone()),
                Arc::new(cluster.clone()),
                Arc::new(s.clone()),
            );
            let mut run = SimRun::new();
            for mode in [SimMode::Recompute, SimMode::FollowStatic] {
                for sigma in [0.0, 0.1, 0.3] {
                    for seed in [5, 7] {
                        let cfg = SimConfig::new(mode, DeviationModel::new(sigma, seed));
                        let fresh = simulate(&wf, &cluster, &s, &cfg);
                        let reused = run.simulate(&scaffold, &cfg);
                        outcomes_bit_equal(&fresh, &reused);
                        // The summary variant (the service's hot path)
                        // matches on everything but the elided vector.
                        let summary = run.simulate_summary(&scaffold, &cfg);
                        assert_eq!(summary.completed, fresh.completed);
                        assert_eq!(summary.failure, fresh.failure);
                        assert_eq!(summary.makespan.to_bits(), fresh.makespan.to_bits());
                        assert_eq!(summary.recomputations, fresh.recomputations);
                        assert_eq!(summary.started, fresh.started);
                        assert!(summary.finish_times.is_empty());
                    }
                }
            }
        }
    }

    #[test]
    fn arena_reset_reuses_allocations() {
        // The `recompute_triggered_by_large_deviation` instance: valid,
        // and sigma 0.3 reliably dirties the plan mid-run.
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold =
            SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(s));
        // A sigma large enough to trigger recomputations, so the reset
        // path that restores a dirtied plan is exercised too.
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let mut run = SimRun::new();
        let first = run.simulate(&scaffold, &cfg);
        assert!(first.recomputations > 0, "test wants the plan dirtied");
        let fingerprint = |r: &SimRun| {
            (
                r.state_of.as_ptr() as usize,
                r.st_act.as_ptr() as usize,
                r.ft_act.as_ptr() as usize,
                r.held.as_ptr() as usize,
                r.comm_rt.as_ptr() as usize,
                r.queues.as_ptr() as usize,
                r.pending.as_ptr() as usize,
                r.queues.iter().map(|q| q.as_ptr() as usize).collect::<Vec<_>>(),
            )
        };
        let before = fingerprint(&run);
        let second = run.simulate(&scaffold, &cfg);
        outcomes_bit_equal(&first, &second);
        // Same backing buffers: the reset reused every arena allocation
        // (queue buffers included) instead of reallocating per point.
        assert_eq!(before, fingerprint(&run));
    }

    #[test]
    fn arena_adapts_across_scaffolds() {
        // One thread-local arena must serve heterogeneous sweeps:
        // different workflows, clusters, and schedules back to back.
        let (wf_a, cluster_a) = sample(8, 1);
        let (wf_b, cluster_b) = sample(4, 2);
        let s_a = ScheduleRequest::new(&wf_a, &cluster_a).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let s_b = ScheduleRequest::new(&wf_b, &cluster_b).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
        let sc_a = SimScaffold::new(
            Arc::new(wf_a.clone()),
            Arc::new(cluster_a.clone()),
            Arc::new(s_a.clone()),
        );
        let sc_b = SimScaffold::new(
            Arc::new(wf_b.clone()),
            Arc::new(cluster_b.clone()),
            Arc::new(s_b.clone()),
        );
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.2, 3));
        let mut run = SimRun::new();
        for _ in 0..2 {
            outcomes_bit_equal(&run.simulate(&sc_a, &cfg), &simulate(&wf_a, &cluster_a, &s_a, &cfg));
            outcomes_bit_equal(&run.simulate(&sc_b, &cfg), &simulate(&wf_b, &cluster_b, &s_b, &cfg));
        }
    }

    #[test]
    fn never_started_sentinel_keeps_equality_well_behaved() {
        // An instance that cannot start at all: task memory far beyond
        // every processor. The outcome's finish_times must carry the
        // documented sentinel (not NaN), so Vec equality — what parity
        // tests rely on — holds.
        let mut b = crate::workflow::WorkflowBuilder::new("oom");
        let a = b.task("a", "t", 1.0, 1e30);
        let c = b.task("c", "t", 1.0, 1e30);
        b.edge(a, c, 1.0);
        let wf = b.build().unwrap();
        let cluster = small_cluster();
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(!s.valid);
        let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::none(1));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(!out.completed);
        assert!(out.failure.is_some());
        assert_eq!(out.started, 0);
        assert!(out.finish_times.iter().all(|&f| f == NEVER_STARTED));
        assert_eq!(out.finish_time(0), None);
        // The point of the sentinel: `==` is usable (NaN != NaN broke it).
        let again = simulate(&wf, &cluster, &s, &cfg);
        assert_eq!(out.finish_times, again.finish_times);
        // Completed tasks report a real time through the accessor (the
        // `zero_deviation_follows_schedule` instance, known valid).
        let (wf2, cluster2) = sample(6, 1);
        let s2 = ScheduleRequest::new(&wf2, &cluster2).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s2.valid);
        let done = simulate(&wf2, &cluster2, &s2, &SimConfig::new(SimMode::FollowStatic, DeviationModel::none(1)));
        assert!(done.completed);
        assert!((0..wf2.num_tasks()).all(|v| done.finish_time(v).is_some()));
    }

    #[test]
    fn scaffold_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SimScaffold>();
        assert_send_sync::<SimRun>();
    }

    #[test]
    fn followstatic_hot_loop_never_touches_wf_edge() {
        // The fast-path contract: a FollowStatic point runs entirely on
        // the scaffold's hoisted partitions — zero `wf.edge()` touches
        // in the start/finish hot loop.
        let (wf, cluster) = sample(8, 9);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let scaffold = SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(s));
        let mut run = SimRun::new();
        for sigma in [0.0, 0.1, 0.3] {
            let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(sigma, 5));
            let out = run.simulate(&scaffold, &cfg);
            assert!(out.completed || out.failure.is_some());
            assert_eq!(run.edge_touches, 0, "sigma {sigma}: hot loop touched wf.edge()");
        }
    }

    #[test]
    fn dirty_path_walks_each_in_edge_once() {
        // Pin against re-derivation: after a recompute, a dirty task's
        // partition comes from exactly ONE in-edge walk per start (and
        // one per finish) — the arrival and producer-free phases reuse
        // it. Every `wf.edge()` touch must be accounted to a declared
        // walk; a second derivation site breaks the equality.
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold = SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(s));
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let mut run = SimRun::new();
        let out = run.simulate(&scaffold, &cfg);
        assert!(out.recomputations > 0, "test wants the overlay exercised");
        assert_eq!(run.edge_touches, run.walked_in_edges);
    }

    #[test]
    fn followstatic_point_after_recompute_point_sees_clean_partitions() {
        // The overlay edge case: a Recompute point dirties the plan (and
        // with it the partition overlay); the next FollowStatic point on
        // the SAME scaffold and arena must observe pristine hoisted
        // partitions — zero edge touches and bit-parity with a fresh
        // run.
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold = SimScaffold::new(
            Arc::new(wf.clone()),
            Arc::new(cluster.clone()),
            Arc::new(s.clone()),
        );
        let mut run = SimRun::new();
        let dirtying = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let first = run.simulate(&scaffold, &dirtying);
        assert!(first.recomputations > 0, "test wants the overlay dirtied");
        for sigma in [0.0, 0.1] {
            let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(sigma, 7));
            let reused = run.simulate(&scaffold, &cfg);
            assert_eq!(run.edge_touches, 0, "stale overlay leaked into the FollowStatic point");
            outcomes_bit_equal(&reused, &simulate(&wf, &cluster, &s, &cfg));
        }
        // And a Recompute point after a Recompute point resets cleanly
        // too (the overlay is per-point state, not per-arena).
        outcomes_bit_equal(&run.simulate(&scaffold, &dirtying), &first);
    }

    #[test]
    fn pooled_recompute_matches_serial_bit_exactly() {
        // The tentpole determinism contract: threading a ScorePool into
        // the recompute-triggered engine resumes changes wall-clock, not
        // outcomes — bit-identical for any pool size, across algorithms
        // and sigmas.
        let (wf, cluster) = sample(6, 4);
        let pools = [ScorePool::new(2), ScorePool::new(4)];
        for &algo in crate::scheduler::Algorithm::all() {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if !s.valid {
                continue;
            }
            let scaffold = SimScaffold::new(
                Arc::new(wf.clone()),
                Arc::new(cluster.clone()),
                Arc::new(s),
            );
            let mut serial = SimRun::new();
            let mut pooled = SimRun::new();
            for sigma in [0.1, 0.3] {
                let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(sigma, 5));
                let base = serial.simulate(&scaffold, &cfg);
                for pool in &pools {
                    let out = pooled.simulate_with(&scaffold, &cfg, Some(pool));
                    outcomes_bit_equal(&base, &out);
                }
            }
        }
    }

    #[test]
    fn hoisted_selector_matches_per_trigger_rebuild() {
        // Borrowing the scaffold's hoisted selector state (PEFT's OCT
        // table, DLS's static levels) must be indistinguishable from
        // rebuilding it on every recompute trigger — both are pure
        // functions of the scaffold's estimates.
        let (wf, cluster) = sample(6, 4);
        for algo in [Algorithm::Peft, Algorithm::Dls, Algorithm::Lookahead, Algorithm::HeftmBl] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            if !s.valid {
                continue;
            }
            let scaffold = SimScaffold::new(
                Arc::new(wf.clone()),
                Arc::new(cluster.clone()),
                Arc::new(s),
            );
            let mut hoisted = SimRun::new();
            let mut rebuilt = SimRun::new();
            rebuilt.set_rebuild_selector(true);
            for sigma in [0.1, 0.3] {
                let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(sigma, 5));
                let a = hoisted.simulate(&scaffold, &cfg);
                let b = rebuilt.simulate(&scaffold, &cfg);
                outcomes_bit_equal(&a, &b);
            }
        }
    }

    #[test]
    fn oct_table_built_once_per_scaffold() {
        // The hoisting claim, pinned: however many recompute triggers a
        // sweep produces, the PEFT OCT table is computed exactly once per
        // scaffold (lazily, on the first trigger).
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Peft).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold = SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(s));
        let mut run = SimRun::new();
        let before = crate::scheduler::ranking::OCT_BUILDS.with(|c| c.get());
        let mut recomputes = 0usize;
        for seed in [5, 7, 11] {
            let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, seed));
            recomputes += run.simulate(&scaffold, &cfg).recomputations;
        }
        assert!(recomputes > 1, "test wants several triggers across the sweep");
        let after = crate::scheduler::ranking::OCT_BUILDS.with(|c| c.get());
        assert_eq!(after - before, 1, "OCT table must be built once per scaffold");
    }

    #[test]
    fn resume_arena_is_reused_across_triggers() {
        // The ResumeArena actually carries its buffers across points:
        // after a recompute-heavy run, the arena holds a platform state
        // and a full fixed buffer, and a second run reuses them while
        // staying bit-identical.
        let (wf, cluster) = sample(6, 4);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid);
        let scaffold = SimScaffold::new(Arc::new(wf), Arc::new(cluster), Arc::new(s));
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let mut run = SimRun::new();
        let first = run.simulate(&scaffold, &cfg);
        assert!(first.recomputations > 0, "test wants the resume path exercised");
        assert!(run.resume.state.is_some(), "arena must retain the platform snapshot");
        assert_eq!(run.resume.fixed.len(), scaffold.wf.num_tasks());
        let fixed_ptr = run.resume.fixed.as_ptr() as usize;
        let second = run.simulate(&scaffold, &cfg);
        outcomes_bit_equal(&first, &second);
        assert_eq!(run.resume.fixed.as_ptr() as usize, fixed_ptr, "fixed buffer reallocated");
    }

    #[test]
    fn calendar_event_queue_outcomes_bit_equal_heap() {
        // The two event-queue implementations must pop in the same total
        // order, making every outcome bit-identical across them, in both
        // modes.
        let (wf, cluster) = sample(8, 9);
        for algo in [Algorithm::HeftmBl, Algorithm::HeftmMm] {
            let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
            let scaffold = SimScaffold::new(
                Arc::new(wf.clone()),
                Arc::new(cluster.clone()),
                Arc::new(s),
            );
            let mut heap_run = SimRun::new();
            let mut cal_run = SimRun::new();
            assert_eq!(heap_run.event_queue_kind(), EventQueueKind::Heap);
            cal_run.set_event_queue(EventQueueKind::Calendar);
            assert_eq!(cal_run.event_queue_kind(), EventQueueKind::Calendar);
            for mode in [SimMode::FollowStatic, SimMode::Recompute] {
                for sigma in [0.0, 0.1, 0.3] {
                    let cfg = SimConfig::new(mode, DeviationModel::new(sigma, 7));
                    outcomes_bit_equal(
                        &heap_run.simulate(&scaffold, &cfg),
                        &cal_run.simulate(&scaffold, &cfg),
                    );
                }
            }
        }
    }

    #[test]
    fn hoisted_partitions_match_a_fresh_derivation() {
        // Structural check on the scaffold build: partitions, remote
        // sums, out-triples, and in-degrees agree with a direct walk.
        let (wf, cluster) = sample(8, 3);
        let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
        let sc = SimScaffold::new(Arc::new(wf.clone()), Arc::new(cluster), Arc::new(s.clone()));
        for v in 0..wf.num_tasks() {
            let j = s.tasks[v].proc;
            let mut local = Vec::new();
            let mut remote = Vec::new();
            let mut sum = 0.0f64;
            for &e in wf.in_edge_ids(v) {
                let edge = wf.edge(e);
                if s.tasks[edge.src].proc == j {
                    local.push((e, edge.data));
                } else {
                    sum += edge.data;
                    remote.push((e, edge.src, edge.data));
                }
            }
            assert_eq!(sc.in_local(v), &local[..]);
            assert_eq!(sc.in_remote(v), &remote[..]);
            assert_eq!(sc.remote_in[v].to_bits(), sum.to_bits());
            assert_eq!(sc.in_deg[v] as usize, wf.in_degree(v));
            let out: Vec<_> =
                wf.out_edge_ids(v).iter().map(|&e| (e, wf.edge(e).dst, wf.edge(e).data)).collect();
            assert_eq!(sc.out_tri(v), &out[..]);
        }
    }
}
