//! The runtime system (paper §V, §VI-A-3): discrete-event simulation of a
//! workflow execution in which actual task parameters deviate from the
//! estimates the scheduler used.
//!
//! Two execution modes:
//!
//! - [`SimMode::FollowStatic`] — the original schedule is followed: each
//!   processor executes its assigned tasks in planned order, waiting for
//!   busy processors and unfinished predecessors; if a task no longer fits
//!   in memory, the execution **fails** (the schedule was invalidated by
//!   the deviations);
//! - [`SimMode::Recompute`] — the runtime reveals a task's actual
//!   parameters when it arrives and warns the scheduler when they deviate
//!   significantly (> threshold) or no longer fit; the scheduler then
//!   recomputes the placements of all not-yet-started tasks on the fly
//!   (via [`Engine::resume`]) from a snapshot of the current platform
//!   state.
//!
//! The four §VI-A-3 issue types are all represented: *processor blocked*
//! and *predecessor not finished* are handled by waiting; *not enough
//! memory* fails or triggers recomputation depending on the mode; a *task
//! taking significantly less (or more) time than expected* triggers
//! recomputation.

pub mod deviation;

pub use deviation::DeviationModel;

use crate::platform::{Cluster, ProcId};
use crate::scheduler::engine::{Engine, Schedule, TaskSchedule};
use crate::scheduler::state::{EvictionPolicy, PendingSet, PlatformState};
use crate::scheduler::Algorithm;
use crate::workflow::{TaskId, Workflow};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Execution mode of the runtime system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Follow the static schedule; abort on memory violations.
    FollowStatic,
    /// Recompute the schedule on significant deviations.
    Recompute,
}

impl SimMode {
    /// Canonical wire label (accepted back by the `FromStr` impl).
    pub fn label(self) -> &'static str {
        match self {
            SimMode::FollowStatic => "static",
            SimMode::Recompute => "recompute",
        }
    }
}

impl std::str::FromStr for SimMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "follow-static" | "follow_static" => Ok(SimMode::FollowStatic),
            "recompute" | "dynamic" => Ok(SimMode::Recompute),
            other => anyhow::bail!("unknown simulation mode `{other}` (expected static, recompute)"),
        }
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub mode: SimMode,
    pub deviation: DeviationModel,
    /// Relative deviation that triggers a recomputation (paper: 10%).
    pub recompute_threshold: f64,
}

impl SimConfig {
    pub fn new(mode: SimMode, deviation: DeviationModel) -> SimConfig {
        SimConfig { mode, deviation, recompute_threshold: 0.1 }
    }
}

/// Why an execution failed.
#[derive(Debug, Clone, PartialEq)]
pub enum SimFailure {
    /// A task did not fit in memory on its processor (FollowStatic), or
    /// could not be placed anywhere even after recomputation.
    OutOfMemory { task: TaskId, proc: ProcId },
    /// Evicted files exceeded the communication buffer.
    BufferOverflow { task: TaskId, proc: ProcId },
}

/// Result of one simulated execution.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// True iff every task executed within the memory constraints.
    pub completed: bool,
    /// Total execution time (meaningful only if `completed`).
    pub makespan: f64,
    pub failure: Option<SimFailure>,
    /// Number of schedule recomputations performed.
    pub recomputations: usize,
    /// Tasks that started before failure/completion.
    pub started: usize,
    /// Actual per-task finish times (NaN where never started).
    pub finish_times: Vec<f64>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum TState {
    NotStarted,
    Running,
    Done,
}

/// Simulate executing `schedule` of `wf_est` (estimated weights) under the
/// deviation model in `cfg`.
pub fn simulate(
    wf_est: &Workflow,
    cluster: &Cluster,
    schedule: &Schedule,
    cfg: &SimConfig,
) -> SimOutcome {
    Sim::new(wf_est, cluster, schedule, cfg).run()
}

struct Sim<'a> {
    wf_est: &'a Workflow,
    /// Estimates, overwritten with actuals as tasks arrive.
    known: Workflow,
    cluster: &'a Cluster,
    cfg: &'a SimConfig,
    policy: EvictionPolicy,
    algorithm: Algorithm,
    rank_order: Vec<TaskId>,
    rank_pos: Vec<usize>,
    plan: Vec<TaskSchedule>,
    // Runtime state -------------------------------------------------------
    time: f64,
    proc_free: Vec<f64>,
    running: Vec<Option<TaskId>>,
    avail_mem: Vec<f64>,
    avail_buf: Vec<f64>,
    pending: Vec<PendingSet>,
    buffered: Vec<PendingSet>,
    comm_rt: Vec<f64>, // k×k
    state_of: Vec<TState>,
    st_act: Vec<f64>,
    ft_act: Vec<f64>,
    /// Transient memory held by a running task (freed at finish).
    held: Vec<f64>,
    /// Per-processor queues of unstarted tasks in plan order (reversed;
    /// pop from the back).
    queues: Vec<Vec<TaskId>>,
    heap: BinaryHeap<Reverse<(u64, TaskId)>>, // (finish-time bits, task)
    recomputations: usize,
    started: usize,
    /// Guards against recompute→fail→recompute loops per task.
    recompute_tried: Vec<bool>,
    /// Tasks deferred until the next finish event (waiting for memory).
    deferred: Vec<bool>,
}

/// Total-order bits for a non-negative f64 (times are ≥ 0).
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

impl<'a> Sim<'a> {
    fn new(
        wf_est: &'a Workflow,
        cluster: &'a Cluster,
        schedule: &'a Schedule,
        cfg: &'a SimConfig,
    ) -> Sim<'a> {
        let n = wf_est.num_tasks();
        let k = cluster.len();
        let mut rank_pos = vec![0usize; n];
        for (i, &v) in schedule.rank_order.iter().enumerate() {
            rank_pos[v] = i;
        }
        let mut sim = Sim {
            wf_est,
            known: wf_est.clone(),
            cluster,
            cfg,
            policy: schedule.policy,
            algorithm: schedule.algorithm,
            rank_order: schedule.rank_order.clone(),
            rank_pos,
            plan: schedule.tasks.clone(),
            time: 0.0,
            proc_free: vec![0.0; k],
            running: vec![None; k],
            avail_mem: cluster.processors.iter().map(|p| p.memory).collect(),
            avail_buf: cluster.processors.iter().map(|p| p.comm_buffer).collect(),
            pending: vec![PendingSet::default(); k],
            buffered: vec![PendingSet::default(); k],
            comm_rt: vec![0.0; k * k],
            state_of: vec![TState::NotStarted; n],
            st_act: vec![f64::NAN; n],
            ft_act: vec![f64::NAN; n],
            held: vec![0.0; n],
            queues: vec![Vec::new(); k],
            heap: BinaryHeap::new(),
            recomputations: 0,
            started: 0,
            recompute_tried: vec![false; n],
            deferred: vec![false; n],
        };
        sim.rebuild_queues();
        sim
    }

    /// Rebuild per-processor queues of unstarted tasks in plan order
    /// (planned start, then rank position; stored reversed for pop()).
    fn rebuild_queues(&mut self) {
        for q in &mut self.queues {
            q.clear();
        }
        let mut by_proc: Vec<Vec<TaskId>> = vec![Vec::new(); self.queues.len()];
        for v in 0..self.plan.len() {
            if self.state_of[v] == TState::NotStarted {
                by_proc[self.plan[v].proc].push(v);
            }
        }
        for (j, mut tasks) in by_proc.into_iter().enumerate() {
            tasks.sort_by(|&a, &b| {
                self.plan[a]
                    .start
                    .partial_cmp(&self.plan[b].start)
                    .unwrap()
                    .then(self.rank_pos[a].cmp(&self.rank_pos[b]))
            });
            tasks.reverse();
            self.queues[j] = tasks;
        }
    }

    fn parents_done(&self, v: TaskId) -> bool {
        self.wf_est.parents(v).all(|(u, _)| self.state_of[u] == TState::Done)
    }

    /// Arrival time of all remote inputs of `v` on `j`, advancing channel
    /// ready times (mirrors the scheduler's bookkeeping).
    fn input_arrival(&mut self, v: TaskId, j: ProcId) -> f64 {
        let k = self.queues.len();
        let mut arrival = 0.0f64;
        for &e in self.wf_est.in_edge_ids(v) {
            let edge = self.wf_est.edge(e);
            let pu = self.plan[edge.src].proc;
            if pu != j {
                let channel = self.comm_rt[pu * k + j].max(self.ft_act[edge.src]);
                let t = channel + edge.data / self.cluster.bandwidth;
                self.comm_rt[pu * k + j] = t;
                arrival = arrival.max(t);
            }
        }
        arrival
    }

    /// Attempt to start task `v` on its planned processor. Returns:
    /// - `Ok(true)`  — started;
    /// - `Ok(false)` — recomputation happened instead (Recompute mode);
    /// - `Err(f)`    — execution failed.
    fn try_start(&mut self, v: TaskId) -> Result<bool, SimFailure> {
        let j = self.plan[v].proc;
        // Reveal actual parameters (the task "arrives in the system").
        let est = self.wf_est.task(v);
        let (w_act, m_act) = self.cfg.deviation.actual(v, est.work, est.memory);
        self.known.set_task_params(v, w_act, m_act);

        // Memory feasibility with actual values.
        let mut remote_in = 0.0f64;
        let mut local_inputs: Vec<(usize, f64)> = Vec::new();
        for &e in self.wf_est.in_edge_ids(v) {
            let edge = self.wf_est.edge(e);
            if self.plan[edge.src].proc == j {
                local_inputs.push((e, edge.data));
            } else {
                remote_in += edge.data;
            }
        }
        let out = self.wf_est.total_out_data(v);

        // Planned evictions first (skip files already gone).
        let mut evict: Vec<(usize, f64)> = Vec::new();
        let mut buf_left = self.avail_buf[j];
        let mut mem_gain = 0.0f64;
        for &e in &self.plan[v].evicted.clone() {
            if let Some(size) = self.pending[j].get(e) {
                if size > buf_left {
                    return self.memory_problem(v, j, true);
                }
                buf_left -= size;
                mem_gain += size;
                evict.push((e, size));
            }
        }
        let mut res = self.avail_mem[j] + mem_gain - m_act - remote_in - out;
        if res < 0.0 && self.cfg.mode == SimMode::Recompute {
            // Additional greedy evictions (the scheduler would have
            // planned these, had it known the actual memory).
            for (e, size) in self.pending[j].candidates(self.policy) {
                if res >= 0.0 {
                    break;
                }
                if local_inputs.iter().any(|&(le, _)| le == e)
                    || evict.iter().any(|&(ee, _)| ee == e)
                    || size > buf_left
                {
                    continue;
                }
                buf_left -= size;
                res += size;
                evict.push((e, size));
            }
        }
        if res < 0.0 {
            return self.memory_problem(v, j, false);
        }

        // Commit the start. -------------------------------------------------
        for &(e, size) in &evict {
            self.pending[j].remove(e);
            self.avail_mem[j] += size;
            self.buffered[j].insert(e, size);
            self.avail_buf[j] -= size;
        }
        let arrival = self.input_arrival(v, j);
        let st = self.proc_free[j].max(arrival).max(self.time);
        let dur = self.cluster.exec_time(w_act, j);
        // Producer-side frees for remote inputs (files are sent now).
        for &e in self.wf_est.in_edge_ids(v) {
            let edge = self.wf_est.edge(e);
            let pu = self.plan[edge.src].proc;
            if pu != j {
                if let Some(size) = self.pending[pu].remove(e) {
                    self.avail_mem[pu] += size;
                } else if let Some(size) = self.buffered[pu].remove(e) {
                    self.avail_buf[pu] += size;
                }
            }
        }
        self.avail_mem[j] -= m_act + remote_in + out;
        self.held[v] = m_act + remote_in;
        self.st_act[v] = st;
        self.ft_act[v] = st + dur;
        self.state_of[v] = TState::Running;
        self.running[j] = Some(v);
        self.proc_free[j] = st + dur;
        self.started += 1;
        self.heap.push(Reverse((time_key(st + dur), v)));

        // Significant execution-time/memory deviation → warn the scheduler.
        if self.cfg.mode == SimMode::Recompute {
            let rel = (w_act - est.work).abs() / est.work.max(1e-12);
            let mel = (m_act - est.memory).abs() / est.memory.max(1e-12);
            if rel > self.cfg.recompute_threshold || mel > self.cfg.recompute_threshold {
                self.recompute();
            }
        }
        Ok(true)
    }

    /// Handle a memory violation at `v`'s start.
    ///
    /// In Recompute mode the scheduler is warned first (one recomputation
    /// per attempt). In both modes, if other tasks are still running the
    /// start is *deferred* — their completion returns transients and ships
    /// pending files, which is also how the static bookkeeping (freeing at
    /// assignment, §IV-B) and the execution (freeing at runtime) reconcile.
    /// Only when no progress is possible is the execution declared invalid
    /// (§VI-A-3: "not enough memory").
    fn memory_problem(&mut self, v: TaskId, j: ProcId, buffer: bool) -> Result<bool, SimFailure> {
        if self.cfg.mode == SimMode::Recompute && !self.recompute_tried[v] {
            self.recompute_tried[v] = true;
            self.recompute();
            return Ok(false);
        }
        if !self.heap.is_empty() {
            // Tasks are still running: waiting may free memory. Defer v
            // until the next finish event. (`recompute_tried` stays set:
            // one recomputation per memory issue — repeated recomputes per
            // retry would cost O(n·k) each for no new information.)
            self.deferred[v] = true;
            self.rebuild_queues(); // restore v (it was pre-popped)
            return Ok(false);
        }
        Err(if buffer {
            SimFailure::BufferOverflow { task: v, proc: j }
        } else {
            SimFailure::OutOfMemory { task: v, proc: j }
        })
    }

    /// Recompute the placements of all unstarted tasks from the current
    /// platform state (paper §V).
    fn recompute(&mut self) {
        let k = self.queues.len();
        // Snapshot the platform.
        let mut state = PlatformState::new(self.cluster);
        for j in 0..k {
            state.procs[j].ready_time = self.proc_free[j].max(self.time);
            state.procs[j].avail_mem = self.avail_mem[j];
            state.procs[j].avail_buf = self.avail_buf[j];
            state.procs[j].pending = self.pending[j].clone();
            state.procs[j].buffered = self.buffered[j].clone();
            // Outputs of running tasks are already reserved in avail_mem
            // but not yet in the pending set; pre-insert them so Step 1
            // sees them when placing their children.
            if let Some(r) = self.running[j] {
                for &e in self.wf_est.out_edge_ids(r) {
                    state.procs[j].pending.insert(e, self.wf_est.edge(e).data);
                }
            }
            for to in 0..k {
                let dt = self.comm_rt[j * k + to];
                if dt > 0.0 {
                    state.push_comm(j, to, dt);
                }
            }
        }
        // Fixed placements: everything started keeps its actual times.
        let fixed: Vec<Option<TaskSchedule>> = (0..self.plan.len())
            .map(|v| match self.state_of[v] {
                TState::NotStarted => None,
                _ => Some(TaskSchedule {
                    proc: self.plan[v].proc,
                    start: self.st_act[v],
                    finish: self.ft_act[v],
                    evicted: self.plan[v].evicted.clone(),
                    res_nonneg: self.plan[v].res_nonneg,
                }),
            })
            .collect();
        let engine = Engine::resume(
            &self.known,
            self.cluster,
            self.algorithm,
            self.policy,
            state,
            fixed,
        );
        let new = engine.run(&self.rank_order);
        self.plan = new.tasks;
        self.rebuild_queues();
        self.recomputations += 1;
    }

    /// Sweep all idle processors; start whatever is startable.
    fn try_starts(&mut self) -> Result<(), SimFailure> {
        let k = self.queues.len();
        let mut progress = true;
        while progress {
            progress = false;
            for j in 0..k {
                if self.running[j].is_some() {
                    continue;
                }
                // Drop queue entries whose placement moved (recompute).
                while let Some(&v) = self.queues[j].last() {
                    if self.state_of[v] != TState::NotStarted || self.plan[v].proc != j {
                        self.queues[j].pop();
                    } else {
                        break;
                    }
                }
                let Some(&v) = self.queues[j].last() else { continue };
                if !self.parents_done(v) {
                    continue; // predecessor not finished: wait
                }
                if self.deferred[v] {
                    continue; // waiting for memory until the next event
                }
                // Pop before attempting: any recompute inside try_start
                // rebuilds the queues from scratch (and re-inserts v if it
                // did not start), so the stale entry must be gone first.
                self.queues[j].pop();
                match self.try_start(v)? {
                    true => {
                        progress = true;
                    }
                    false => {
                        // Recompute happened; rescan all processors.
                        progress = true;
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    fn finish_task(&mut self, v: TaskId) {
        let j = self.plan[v].proc;
        debug_assert_eq!(self.running[j], Some(v));
        self.running[j] = None;
        self.state_of[v] = TState::Done;
        // Free the transient (task memory + remote inputs).
        self.avail_mem[j] += self.held[v];
        // Local inputs leave the pending set.
        for &e in self.wf_est.in_edge_ids(v) {
            let edge = self.wf_est.edge(e);
            if self.plan[edge.src].proc == j {
                if let Some(size) = self.pending[j].remove(e) {
                    self.avail_mem[j] += size;
                }
            }
        }
        // Outputs become pending files (space already reserved at start).
        for &e in self.wf_est.out_edge_ids(v) {
            self.pending[j].insert(e, self.wf_est.edge(e).data);
        }
    }

    fn run(mut self) -> SimOutcome {
        let n = self.wf_est.num_tasks();
        let mut done = 0usize;
        loop {
            if let Err(f) = self.try_starts() {
                return self.outcome(false, Some(f));
            }
            let Some(Reverse((tk, v))) = self.heap.pop() else {
                break;
            };
            self.time = f64::from_bits(tk);
            self.finish_task(v);
            // Freed memory: deferred tasks get another chance.
            self.deferred.iter_mut().for_each(|d| *d = false);
            done += 1;
            if done == n {
                break;
            }
        }
        let completed = done == n;
        self.outcome(completed, None)
    }

    fn outcome(self, completed: bool, failure: Option<SimFailure>) -> SimOutcome {
        let makespan = self.ft_act.iter().copied().filter(|f| f.is_finite()).fold(0.0, f64::max);
        SimOutcome {
            completed,
            makespan,
            failure,
            recomputations: self.recomputations,
            started: self.started,
            finish_times: self.ft_act,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::small_cluster;
    use crate::scheduler::compute_schedule;

    fn sample(samples: usize, seed: u64) -> (Workflow, Cluster) {
        let model = crate::generator::models::chipseq();
        let wf = crate::generator::expand(&model, samples).unwrap();
        let data = crate::traces::HistoricalData::synthesize(
            &crate::traces::task_types(&wf),
            &crate::traces::TraceConfig::default(),
            seed,
        );
        (crate::traces::bind_weights(&wf, &data, 2), small_cluster())
    }

    #[test]
    fn zero_deviation_follows_schedule() {
        let (wf, cluster) = sample(6, 1);
        let s = compute_schedule(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert!(s.valid);
        let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::none(1));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        assert_eq!(out.recomputations, 0);
        assert_eq!(out.started, wf.num_tasks());
        // Runtime makespan tracks the planned one closely (identical
        // parameters; only comm bookkeeping order differs).
        let rel = (out.makespan - s.makespan).abs() / s.makespan;
        assert!(rel < 0.05, "plan {} vs sim {}", s.makespan, out.makespan);
    }

    #[test]
    fn deviations_change_makespan_deterministically() {
        let (wf, cluster) = sample(6, 2);
        let s = compute_schedule(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        let cfg = SimConfig::new(SimMode::FollowStatic, DeviationModel::new(0.1, 7));
        let a = simulate(&wf, &cluster, &s, &cfg);
        let b = simulate(&wf, &cluster, &s, &cfg);
        if a.completed {
            assert_eq!(a.makespan, b.makespan);
            assert_ne!(a.makespan, 0.0);
        }
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn recompute_mode_no_worse_than_static() {
        // Constrained memories: upward deviations break static schedules.
        let (wf, cluster) = sample(10, 3);
        let tight = cluster.scale_memory(0.12, "tight");
        let s = compute_schedule(&wf, &tight, Algorithm::HeftmMm, EvictionPolicy::LargestFirst);
        if !s.valid {
            return; // instance unschedulable even statically; not this test
        }
        let dev = DeviationModel::new(0.1, 11);
        let stat = simulate(&wf, &tight, &s, &SimConfig::new(SimMode::FollowStatic, dev));
        let dynr = simulate(&wf, &tight, &s, &SimConfig::new(SimMode::Recompute, dev));
        assert!(dynr.completed || !stat.completed);
    }

    #[test]
    fn recompute_triggered_by_large_deviation() {
        let (wf, cluster) = sample(6, 4);
        let s = compute_schedule(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst);
        assert!(s.valid);
        // 30% sigma guarantees many tasks cross the 10% threshold.
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.3, 5));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        assert!(out.recomputations > 0);
    }

    #[test]
    fn finish_times_respect_dependencies() {
        let (wf, cluster) = sample(5, 6);
        let s =
            compute_schedule(&wf, &cluster, Algorithm::HeftmBlc, EvictionPolicy::LargestFirst);
        let cfg = SimConfig::new(SimMode::Recompute, DeviationModel::new(0.1, 13));
        let out = simulate(&wf, &cluster, &s, &cfg);
        assert!(out.completed, "{:?}", out.failure);
        for e in wf.edges() {
            assert!(
                out.finish_times[e.dst] > out.finish_times[e.src] - 1e-9,
                "child finished before parent"
            );
        }
    }

    #[test]
    fn all_algorithms_simulate_cleanly_small() {
        let (wf, cluster) = sample(4, 8);
        for algo in Algorithm::all() {
            let s = compute_schedule(&wf, &cluster, algo, EvictionPolicy::LargestFirst);
            for mode in [SimMode::FollowStatic, SimMode::Recompute] {
                let cfg = SimConfig::new(mode, DeviationModel::new(0.05, 21));
                let out = simulate(&wf, &cluster, &s, &cfg);
                // Memory-aware schedules must survive in recompute mode;
                // HEFT (memory-oblivious) may legitimately die at runtime
                // — that is the paper's core observation.
                if algo.memory_aware() && s.valid && mode == SimMode::Recompute {
                    assert!(out.completed, "{algo:?} {mode:?}: {:?}", out.failure);
                }
                // Either way the simulation must terminate cleanly with a
                // coherent outcome.
                assert!(out.completed || out.failure.is_some(), "{algo:?} {mode:?} stalled");
            }
        }
    }
}
