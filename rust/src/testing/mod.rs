//! In-tree property-testing helpers (proptest is unavailable offline).
//!
//! [`check`] runs a property over many seeded random cases and reports the
//! failing seed so a failure is reproducible with a unit test. Generators
//! for random DAG workflows and random clusters live here too; they are
//! used by the property suites in `rust/tests/`.

use crate::platform::{Cluster, Processor};
use crate::util::rng::Rng;
use crate::workflow::{Workflow, WorkflowBuilder};

/// Run `property` over `cases` random cases derived from `seed`.
/// Panics with the offending case seed on the first failure.
pub fn check<F>(cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed on case {i} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Random layered DAG: up to `max_tasks` tasks, random layer widths, edges
/// only forward across layers (guaranteed acyclic), random weights with
/// realistic magnitudes (work ~ seconds, memory/files ~ MB..GB).
pub fn random_dag(rng: &mut Rng, max_tasks: usize) -> Workflow {
    let n = rng.range_inclusive(2, max_tasks.max(2));
    let mut b = WorkflowBuilder::new(format!("rand_{n}"));
    // Assign each task to a layer.
    let layers = rng.range_inclusive(2, (n / 2).clamp(2, 12));
    let mut layer_of = Vec::with_capacity(n);
    for i in 0..n {
        let l = if i < layers { i } else { rng.range_inclusive(0, layers - 1) };
        layer_of.push(l);
        let work = rng.uniform(0.5, 300.0);
        let memory = rng.uniform(1.0, 4096.0) * 1024.0 * 1024.0;
        b.task(format!("t{i}"), format!("ty{}", i % 7), work, memory);
    }
    // Forward edges.
    for v in 0..n {
        if layer_of[v] == 0 {
            continue;
        }
        let parents = rng.range_inclusive(1, 3);
        for _ in 0..parents {
            // Pick a random task in an earlier layer.
            let candidates: Vec<usize> =
                (0..n).filter(|&u| layer_of[u] < layer_of[v]).collect();
            if candidates.is_empty() {
                continue;
            }
            let u = candidates[rng.pick_index(&candidates)];
            b.edge(u, v, rng.uniform(0.001, 512.0) * 1024.0 * 1024.0);
        }
    }
    match b.build() {
        Ok(wf) => wf,
        Err(_) => {
            // Duplicate edges cannot happen; cycles cannot happen; only
            // pathological cases (none known) would land here.
            let mut b = WorkflowBuilder::new("fallback");
            let a = b.task("a", "t", 1.0, 1.0);
            let c = b.task("c", "t", 1.0, 1.0);
            b.edge(a, c, 1.0);
            b.build().unwrap()
        }
    }
}

/// Random heterogeneous cluster: 2–8 processors, speeds 1–32, memories
/// 1–64 GB, buffer 10× memory.
pub fn random_cluster(rng: &mut Rng) -> Cluster {
    let k = rng.range_inclusive(2, 8);
    let gb = 1024.0 * 1024.0 * 1024.0;
    let processors = (0..k)
        .map(|j| {
            let mem = rng.uniform(1.0, 64.0) * gb;
            Processor {
                name: format!("p{j}"),
                kind: format!("k{}", j % 3),
                speed: rng.uniform(1.0, 32.0),
                memory: mem,
                comm_buffer: 10.0 * mem,
            }
        })
        .collect();
    Cluster { name: "rand".into(), processors, bandwidth: rng.uniform(0.1, 2.0) * gb }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(20, 1, |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(5, 2, |_| Err("always fails".to_string()));
    }

    #[test]
    fn random_dags_are_valid() {
        check(30, 3, |rng| {
            let wf = random_dag(rng, 60);
            if !wf.is_topological_order(&wf.topological_order()) {
                return Err("not a DAG".into());
            }
            if wf.num_tasks() < 2 {
                return Err("too small".into());
            }
            Ok(())
        });
    }

    #[test]
    fn random_clusters_validate() {
        check(30, 4, |rng| {
            let c = random_cluster(rng);
            c.validate().map_err(|e| e.to_string())
        });
    }
}
