//! Synthetic historical task-resource data (paper §VI-A-1b).
//!
//! The paper binds task and edge weights from the Lotaru historical traces
//! of Bader et al. [6]: measured runtime and memory per (task type, input
//! size), with the *total* output file size per task (not per edge), and
//! with >40–50% of task types carrying no data at all — those receive fixed
//! defaults (runtime 1, memory 50 MB, files 1 KB).
//!
//! Those traces are not redistributable / not available offline, so this
//! module synthesizes statistically equivalent tables (documented in
//! DESIGN.md): per task type, log-normally distributed base runtime /
//! memory / output size, scaled across five input sizes, with a seeded
//! fraction of types intentionally *missing*. The binder
//! ([`bind_weights`]) is identical to what real traces would use, so real
//! Lotaru CSVs could be plugged in by constructing [`HistoricalData`]
//! directly.

use crate::platform::presets::{KB, MB};
use crate::util::rng::Rng;
use crate::workflow::Workflow;
use std::collections::BTreeMap;

/// Number of distinct input sizes per workflow family (§VI-A-1b).
pub const NUM_INPUT_SIZES: usize = 5;

/// Paper defaults for tasks without historical data (§VI-A-1b).
pub const DEFAULT_WORK: f64 = 1.0;
/// 50 MB.
pub const DEFAULT_MEMORY: f64 = 50.0 * MB;
/// 1 KB.
pub const DEFAULT_FILE: f64 = 1.0 * KB;

/// One historical record: resources of a task type at one input size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    /// Measured work (normalized operations; seconds on a speed-1 machine).
    pub work: f64,
    /// Peak memory of the task, bytes (total requirement: the OS cannot
    /// separate computation RAM from file buffers — §VI-A-1b).
    pub memory: f64,
    /// Total size of files sent to *all* children, bytes.
    pub output_total: f64,
}

/// Historical data table: task type → per-input-size records.
/// Types absent from the map have no historical data (the paper's
/// missing-data case).
#[derive(Debug, Clone, Default)]
pub struct HistoricalData {
    records: BTreeMap<String, [TraceRecord; NUM_INPUT_SIZES]>,
}

/// Tuning knobs for the synthetic tables.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Fraction of task types with *no* historical data (paper: 40–50%).
    pub missing_fraction: f64,
    /// Median work of a heavy type at the smallest input (speed-1 seconds).
    pub base_work: f64,
    /// Median memory of a heavy type at the smallest input, bytes.
    pub base_memory: f64,
    /// Median total output of a heavy type at the smallest input, bytes.
    pub base_output: f64,
    /// Log-normal sigma across task types.
    pub spread: f64,
    /// Multiplicative growth per input-size step.
    pub input_growth: f64,
    /// Upper clamp on task memory, bytes. Real pipeline tasks are sized to
    /// fit the cluster's largest node (jobs that can never run get fixed
    /// by their authors); without a cap the log-normal tail would create
    /// tasks no algorithm can place, which the paper does not observe
    /// (HEFTM-MM schedules 100% even memory-constrained).
    pub max_memory: f64,
    /// Upper clamp on a task's total output, bytes.
    pub max_output: f64,
    /// Upper clamp on a task's total *input* volume, bytes. High fan-in
    /// aggregation stages (multiqc, consensus peaks, ...) receive summary
    /// files, not the producers' full outputs; without this cap a gather
    /// over thousands of samples would need more memory than any machine
    /// has, which the paper's workloads do not exhibit (its largest
    /// workflows remain schedulable by HEFTM-MM on the constrained
    /// cluster). Incoming edges of a task are scaled down proportionally
    /// when their sum exceeds the cap.
    pub max_input: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            missing_fraction: 0.45,
            // Tuned so that a default-cluster node (8–192 GB) comfortably
            // runs a handful of heavy tasks but HEFT's memory-oblivious
            // packing overcommits on large workflows, as in the paper.
            base_work: 120.0,
            base_memory: 1.5 * 1024.0 * MB, // ~1.5 GiB median heavy task
            base_output: 400.0 * MB,
            spread: 0.8,
            input_growth: 1.6,
            // Worst case m + max_input + max_output = 18 GiB: fits the
            // constrained C2 node (19.2 GB), so every task is placeable
            // *somewhere* — failures are always about accumulation.
            max_memory: 10.0 * 1024.0 * MB,
            max_output: 2.0 * 1024.0 * MB,
            max_input: 6.0 * 1024.0 * MB,
        }
    }
}

impl HistoricalData {
    /// Synthesize a table for the given task types. Deterministic in
    /// `seed`. Types are classified heavy/light (bimodal, as observed in
    /// [6]: a few dominant aligners/caller stages, many small utility
    /// tasks), then `missing_fraction` of types is dropped entirely.
    pub fn synthesize(task_types: &[String], cfg: &TraceConfig, seed: u64) -> HistoricalData {
        let mut rng = Rng::new(seed ^ 0x7261_6365); // "race"
        let mut records = BTreeMap::new();
        for ty in task_types {
            if rng.next_f64() < cfg.missing_fraction {
                continue; // no historical data for this type
            }
            let heavy = rng.next_f64() < 0.4;
            let scale = if heavy { 1.0 } else { 0.08 };
            // Per-type multipliers, log-normal around the base.
            let lognorm = |rng: &mut Rng, sigma: f64| (sigma * rng.normal()).exp();
            let work0 = cfg.base_work * scale * lognorm(&mut rng, cfg.spread);
            let mem0 = cfg.base_memory * scale * lognorm(&mut rng, cfg.spread * 0.6);
            let out0 = cfg.base_output * scale * lognorm(&mut rng, cfg.spread * 0.8);
            let mut recs = [TraceRecord { work: 0.0, memory: 0.0, output_total: 0.0 };
                NUM_INPUT_SIZES];
            for (i, r) in recs.iter_mut().enumerate() {
                let growth = cfg.input_growth.powi(i as i32);
                // Mild per-size measurement noise.
                let jitter = |rng: &mut Rng| 1.0 + 0.05 * rng.normal();
                r.work = (work0 * growth * jitter(&mut rng)).max(0.01);
                r.memory =
                    (mem0 * growth * jitter(&mut rng)).clamp(1.0 * MB, cfg.max_memory);
                r.output_total =
                    (out0 * growth * jitter(&mut rng)).clamp(1.0 * KB, cfg.max_output);
            }
            records.insert(ty.clone(), recs);
        }
        HistoricalData { records }
    }

    /// Insert a record row explicitly (for real trace ingestion and tests).
    pub fn insert(&mut self, task_type: &str, recs: [TraceRecord; NUM_INPUT_SIZES]) {
        self.records.insert(task_type.to_string(), recs);
    }

    pub fn get(&self, task_type: &str, input_size: usize) -> Option<&TraceRecord> {
        self.records.get(task_type).map(|r| &r[input_size.min(NUM_INPUT_SIZES - 1)])
    }

    pub fn has_type(&self, task_type: &str) -> bool {
        self.records.contains_key(task_type)
    }

    pub fn num_types(&self) -> usize {
        self.records.len()
    }

    /// Fraction of the workflow's tasks with historical data.
    pub fn coverage(&self, wf: &Workflow) -> f64 {
        let covered =
            wf.tasks().iter().filter(|t| self.records.contains_key(&t.task_type)).count();
        covered as f64 / wf.num_tasks() as f64
    }
}

/// Bind task and edge weights of `wf` from historical data at the given
/// input size, applying the paper's defaults where data is missing.
///
/// Edge weights: the traces only store the *total* output size of a task
/// (§VI-A-1b), so it is split evenly across the task's out-edges.
pub fn bind_weights(wf: &Workflow, data: &HistoricalData, input_size: usize) -> Workflow {
    bind_weights_capped(wf, data, input_size, TraceConfig::default().max_input)
}

/// [`bind_weights`] with an explicit per-task input-volume cap (see
/// [`TraceConfig::max_input`]): incoming edges of a task whose inputs sum
/// beyond the cap are scaled down proportionally (aggregation stages
/// receive summary files).
pub fn bind_weights_capped(
    wf: &Workflow,
    data: &HistoricalData,
    input_size: usize,
    max_input: f64,
) -> Workflow {
    let mut b = crate::workflow::WorkflowBuilder::new(&wf.name);
    let mut out_edge_data = vec![DEFAULT_FILE; wf.num_tasks()];
    for (id, t) in wf.tasks().iter().enumerate() {
        match data.get(&t.task_type, input_size) {
            Some(rec) => {
                // Per-instance variability: real historical tables carry
                // one row per *execution*, so two instances of the same
                // type differ; a deterministic ±20% jitter keyed on the
                // task name reproduces that (and breaks the rank-order
                // ties that would otherwise make BL and BLC coincide).
                let j = instance_jitter(&t.name);
                b.task(&t.name, &t.task_type, rec.work * j, (rec.memory * j).min(
                    TraceConfig::default().max_memory));
                let out_deg = wf.out_degree(id).max(1);
                out_edge_data[id] = rec.output_total * j / out_deg as f64;
            }
            None => {
                b.task(&t.name, &t.task_type, DEFAULT_WORK, DEFAULT_MEMORY);
                out_edge_data[id] = DEFAULT_FILE;
            }
        }
    }
    // Per-consumer input cap.
    let mut edge_data: Vec<f64> = wf.edges().iter().map(|e| out_edge_data[e.src]).collect();
    for v in 0..wf.num_tasks() {
        let total: f64 = wf.in_edge_ids(v).iter().map(|&e| edge_data[e]).sum();
        if total > max_input {
            let factor = max_input / total;
            for &e in wf.in_edge_ids(v) {
                edge_data[e] *= factor;
            }
        }
    }
    for (i, e) in wf.edges().iter().enumerate() {
        b.edge(e.src, e.dst, edge_data[i]);
    }
    b.build().expect("re-binding weights preserves graph validity")
}

/// Deterministic per-instance multiplier in [0.8, 1.2] from a task name.
fn instance_jitter(name: &str) -> f64 {
    let h = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    0.8 + 0.4 * ((h >> 11) as f64 / (1u64 << 53) as f64)
}

/// Collect the distinct task types of a workflow (sorted).
pub fn task_types(wf: &Workflow) -> Vec<String> {
    let mut types: Vec<String> = wf.tasks().iter().map(|t| t.task_type.clone()).collect();
    types.sort_unstable();
    types.dedup();
    types
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::WorkflowBuilder;

    fn wf_with_types(types: &[&str]) -> Workflow {
        let mut b = WorkflowBuilder::new("w");
        let ids: Vec<_> = types
            .iter()
            .enumerate()
            .map(|(i, ty)| b.task(format!("t{i}"), *ty, 0.0, 0.0))
            .collect();
        for w in ids.windows(2) {
            b.edge(w[0], w[1], 0.0);
        }
        b.build().unwrap()
    }

    #[test]
    fn synthesis_is_deterministic() {
        let types: Vec<String> = (0..50).map(|i| format!("ty{i}")).collect();
        let a = HistoricalData::synthesize(&types, &TraceConfig::default(), 1);
        let b = HistoricalData::synthesize(&types, &TraceConfig::default(), 1);
        assert_eq!(a.num_types(), b.num_types());
        for ty in &types {
            assert_eq!(a.get(ty, 2).map(|r| r.work), b.get(ty, 2).map(|r| r.work));
        }
    }

    #[test]
    fn missing_fraction_respected() {
        let types: Vec<String> = (0..400).map(|i| format!("ty{i}")).collect();
        let d = HistoricalData::synthesize(&types, &TraceConfig::default(), 7);
        let present = d.num_types() as f64 / types.len() as f64;
        assert!((0.45..0.65).contains(&present), "present fraction {present}");
    }

    #[test]
    fn records_grow_with_input_size() {
        let types = vec!["a".to_string()];
        let cfg = TraceConfig { missing_fraction: 0.0, ..TraceConfig::default() };
        let d = HistoricalData::synthesize(&types, &cfg, 3);
        let w: Vec<f64> = (0..NUM_INPUT_SIZES).map(|i| d.get("a", i).unwrap().work).collect();
        // Growth factor 1.8 with 5% jitter: must be increasing overall.
        assert!(w[4] > w[0] * 4.0, "{w:?}");
    }

    #[test]
    fn binding_applies_defaults_for_missing() {
        let wf = wf_with_types(&["known", "unknown"]);
        let mut d = HistoricalData::default();
        d.insert(
            "known",
            [TraceRecord { work: 10.0, memory: 1e9, output_total: 4e6 }; NUM_INPUT_SIZES],
        );
        let bound = bind_weights(&wf, &d, 0);
        // Known type: record value modulated by the ±20% instance jitter.
        let j = bound.task(0).work / 10.0;
        assert!((0.8..=1.2).contains(&j), "jitter {j}");
        assert!((bound.task(0).memory / 1e9 - j).abs() < 1e-9);
        // Missing type: exact paper defaults (no jitter).
        assert_eq!(bound.task(1).work, DEFAULT_WORK);
        assert_eq!(bound.task(1).memory, DEFAULT_MEMORY);
        // Edge from known: output_total split over 1 out-edge (jittered).
        assert!((bound.edge(0).data / 4e6 - j).abs() < 1e-9);
    }

    #[test]
    fn instance_jitter_deterministic_and_bounded() {
        for name in ["a", "bwa_17", "fastqc_0", "x_999"] {
            let a = instance_jitter(name);
            assert_eq!(a, instance_jitter(name));
            assert!((0.8..=1.2).contains(&a), "{name}: {a}");
        }
        assert_ne!(instance_jitter("a"), instance_jitter("b"));
    }

    #[test]
    fn output_split_across_children() {
        let mut b = WorkflowBuilder::new("split");
        let a = b.task("a", "known", 0.0, 0.0);
        let c1 = b.task("c1", "x", 0.0, 0.0);
        let c2 = b.task("c2", "x", 0.0, 0.0);
        b.edge(a, c1, 0.0);
        b.edge(a, c2, 0.0);
        let wf = b.build().unwrap();
        let mut d = HistoricalData::default();
        d.insert(
            "known",
            [TraceRecord { work: 1.0, memory: 1.0, output_total: 10.0 }; NUM_INPUT_SIZES],
        );
        let bound = bind_weights(&wf, &d, 0);
        // Equal split across the two children (same producer jitter).
        assert_eq!(bound.edge(0).data, bound.edge(1).data);
        let j = bound.task(0).work / 1.0;
        assert!((bound.edge(0).data - 5.0 * j).abs() < 1e-9);
    }

    #[test]
    fn coverage_reported() {
        let wf = wf_with_types(&["a", "b", "c", "d"]);
        let mut d = HistoricalData::default();
        let rec = [TraceRecord { work: 1.0, memory: 1.0, output_total: 1.0 }; NUM_INPUT_SIZES];
        d.insert("a", rec);
        d.insert("b", rec);
        assert_eq!(d.coverage(&wf), 0.5);
    }

    #[test]
    fn input_size_clamped() {
        let mut d = HistoricalData::default();
        let mut recs =
            [TraceRecord { work: 1.0, memory: 1.0, output_total: 1.0 }; NUM_INPUT_SIZES];
        recs[NUM_INPUT_SIZES - 1].work = 99.0;
        d.insert("a", recs);
        assert_eq!(d.get("a", 1000).unwrap().work, 99.0);
    }
}
