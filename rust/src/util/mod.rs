//! Small shared utilities (deterministic RNG, logging helpers).

pub mod rng;
