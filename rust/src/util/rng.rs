//! Deterministic pseudo-random number generation.
//!
//! All stochastic components of the library (workflow generation, task
//! parameter deviation, tie-breaking) draw from this RNG so that every
//! experiment is exactly reproducible from a seed. The implementation is
//! SplitMix64 for seeding plus xoshiro256++ for the stream, both public
//! domain algorithms.

/// A deterministic xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)`. `bound` must be > 0.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal sample (Box-Muller; one value per call for
    /// reproducibility simplicity).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0) by mapping into (0,1].
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal sample with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element index; panics on empty slice.
    pub fn pick_index<T>(&mut self, xs: &[T]) -> usize {
        assert!(!xs.is_empty());
        self.next_below(xs.len() as u64) as usize
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_mean_and_sd_plausible() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal_with(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1, "mean {mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.1, "sd {}", var.sqrt());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
