//! Parser for the GraphViz DOT subset emitted by `nextflow -with-dag`
//! (paper §VI-A-1a).
//!
//! Supported grammar (a pragmatic subset of DOT):
//!
//! ```text
//! digraph NAME? {
//!   node_id [attr=val, ...];
//!   node_id -> node_id [label="...", ...];
//! }
//! ```
//!
//! Node attributes recognized: `type`, `work`, `memory` (also `label`, kept
//! as the task name when present). Edge attribute recognized: `data` (or
//! `label` if numeric). Tasks referenced only in edges are created with
//! zero weights — the trace binder ([`crate::traces`]) fills them in, and
//! nextflow *pseudo-tasks* (names starting with `p_` or quoted empty
//! labels) are dropped and their edges contracted, mirroring the paper's
//! preprocessing.

use super::{Workflow, WorkflowBuilder};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parse DOT text into a workflow. `contract_pseudo` drops nextflow
/// internal pseudo-tasks (`p_*`) and splices their edges.
pub fn parse_dot(text: &str, contract_pseudo: bool) -> Result<Workflow> {
    let mut lx = Lexer::new(text);
    lx.expect_ident("digraph")?;
    let name = match lx.peek()? {
        Tok::Ident(_) | Tok::Quoted(_) => lx.take_name()?,
        _ => "workflow".to_string(),
    };
    lx.expect(Tok::LBrace)?;

    let mut nodes: Vec<RawNode> = Vec::new();
    let mut ids: HashMap<String, usize> = HashMap::new();
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();

    loop {
        match lx.peek()? {
            Tok::RBrace => {
                lx.next()?;
                break;
            }
            Tok::Eof => bail!("unexpected end of DOT input (missing `}}`)"),
            Tok::Semi => {
                lx.next()?;
            }
            Tok::Ident(_) | Tok::Quoted(_) => {
                let first = lx.take_name()?;
                // Skip graph-level attribute statements.
                if (first == "graph" || first == "node" || first == "edge")
                    && matches!(lx.peek()?, Tok::LBracket)
                {
                    let _ = lx.attrs()?;
                    continue;
                }
                if matches!(lx.peek()?, Tok::Arrow) {
                    // Edge chain: a -> b -> c [attrs]
                    let mut chain = vec![intern(&mut nodes, &mut ids, &first)];
                    while matches!(lx.peek()?, Tok::Arrow) {
                        lx.next()?;
                        let nm = lx.take_name()?;
                        chain.push(intern(&mut nodes, &mut ids, &nm));
                    }
                    let attrs = if matches!(lx.peek()?, Tok::LBracket) {
                        lx.attrs()?
                    } else {
                        Vec::new()
                    };
                    let data = edge_data(&attrs);
                    for w in chain.windows(2) {
                        edges.push((w[0], w[1], data));
                    }
                } else {
                    // Node statement.
                    let id = intern(&mut nodes, &mut ids, &first);
                    if matches!(lx.peek()?, Tok::LBracket) {
                        let attrs = lx.attrs()?;
                        apply_node_attrs(&mut nodes[id], &attrs);
                    }
                }
            }
            other => bail!("unexpected token {other:?} in DOT body"),
        }
    }

    build_workflow(name, nodes, edges, contract_pseudo)
}

#[derive(Debug, Clone)]
struct RawNode {
    name: String,
    task_type: String,
    work: f64,
    memory: f64,
}

fn intern(nodes: &mut Vec<RawNode>, ids: &mut HashMap<String, usize>, name: &str) -> usize {
    if let Some(&id) = ids.get(name) {
        return id;
    }
    let id = nodes.len();
    nodes.push(RawNode {
        name: name.to_string(),
        task_type: default_type(name),
        work: 0.0,
        memory: 0.0,
    });
    ids.insert(name.to_string(), id);
    id
}

/// Task type defaults to the name with a trailing `_<digits>` instance
/// suffix stripped (`fastqc_12` -> `fastqc`).
fn default_type(name: &str) -> String {
    match name.rfind('_') {
        Some(i) if name[i + 1..].chars().all(|c| c.is_ascii_digit()) && i > 0 => {
            name[..i].to_string()
        }
        _ => name.to_string(),
    }
}

fn apply_node_attrs(node: &mut RawNode, attrs: &[(String, String)]) {
    for (k, v) in attrs {
        match k.as_str() {
            "type" => node.task_type = v.clone(),
            "work" => {
                if let Ok(x) = v.parse() {
                    node.work = x;
                }
            }
            "memory" => {
                if let Ok(x) = v.parse() {
                    node.memory = x;
                }
            }
            _ => {}
        }
    }
}

fn edge_data(attrs: &[(String, String)]) -> f64 {
    for (k, v) in attrs {
        if k == "data" {
            if let Ok(x) = v.parse() {
                return x;
            }
        }
        if k == "label" {
            if let Ok(x) = v.parse() {
                return x;
            }
        }
    }
    0.0
}

/// Nextflow pseudo-tasks: internal representation nodes, not real tasks.
fn is_pseudo(name: &str) -> bool {
    name.starts_with("p_") || name.is_empty()
}

fn build_workflow(
    name: String,
    nodes: Vec<RawNode>,
    edges: Vec<(usize, usize, f64)>,
    contract_pseudo: bool,
) -> Result<Workflow> {
    if !contract_pseudo {
        let mut b = WorkflowBuilder::new(name);
        for nd in &nodes {
            b.task(&nd.name, &nd.task_type, nd.work, nd.memory);
        }
        for (s, d, c) in edges {
            b.edge(s, d, c);
        }
        return b.build().context("building workflow from DOT");
    }

    // Contract pseudo-tasks: repeatedly splice edges through them.
    // Build adjacency over the raw indices first.
    let n = nodes.len();
    let keep: Vec<bool> = nodes.iter().map(|nd| !is_pseudo(&nd.name)).collect();
    let mut out: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for &(s, d, c) in &edges {
        out[s].push((d, c));
    }
    // For each kept node, walk through pseudo chains to find kept targets.
    // The pseudo subgraph is a DAG, so a DFS with memoization terminates.
    let mut memo: Vec<Option<Vec<(usize, f64)>>> = vec![None; n];
    fn resolve(
        u: usize,
        out: &[Vec<(usize, f64)>],
        keep: &[bool],
        memo: &mut Vec<Option<Vec<(usize, f64)>>>,
    ) -> Vec<(usize, f64)> {
        if let Some(cached) = &memo[u] {
            return cached.clone();
        }
        let mut targets = Vec::new();
        for &(v, c) in &out[u] {
            if keep[v] {
                targets.push((v, c));
            } else {
                // Data carried by the edge into the pseudo node is
                // forwarded along its out-edges.
                for (w, c2) in resolve(v, out, keep, memo) {
                    targets.push((w, c.max(c2)));
                }
            }
        }
        memo[u] = Some(targets.clone());
        targets
    }

    let mut remap = vec![usize::MAX; n];
    let mut b = WorkflowBuilder::new(name);
    for (i, nd) in nodes.iter().enumerate() {
        if keep[i] {
            remap[i] = b.task(&nd.name, &nd.task_type, nd.work, nd.memory);
        }
    }
    if b.num_tasks() == 0 {
        bail!("workflow is empty after pseudo-task contraction");
    }
    let mut emitted: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
    for u in 0..n {
        if !keep[u] {
            continue;
        }
        for &(v, c) in &out[u] {
            let targets =
                if keep[v] { vec![(v, c)] } else { resolve(v, &out, &keep, &mut memo) };
            for (w, c2) in targets {
                if emitted.insert((remap[u], remap[w])) {
                    b.edge(remap[u], remap[w], if keep[v] { c } else { c.max(c2) });
                }
            }
        }
    }
    b.build().context("building workflow from DOT (contracted)")
}

/// Render a workflow as DOT (inverse of [`parse_dot`], for inspection).
pub fn to_dot(wf: &Workflow) -> String {
    let mut s = String::new();
    s.push_str(&format!("digraph \"{}\" {{\n", wf.name));
    for (id, t) in wf.tasks().iter().enumerate() {
        s.push_str(&format!(
            "  \"{}\" [type=\"{}\", work={}, memory={}];\n",
            t.name, t.task_type, t.work, t.memory
        ));
        let _ = id;
    }
    for e in wf.edges() {
        s.push_str(&format!(
            "  \"{}\" -> \"{}\" [data={}];\n",
            wf.task(e.src).name,
            wf.task(e.dst).name,
            e.data
        ));
    }
    s.push_str("}\n");
    s
}

// ---------------------------------------------------------------------------
// Lexer

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Quoted(String),
    Arrow,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Equals,
    Comma,
    Semi,
    Eof,
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    peeked: Option<Tok>,
}

impl<'a> Lexer<'a> {
    fn new(text: &'a str) -> Self {
        Lexer { bytes: text.as_bytes(), pos: 0, peeked: None }
    }

    fn peek(&mut self) -> Result<Tok> {
        if self.peeked.is_none() {
            self.peeked = Some(self.lex()?);
        }
        Ok(self.peeked.clone().unwrap())
    }

    fn next(&mut self) -> Result<Tok> {
        match self.peeked.take() {
            Some(t) => Ok(t),
            None => self.lex(),
        }
    }

    fn expect(&mut self, want: Tok) -> Result<()> {
        let got = self.next()?;
        if got != want {
            bail!("DOT parse error: expected {want:?}, found {got:?}");
        }
        Ok(())
    }

    fn expect_ident(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s == kw => Ok(()),
            other => bail!("DOT parse error: expected `{kw}`, found {other:?}"),
        }
    }

    /// Take an identifier or quoted string as a name.
    fn take_name(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) | Tok::Quoted(s) => Ok(s),
            other => bail!("DOT parse error: expected name, found {other:?}"),
        }
    }

    /// Parse `[k=v, k=v, ...]`.
    fn attrs(&mut self) -> Result<Vec<(String, String)>> {
        self.expect(Tok::LBracket)?;
        let mut out = Vec::new();
        loop {
            match self.next()? {
                Tok::RBracket => return Ok(out),
                Tok::Comma | Tok::Semi => continue,
                Tok::Ident(k) | Tok::Quoted(k) => {
                    self.expect(Tok::Equals)?;
                    let v = self.take_name()?;
                    out.push((k, v));
                }
                other => bail!("DOT parse error: unexpected {other:?} in attribute list"),
            }
        }
    }

    fn lex(&mut self) -> Result<Tok> {
        // Skip whitespace and // or # comments.
        loop {
            while matches!(self.bytes.get(self.pos), Some(b) if b.is_ascii_whitespace()) {
                self.pos += 1;
            }
            match (self.bytes.get(self.pos), self.bytes.get(self.pos + 1)) {
                (Some(b'/'), Some(b'/')) | (Some(b'#'), _) => {
                    while !matches!(self.bytes.get(self.pos), None | Some(b'\n')) {
                        self.pos += 1;
                    }
                }
                (Some(b'/'), Some(b'*')) => {
                    self.pos += 2;
                    while self.pos < self.bytes.len()
                        && !(self.bytes[self.pos] == b'*'
                            && self.bytes.get(self.pos + 1) == Some(&b'/'))
                    {
                        self.pos += 1;
                    }
                    self.pos = (self.pos + 2).min(self.bytes.len());
                }
                _ => break,
            }
        }
        let Some(&b) = self.bytes.get(self.pos) else {
            return Ok(Tok::Eof);
        };
        self.pos += 1;
        match b {
            b'{' => Ok(Tok::LBrace),
            b'}' => Ok(Tok::RBrace),
            b'[' => Ok(Tok::LBracket),
            b']' => Ok(Tok::RBracket),
            b'=' => Ok(Tok::Equals),
            b',' => Ok(Tok::Comma),
            b';' => Ok(Tok::Semi),
            b'-' if self.bytes.get(self.pos) == Some(&b'>') => {
                self.pos += 1;
                Ok(Tok::Arrow)
            }
            b'"' => {
                let start = self.pos;
                while self.pos < self.bytes.len() && self.bytes[self.pos] != b'"' {
                    if self.bytes[self.pos] == b'\\' {
                        self.pos += 1;
                    }
                    self.pos += 1;
                }
                if self.pos >= self.bytes.len() {
                    bail!("DOT parse error: unterminated string");
                }
                let raw = std::str::from_utf8(&self.bytes[start..self.pos])
                    .context("invalid UTF-8 in DOT string")?
                    .replace("\\\"", "\"");
                self.pos += 1; // closing quote
                Ok(Tok::Quoted(raw))
            }
            b if b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b'-' => {
                let start = self.pos - 1;
                while matches!(self.bytes.get(self.pos),
                    Some(&c) if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b'-')
                {
                    self.pos += 1;
                }
                Ok(Tok::Ident(
                    std::str::from_utf8(&self.bytes[start..self.pos]).unwrap().to_string(),
                ))
            }
            other => bail!("DOT parse error: unexpected character `{}`", other as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_digraph() {
        let wf = parse_dot(
            r#"digraph test {
                a [work=10, memory=100, type="prep"];
                b [work=20, memory=200];
                a -> b [data=5];
            }"#,
            false,
        )
        .unwrap();
        assert_eq!(wf.num_tasks(), 2);
        assert_eq!(wf.num_edges(), 1);
        assert_eq!(wf.task(0).work, 10.0);
        assert_eq!(wf.task(0).task_type, "prep");
        assert_eq!(wf.edge(0).data, 5.0);
    }

    #[test]
    fn parses_edge_chains_and_comments() {
        let wf = parse_dot(
            r#"digraph {
                // comment
                a -> b -> c [data=3]; # trailing
                /* block */ a -> c;
            }"#,
            false,
        )
        .unwrap();
        assert_eq!(wf.num_tasks(), 3);
        assert_eq!(wf.num_edges(), 3);
        let e: Vec<f64> = wf.edges().iter().map(|e| e.data).collect();
        assert_eq!(e, vec![3.0, 3.0, 0.0]);
    }

    #[test]
    fn contracts_pseudo_tasks() {
        // a -> p_1 -> b ; pseudo node p_1 must vanish, edge spliced.
        let wf = parse_dot(
            r#"digraph {
                a -> p_1 [data=4];
                p_1 -> b [data=2];
                a -> c [data=1];
            }"#,
            true,
        )
        .unwrap();
        assert_eq!(wf.num_tasks(), 3); // a, b, c
        let names: Vec<&str> = wf.tasks().iter().map(|t| t.name.as_str()).collect();
        assert!(names.contains(&"a") && names.contains(&"b") && names.contains(&"c"));
        assert_eq!(wf.num_edges(), 2);
        // Contracted edge carries max of the two file sizes.
        let ab = wf.edges().iter().find(|e| wf.task(e.dst).name == "b").unwrap();
        assert_eq!(ab.data, 4.0);
    }

    #[test]
    fn pseudo_chain_contraction() {
        let wf = parse_dot(
            r#"digraph {
                a -> p_1; p_1 -> p_2; p_2 -> b [data=9];
            }"#,
            true,
        )
        .unwrap();
        assert_eq!(wf.num_tasks(), 2);
        assert_eq!(wf.num_edges(), 1);
        assert_eq!(wf.edge(0).data, 9.0);
    }

    #[test]
    fn quoted_names_and_graph_name() {
        let wf = parse_dot(r#"digraph "my wf" { "task one" -> "task two"; }"#, false).unwrap();
        assert_eq!(wf.name, "my wf");
        assert_eq!(wf.task(0).name, "task one");
    }

    #[test]
    fn type_defaults_strip_instance_suffix() {
        let wf = parse_dot("digraph { fastqc_12 -> align_3; }", false).unwrap();
        assert_eq!(wf.task(0).task_type, "fastqc");
        assert_eq!(wf.task(1).task_type, "align");
    }

    #[test]
    fn dot_roundtrip() {
        let wf = parse_dot(
            r#"digraph rt { a [work=1, memory=2]; b [work=3, memory=4]; a -> b [data=7]; }"#,
            false,
        )
        .unwrap();
        let text = to_dot(&wf);
        let wf2 = parse_dot(&text, false).unwrap();
        assert_eq!(wf2.num_tasks(), 2);
        assert_eq!(wf2.task(0).work, 1.0);
        assert_eq!(wf2.edge(0).data, 7.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_dot("graph { a -- b; }", false).is_err());
        assert!(parse_dot("digraph { a -> ; }", false).is_err());
        assert!(parse_dot("digraph { a -> b", false).is_err());
    }

    #[test]
    fn skips_global_attr_statements() {
        let wf = parse_dot(
            r#"digraph {
                graph [rankdir=LR];
                node [shape=box];
                edge [color=red];
                a -> b;
            }"#,
            false,
        )
        .unwrap();
        assert_eq!(wf.num_tasks(), 2);
    }
}
