//! JSON workflow interchange format (native format of this library).
//!
//! ```json
//! {
//!   "name": "chipseq_2000",
//!   "tasks": [ {"name": "t0", "type": "fastqc", "work": 12.5, "memory": 5e7} ],
//!   "edges": [ {"src": 0, "dst": 1, "data": 1024.0} ]
//! }
//! ```
//!
//! Edge endpoints may be task indices (numbers) or task names (strings).

use super::{Workflow, WorkflowBuilder};
use crate::ser::json::{obj, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Serialize a workflow to the JSON interchange format.
pub fn to_json(wf: &Workflow) -> Value {
    let tasks: Vec<Value> = wf
        .tasks()
        .iter()
        .map(|t| {
            obj(vec![
                ("name", t.name.as_str().into()),
                ("type", t.task_type.as_str().into()),
                ("work", t.work.into()),
                ("memory", t.memory.into()),
            ])
        })
        .collect();
    let edges: Vec<Value> = wf
        .edges()
        .iter()
        .map(|e| {
            obj(vec![
                ("src", e.src.into()),
                ("dst", e.dst.into()),
                ("data", e.data.into()),
            ])
        })
        .collect();
    obj(vec![
        ("name", wf.name.as_str().into()),
        ("tasks", Value::Array(tasks)),
        ("edges", Value::Array(edges)),
    ])
}

/// Deserialize a workflow from the JSON interchange format.
pub fn from_json(v: &Value) -> Result<Workflow> {
    let name = v.req_str("name")?;
    let mut b = WorkflowBuilder::new(name);
    let mut by_name: HashMap<String, usize> = HashMap::new();
    for (i, t) in v.req_array("tasks")?.iter().enumerate() {
        let tname = t.req_str("name").with_context(|| format!("task #{i}"))?;
        let ttype = t.get("type").and_then(Value::as_str).unwrap_or(tname);
        let work = t.req_f64("work").with_context(|| format!("task `{tname}`"))?;
        let memory = t.req_f64("memory").with_context(|| format!("task `{tname}`"))?;
        let id = b.task(tname, ttype, work, memory);
        by_name.insert(tname.to_string(), id);
    }
    let n = b.num_tasks();
    let endpoint = |e: &Value, key: &str| -> Result<usize> {
        match e.req(key)? {
            Value::Number(_) => {
                let id = e.req_usize(key)?;
                if id >= n {
                    bail!("edge endpoint `{key}` = {id} out of range (n = {n})");
                }
                Ok(id)
            }
            Value::String(s) => by_name
                .get(s.as_str())
                .copied()
                .ok_or_else(|| anyhow!("edge endpoint `{key}` references unknown task `{s}`")),
            _ => bail!("edge endpoint `{key}` must be an index or a task name"),
        }
    };
    for (i, e) in v.req_array("edges")?.iter().enumerate() {
        let src = endpoint(e, "src").with_context(|| format!("edge #{i}"))?;
        let dst = endpoint(e, "dst").with_context(|| format!("edge #{i}"))?;
        let data = e.req_f64("data").with_context(|| format!("edge #{i}"))?;
        b.edge(src, dst, data);
    }
    b.build()
}

/// Load a workflow from a file, dispatching on extension:
/// `.json` → interchange format, `.dot`/`.gv` → DOT (pseudo-tasks contracted).
pub fn load(path: &Path) -> Result<Workflow> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading workflow file {}", path.display()))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("json") => {
            let v = Value::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
            from_json(&v)
        }
        Some("dot") | Some("gv") => super::dot::parse_dot(&text, true),
        other => bail!(
            "unsupported workflow file extension {:?} for {} (expected .json, .dot, .gv)",
            other,
            path.display()
        ),
    }
}

/// Save a workflow to a `.json` file (pretty-printed).
pub fn save(wf: &Workflow, path: &Path) -> Result<()> {
    std::fs::write(path, to_json(wf).to_string_pretty())
        .with_context(|| format!("writing workflow file {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::WorkflowBuilder;

    fn sample() -> Workflow {
        let mut b = WorkflowBuilder::new("sample");
        let a = b.task("a", "prep", 10.0, 100.0);
        let c = b.task("c", "align", 20.0, 200.0);
        let d = b.task("d", "merge", 5.0, 50.0);
        b.edge(a, c, 7.0);
        b.edge(c, d, 8.0);
        b.edge(a, d, 9.0);
        b.build().unwrap()
    }

    #[test]
    fn json_roundtrip() {
        let wf = sample();
        let v = to_json(&wf);
        let wf2 = from_json(&v).unwrap();
        assert_eq!(wf2.name, wf.name);
        assert_eq!(wf2.num_tasks(), wf.num_tasks());
        assert_eq!(wf2.num_edges(), wf.num_edges());
        assert_eq!(wf2.task(1).task_type, "align");
        assert_eq!(wf2.edge(2).data, 9.0);
    }

    #[test]
    fn edges_by_name() {
        let text = r#"{
            "name": "byname",
            "tasks": [
                {"name": "x", "work": 1, "memory": 1},
                {"name": "y", "work": 1, "memory": 1}
            ],
            "edges": [ {"src": "x", "dst": "y", "data": 3} ]
        }"#;
        let wf = from_json(&Value::parse(text).unwrap()).unwrap();
        assert_eq!(wf.num_edges(), 1);
        assert_eq!(wf.edge(0).src, 0);
        assert_eq!(wf.edge(0).dst, 1);
    }

    #[test]
    fn rejects_bad_references() {
        let text = r#"{
            "name": "bad",
            "tasks": [ {"name": "x", "work": 1, "memory": 1} ],
            "edges": [ {"src": "x", "dst": "nope", "data": 3} ]
        }"#;
        assert!(from_json(&Value::parse(text).unwrap()).is_err());
        let text2 = r#"{
            "name": "bad2",
            "tasks": [ {"name": "x", "work": 1, "memory": 1} ],
            "edges": [ {"src": 0, "dst": 5, "data": 3} ]
        }"#;
        assert!(from_json(&Value::parse(text2).unwrap()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let wf = sample();
        let dir = std::env::temp_dir().join("memsched_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wf.json");
        save(&wf, &path).unwrap();
        let wf2 = load(&path).unwrap();
        assert_eq!(wf2.num_tasks(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_unknown_extension() {
        let p = std::env::temp_dir().join("wf.xyz");
        std::fs::write(&p, "x").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
