//! Workflow DAG substrate (paper §III-A).
//!
//! A workflow is a DAG `G = (V, E)`: vertices are tasks with a work amount
//! `w_u` (operations) and a memory requirement `m_u`; each edge `(u, v)`
//! carries `c_{u,v}`, the size of the file task `u` produces for task `v`.
//!
//! The graph is stored in CSR form (both directions) for allocation-free
//! traversal in the scheduler hot loop.

pub mod dot;
pub mod io;

use anyhow::{bail, Result};

/// Index of a task within its [`Workflow`].
pub type TaskId = usize;

/// A single workflow task (DAG vertex).
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Unique task name (e.g. `fastqc_7`).
    pub name: String,
    /// Task *type* label used to bind historical trace data (e.g. `fastqc`).
    pub task_type: String,
    /// `w_u`: number of operations (normalized work units).
    pub work: f64,
    /// `m_u`: memory required during execution, in bytes.
    pub memory: f64,
}

/// A directed edge `(src, dst)` carrying `c_{src,dst}` bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: TaskId,
    pub dst: TaskId,
    /// `c_{u,v}`: size of the transferred file, in bytes.
    pub data: f64,
}

/// Index of an edge within its [`Workflow`].
pub type EdgeId = usize;

/// An immutable, validated workflow DAG.
#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: String,
    tasks: Vec<Task>,
    edges: Vec<Edge>,
    // CSR over outgoing edges: for task u, edge ids are
    // out_edges[out_start[u]..out_start[u+1]].
    out_start: Vec<usize>,
    out_edges: Vec<EdgeId>,
    // CSR over incoming edges.
    in_start: Vec<usize>,
    in_edges: Vec<EdgeId>,
}

/// Builder that accumulates tasks/edges and validates on [`build`].
///
/// [`build`]: WorkflowBuilder::build
#[derive(Debug, Default, Clone)]
pub struct WorkflowBuilder {
    name: String,
    tasks: Vec<Task>,
    edges: Vec<Edge>,
}

impl WorkflowBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        WorkflowBuilder { name: name.into(), tasks: Vec::new(), edges: Vec::new() }
    }

    /// Add a task; returns its id. Name uniqueness is checked in [`build`].
    ///
    /// [`build`]: WorkflowBuilder::build
    pub fn task(
        &mut self,
        name: impl Into<String>,
        task_type: impl Into<String>,
        work: f64,
        memory: f64,
    ) -> TaskId {
        let id = self.tasks.len();
        self.tasks.push(Task {
            name: name.into(),
            task_type: task_type.into(),
            work,
            memory,
        });
        id
    }

    /// Add an edge `(src, dst)` with `data` bytes transferred.
    pub fn edge(&mut self, src: TaskId, dst: TaskId, data: f64) {
        self.edges.push(Edge { src, dst, data });
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Validate and freeze into a [`Workflow`].
    ///
    /// Checks: non-empty, unique names, in-range endpoints, no self-loops,
    /// non-negative finite weights, acyclicity.
    pub fn build(self) -> Result<Workflow> {
        let n = self.tasks.len();
        if n == 0 {
            bail!("workflow `{}` has no tasks", self.name);
        }
        {
            let mut names: Vec<&str> = self.tasks.iter().map(|t| t.name.as_str()).collect();
            names.sort_unstable();
            if let Some(w) = names.windows(2).find(|w| w[0] == w[1]) {
                bail!("duplicate task name `{}` in workflow `{}`", w[0], self.name);
            }
        }
        for t in &self.tasks {
            if !(t.work.is_finite() && t.work >= 0.0) {
                bail!("task `{}` has invalid work {}", t.name, t.work);
            }
            if !(t.memory.is_finite() && t.memory >= 0.0) {
                bail!("task `{}` has invalid memory {}", t.name, t.memory);
            }
        }
        for e in &self.edges {
            if e.src >= n || e.dst >= n {
                bail!("edge ({}, {}) out of range (n = {n})", e.src, e.dst);
            }
            if e.src == e.dst {
                bail!("self-loop on task `{}`", self.tasks[e.src].name);
            }
            if !(e.data.is_finite() && e.data >= 0.0) {
                bail!("edge ({}, {}) has invalid data size {}", e.src, e.dst, e.data);
            }
        }

        // CSR construction (counting sort by src / dst).
        let m = self.edges.len();
        let mut out_start = vec![0usize; n + 1];
        let mut in_start = vec![0usize; n + 1];
        for e in &self.edges {
            out_start[e.src + 1] += 1;
            in_start[e.dst + 1] += 1;
        }
        for i in 0..n {
            out_start[i + 1] += out_start[i];
            in_start[i + 1] += in_start[i];
        }
        let mut out_edges = vec![0usize; m];
        let mut in_edges = vec![0usize; m];
        let mut out_cursor = out_start.clone();
        let mut in_cursor = in_start.clone();
        for (eid, e) in self.edges.iter().enumerate() {
            out_edges[out_cursor[e.src]] = eid;
            out_cursor[e.src] += 1;
            in_edges[in_cursor[e.dst]] = eid;
            in_cursor[e.dst] += 1;
        }

        let wf = Workflow {
            name: self.name,
            tasks: self.tasks,
            edges: self.edges,
            out_start,
            out_edges,
            in_start,
            in_edges,
        };
        // Acyclicity: Kahn's algorithm must consume every vertex.
        if wf.topological_order().len() != n {
            bail!("workflow `{}` contains a cycle", wf.name);
        }
        Ok(wf)
    }
}

impl Workflow {
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }

    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id]
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Ids of outgoing edges of `u`.
    pub fn out_edge_ids(&self, u: TaskId) -> &[EdgeId] {
        &self.out_edges[self.out_start[u]..self.out_start[u + 1]]
    }

    /// Ids of incoming edges of `u`.
    pub fn in_edge_ids(&self, u: TaskId) -> &[EdgeId] {
        &self.in_edges[self.in_start[u]..self.in_start[u + 1]]
    }

    /// Children of `u` with the corresponding edge data sizes.
    pub fn children(&self, u: TaskId) -> impl Iterator<Item = (TaskId, f64)> + '_ {
        self.out_edge_ids(u).iter().map(move |&e| (self.edges[e].dst, self.edges[e].data))
    }

    /// Parents of `u` with the corresponding edge data sizes.
    pub fn parents(&self, u: TaskId) -> impl Iterator<Item = (TaskId, f64)> + '_ {
        self.in_edge_ids(u).iter().map(move |&e| (self.edges[e].src, self.edges[e].data))
    }

    pub fn out_degree(&self, u: TaskId) -> usize {
        self.out_start[u + 1] - self.out_start[u]
    }

    pub fn in_degree(&self, u: TaskId) -> usize {
        self.in_start[u + 1] - self.in_start[u]
    }

    /// Tasks with no parents.
    pub fn sources(&self) -> Vec<TaskId> {
        (0..self.num_tasks()).filter(|&u| self.in_degree(u) == 0).collect()
    }

    /// Tasks with no children.
    pub fn sinks(&self) -> Vec<TaskId> {
        (0..self.num_tasks()).filter(|&u| self.out_degree(u) == 0).collect()
    }

    /// Sum of incoming edge sizes of `u`.
    pub fn total_in_data(&self, u: TaskId) -> f64 {
        self.parents(u).map(|(_, c)| c).sum()
    }

    /// Sum of outgoing edge sizes of `u`.
    pub fn total_out_data(&self, u: TaskId) -> f64 {
        self.children(u).map(|(_, c)| c).sum()
    }

    /// `r_u` (paper eq. 1): total memory requirement of executing `u`,
    /// `max(m_u, sum of inputs, sum of outputs)`.
    pub fn memory_requirement(&self, u: TaskId) -> f64 {
        self.tasks[u]
            .memory
            .max(self.total_in_data(u))
            .max(self.total_out_data(u))
    }

    /// A topological order via Kahn's algorithm (stable: ready tasks are
    /// processed in increasing id order). Returns fewer than `n` tasks iff
    /// the graph has a cycle (only possible pre-validation).
    pub fn topological_order(&self) -> Vec<TaskId> {
        let n = self.num_tasks();
        let mut indeg: Vec<usize> = (0..n).map(|u| self.in_degree(u)).collect();
        // Binary heap would give lexicographically-smallest order; a simple
        // FIFO is sufficient and faster. Seed in id order for determinism.
        let mut queue: std::collections::VecDeque<TaskId> =
            (0..n).filter(|&u| indeg[u] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for (v, _) in self.children(u) {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push_back(v);
                }
            }
        }
        order
    }

    /// Check that `order` is a permutation of all tasks respecting edges.
    pub fn is_topological_order(&self, order: &[TaskId]) -> bool {
        if order.len() != self.num_tasks() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.num_tasks()];
        for (i, &u) in order.iter().enumerate() {
            if u >= self.num_tasks() || pos[u] != usize::MAX {
                return false;
            }
            pos[u] = i;
        }
        self.edges.iter().all(|e| pos[e.src] < pos[e.dst])
    }

    /// Update a task's parameters in place (used by the runtime system
    /// when actual values are revealed; the DAG structure is immutable).
    pub fn set_task_params(&mut self, u: TaskId, work: f64, memory: f64) {
        debug_assert!(work.is_finite() && work >= 0.0);
        debug_assert!(memory.is_finite() && memory >= 0.0);
        self.tasks[u].work = work;
        self.tasks[u].memory = memory;
    }

    /// Total work over all tasks.
    pub fn total_work(&self) -> f64 {
        self.tasks.iter().map(|t| t.work).sum()
    }

    /// Summary statistics (used by `memsched info` and reports).
    pub fn stats(&self) -> WorkflowStats {
        let n = self.num_tasks();
        let depth = self.critical_path_len();
        WorkflowStats {
            tasks: n,
            edges: self.num_edges(),
            sources: self.sources().len(),
            sinks: self.sinks().len(),
            max_in_degree: (0..n).map(|u| self.in_degree(u)).max().unwrap_or(0),
            max_out_degree: (0..n).map(|u| self.out_degree(u)).max().unwrap_or(0),
            total_work: self.total_work(),
            total_data: self.edges.iter().map(|e| e.data).sum(),
            max_memory_requirement: (0..n)
                .map(|u| self.memory_requirement(u))
                .fold(0.0, f64::max),
            depth,
        }
    }

    /// Length (in vertices) of the longest path.
    pub fn critical_path_len(&self) -> usize {
        let order = self.topological_order();
        let mut depth = vec![1usize; self.num_tasks()];
        let mut best = 0;
        for &u in &order {
            for (v, _) in self.children(u) {
                depth[v] = depth[v].max(depth[u] + 1);
            }
            best = best.max(depth[u]);
        }
        best
    }
}

/// Aggregate graph statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowStats {
    pub tasks: usize,
    pub edges: usize,
    pub sources: usize,
    pub sinks: usize,
    pub max_in_degree: usize,
    pub max_out_degree: usize,
    pub total_work: f64,
    pub total_data: f64,
    pub max_memory_requirement: f64,
    pub depth: usize,
}

/// Paper §VI-A-1a size groups: tiny ≤ 200, small 1 000–8 000,
/// middle 10 000–18 000, big 20 000–30 000 tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SizeGroup {
    Tiny,
    Small,
    Middle,
    Big,
}

impl SizeGroup {
    pub fn of(num_tasks: usize) -> SizeGroup {
        match num_tasks {
            0..=200 => SizeGroup::Tiny,
            201..=8000 => SizeGroup::Small,
            8001..=18000 => SizeGroup::Middle,
            _ => SizeGroup::Big,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SizeGroup::Tiny => "tiny",
            SizeGroup::Small => "small",
            SizeGroup::Middle => "middle",
            SizeGroup::Big => "big",
        }
    }

    pub fn all() -> [SizeGroup; 4] {
        [SizeGroup::Tiny, SizeGroup::Small, SizeGroup::Middle, SizeGroup::Big]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diamond: 0 -> {1, 2} -> 3.
    pub(crate) fn diamond() -> Workflow {
        let mut b = WorkflowBuilder::new("diamond");
        let a = b.task("a", "t", 10.0, 100.0);
        let x = b.task("x", "t", 20.0, 200.0);
        let y = b.task("y", "t", 30.0, 300.0);
        let z = b.task("z", "t", 40.0, 400.0);
        b.edge(a, x, 5.0);
        b.edge(a, y, 6.0);
        b.edge(x, z, 7.0);
        b.edge(y, z, 8.0);
        b.build().unwrap()
    }

    #[test]
    fn builds_and_traverses() {
        let wf = diamond();
        assert_eq!(wf.num_tasks(), 4);
        assert_eq!(wf.num_edges(), 4);
        assert_eq!(wf.sources(), vec![0]);
        assert_eq!(wf.sinks(), vec![3]);
        let kids: Vec<_> = wf.children(0).collect();
        assert_eq!(kids, vec![(1, 5.0), (2, 6.0)]);
        let parents: Vec<_> = wf.parents(3).collect();
        assert_eq!(parents, vec![(1, 7.0), (2, 8.0)]);
        assert_eq!(wf.in_degree(3), 2);
        assert_eq!(wf.out_degree(0), 2);
    }

    #[test]
    fn topological_order_valid() {
        let wf = diamond();
        let order = wf.topological_order();
        assert!(wf.is_topological_order(&order));
        assert!(!wf.is_topological_order(&[3, 2, 1, 0]));
        assert!(!wf.is_topological_order(&[0, 1, 2]));
        assert!(!wf.is_topological_order(&[0, 1, 1, 3]));
    }

    #[test]
    fn rejects_cycle() {
        let mut b = WorkflowBuilder::new("cycle");
        let a = b.task("a", "t", 1.0, 1.0);
        let c = b.task("c", "t", 1.0, 1.0);
        b.edge(a, c, 1.0);
        b.edge(c, a, 1.0);
        assert!(b.build().is_err());
    }

    #[test]
    fn rejects_self_loop_and_bad_weights() {
        let mut b = WorkflowBuilder::new("bad");
        let a = b.task("a", "t", 1.0, 1.0);
        b.edge(a, a, 1.0);
        assert!(b.build().is_err());

        let mut b = WorkflowBuilder::new("bad2");
        b.task("a", "t", -1.0, 1.0);
        assert!(b.build().is_err());

        let mut b = WorkflowBuilder::new("bad3");
        b.task("a", "t", 1.0, f64::NAN);
        assert!(b.build().is_err());
    }

    #[test]
    fn rejects_duplicate_names_and_empty() {
        let mut b = WorkflowBuilder::new("dup");
        b.task("a", "t", 1.0, 1.0);
        b.task("a", "t", 1.0, 1.0);
        assert!(b.build().is_err());
        assert!(WorkflowBuilder::new("empty").build().is_err());
    }

    #[test]
    fn memory_requirement_is_max_of_three() {
        let wf = diamond();
        // Task 0: m=100, in=0, out=11 -> 100.
        assert_eq!(wf.memory_requirement(0), 100.0);
        // Task 3: m=400, in=15, out=0 -> 400.
        assert_eq!(wf.memory_requirement(3), 400.0);
        // A task whose file sizes dominate.
        let mut b = WorkflowBuilder::new("m");
        let a = b.task("a", "t", 1.0, 1.0);
        let c = b.task("c", "t", 1.0, 2.0);
        let d = b.task("d", "t", 1.0, 1.0);
        b.edge(a, c, 500.0);
        b.edge(c, d, 300.0);
        let wf = b.build().unwrap();
        assert_eq!(wf.memory_requirement(1), 500.0);
    }

    #[test]
    fn stats_sane() {
        let wf = diamond();
        let s = wf.stats();
        assert_eq!(s.tasks, 4);
        assert_eq!(s.edges, 4);
        assert_eq!(s.depth, 3);
        assert_eq!(s.total_work, 100.0);
        assert_eq!(s.max_in_degree, 2);
    }

    #[test]
    fn size_groups() {
        assert_eq!(SizeGroup::of(100), SizeGroup::Tiny);
        assert_eq!(SizeGroup::of(200), SizeGroup::Tiny);
        assert_eq!(SizeGroup::of(1000), SizeGroup::Small);
        assert_eq!(SizeGroup::of(8000), SizeGroup::Small);
        assert_eq!(SizeGroup::of(10000), SizeGroup::Middle);
        assert_eq!(SizeGroup::of(18000), SizeGroup::Middle);
        assert_eq!(SizeGroup::of(20000), SizeGroup::Big);
        assert_eq!(SizeGroup::of(30000), SizeGroup::Big);
    }
}
