//! Property tests for the MemDag substrate: SP decomposition and the
//! min-peak-memory traversal on random DAGs and generator models.

use memsched::memdag::{greedy_min_peak, min_memory_traversal, peak_memory, sptree};
use memsched::testing::{check, random_dag};

#[test]
fn traversals_are_topological_orders() {
    check(80, 0x111, |rng| {
        let wf = random_dag(rng, 100);
        let tr = min_memory_traversal(&wf);
        if !wf.is_topological_order(&tr.order) {
            return Err("MemDag order not topological".into());
        }
        if tr.order.len() != wf.num_tasks() {
            return Err("MemDag order incomplete".into());
        }
        Ok(())
    });
}

#[test]
fn traversal_peak_matches_reported_peak() {
    check(60, 0x222, |rng| {
        let wf = random_dag(rng, 80);
        let tr = min_memory_traversal(&wf);
        let recomputed = peak_memory(&wf, &tr.order);
        if (recomputed - tr.peak).abs() > 1e-6 * tr.peak.max(1.0) {
            return Err(format!("peak mismatch: {} vs {}", tr.peak, recomputed));
        }
        Ok(())
    });
}

#[test]
fn memdag_no_worse_than_greedy_on_sp_graphs() {
    // On the SP-decomposable generator models, the Liu-style ordering must
    // not lose to the naive topological order.
    for model in memsched::generator::models::all_models() {
        for samples in [3usize, 8, 15] {
            let graph = memsched::generator::expand(&model, samples).unwrap();
            let data = memsched::traces::HistoricalData::synthesize(
                &memsched::traces::task_types(&graph),
                &memsched::traces::TraceConfig::default(),
                7,
            );
            let wf = memsched::traces::bind_weights(&graph, &data, 2);
            let tr = min_memory_traversal(&wf);
            let base = peak_memory(&wf, &wf.topological_order());
            assert!(
                tr.peak <= base * 1.0001,
                "{} s={samples}: memdag {} vs topo {base}",
                model.name,
                tr.peak
            );
        }
    }
}

#[test]
fn greedy_fallback_is_topological_on_non_sp() {
    check(60, 0x333, |rng| {
        let wf = random_dag(rng, 70);
        let order = greedy_min_peak(&wf);
        if !wf.is_topological_order(&order) {
            return Err("greedy order not topological".into());
        }
        Ok(())
    });
}

#[test]
fn sp_decomposition_vertex_complete_when_it_exists() {
    check(80, 0x444, |rng| {
        let wf = random_dag(rng, 60);
        if let Some(tree) = sptree::decompose(&wf) {
            if tree.root.num_vertices() != wf.num_tasks() {
                return Err(format!(
                    "SP tree has {} vertices, workflow {}",
                    tree.root.num_vertices(),
                    wf.num_tasks()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn deep_chain_and_wide_fan_edge_cases() {
    // Deep chain: 5 000 tasks (recursion depths, profile composition).
    let mut b = memsched::workflow::WorkflowBuilder::new("chain");
    let ids: Vec<_> = (0..5000).map(|i| b.task(format!("t{i}"), "t", 1.0, 10.0)).collect();
    for w in ids.windows(2) {
        b.edge(w[0], w[1], 1.0);
    }
    let wf = b.build().unwrap();
    let tr = min_memory_traversal(&wf);
    assert!(tr.used_sp);
    assert_eq!(tr.order, (0..5000).collect::<Vec<_>>());

    // Wide independent fan: 3 000 isolated tasks.
    let mut b = memsched::workflow::WorkflowBuilder::new("fan");
    for i in 0..3000 {
        b.task(format!("t{i}"), "t", 1.0, (i % 17) as f64 + 1.0);
    }
    let wf = b.build().unwrap();
    let tr = min_memory_traversal(&wf);
    assert!(wf.is_topological_order(&tr.order));
}
