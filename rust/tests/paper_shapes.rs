//! Integration tests pinning the paper's qualitative *shapes* (§VI) at a
//! reduced scale, so regressions in the heuristics or the workload model
//! are caught by `cargo test`:
//!
//!  - HEFT overcommits and fails on large workflows; HEFTM variants stay
//!    valid on the default cluster;
//!  - on the memory-constrained cluster HEFTM-MM succeeds where
//!    HEFTM-BL fails, and uses the least memory;
//!  - dynamic: without recomputation executions die; with recomputation
//!    HEFTM-MM survives.

use memsched::experiments::WorkloadSpec;
use memsched::platform::presets::{default_cluster, memory_constrained_cluster};
use memsched::scheduler::{Algorithm, EvictionPolicy, ScheduleRequest};
use memsched::simulator::{simulate, DeviationModel, SimConfig, SimMode};

fn workload(family: &str, size: usize, input: usize) -> memsched::workflow::Workflow {
    WorkloadSpec { family: family.into(), size: Some(size), input, seed: 42 ^ size as u64 }
        .build()
        .unwrap()
}

#[test]
fn heft_fails_on_default_cluster_at_scale() {
    let wf = workload("chipseq", 20000, 3);
    let cluster = default_cluster();
    let heft = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
    assert!(!heft.valid, "HEFT should overcommit at 20k tasks");
    assert!(
        heft.mem_peak_frac.iter().cloned().fold(0.0, f64::max) > 1.0,
        "HEFT peak usage must exceed 100%"
    );
    for algo in [Algorithm::HeftmBl, Algorithm::HeftmBlc, Algorithm::HeftmMm] {
        let s = ScheduleRequest::new(&wf, &cluster).algo(algo).policy(EvictionPolicy::LargestFirst).run();
        assert!(s.valid, "{algo:?} must schedule the default cluster at 20k");
        // Makespan within a sane band of the (invalid) HEFT bound.
        assert!(s.makespan >= heft.makespan * 0.999);
        assert!(s.makespan <= heft.makespan * 5.0, "{algo:?} makespan blow-up");
    }
}

#[test]
fn constrained_cluster_separates_the_heuristics() {
    // chipseq @ 10k, large input: BL fails, MM succeeds (paper Fig 5).
    let wf = workload("chipseq", 10000, 4);
    let cluster = memory_constrained_cluster();
    let bl = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
    let mm = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
    assert!(!bl.valid, "HEFTM-BL should fail on chipseq@10k input4 constrained");
    assert!(mm.valid, "HEFTM-MM must always succeed (paper: 100%)");
    // MM's memory-minimizing order uses less memory than BL's (Fig 7).
    assert!(
        mm.mean_mem_usage() < bl.mean_mem_usage(),
        "MM {} vs BL {}",
        mm.mean_mem_usage(),
        bl.mean_mem_usage()
    );
}

#[test]
fn mm_memory_usage_insensitive_to_size() {
    // Fig 7: MM's footprint stays flat with workflow size.
    let cluster = memory_constrained_cluster();
    let mut usages = Vec::new();
    for size in [1000, 4000, 10000] {
        let wf = workload("chipseq", size, 3);
        let mm = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
        assert!(mm.valid);
        usages.push(mm.mean_mem_usage());
    }
    // "Flat" in the paper's sense: bounded well below capacity at every
    // size (no growth toward 100% as for BL/BLC/HEFT).
    let max = usages.iter().cloned().fold(0.0, f64::max);
    assert!(max < 0.6, "MM usage must stay well below capacity: {usages:?}");
}

#[test]
fn dynamic_recompute_rescues_constrained_executions() {
    let wf = workload("methylseq", 1000, 3);
    let cluster = memory_constrained_cluster();
    let s = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmMm).policy(EvictionPolicy::LargestFirst).run();
    assert!(s.valid);
    let dev = DeviationModel::new(0.1, 1234);
    let stat = simulate(&wf, &cluster, &s, &SimConfig::new(SimMode::FollowStatic, dev));
    let dynr = simulate(&wf, &cluster, &s, &SimConfig::new(SimMode::Recompute, dev));
    assert!(dynr.completed, "recompute mode must survive: {:?}", dynr.failure);
    // The static mode typically dies here; if it survives, recompute must
    // not be slower by more than a small factor.
    if stat.completed {
        assert!(dynr.makespan <= stat.makespan * 1.2);
    }
    assert!(dynr.recomputations > 0, "10% deviations must trigger recomputations");
}

#[test]
fn relative_makespans_in_paper_band_small() {
    // Fig 2 band at small scale: HEFTM-BL within ~1.0–1.6× of HEFT.
    let wf = workload("atacseq", 2000, 2);
    let cluster = default_cluster();
    let heft = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::Heft).policy(EvictionPolicy::LargestFirst).run();
    let bl = ScheduleRequest::new(&wf, &cluster).algo(Algorithm::HeftmBl).policy(EvictionPolicy::LargestFirst).run();
    assert!(bl.valid);
    let rel = bl.makespan / heft.makespan;
    assert!((0.999..=1.6).contains(&rel), "relative makespan {rel}");
}

#[test]
fn runtimes_ordering_bl_faster_than_mm_at_scale() {
    // Fig 9 shape: BL/BLC rank computation is cheaper than MM's MemDag.
    let wf = workload("eager", 10000, 2);
    let cluster = memory_constrained_cluster();
    let t0 = std::time::Instant::now();
    let _ = Algorithm::HeftmBl.rank_order(&wf, &cluster);
    let t_bl = t0.elapsed();
    let t0 = std::time::Instant::now();
    let _ = Algorithm::HeftmMm.rank_order(&wf, &cluster);
    let t_mm = t0.elapsed();
    assert!(
        t_mm >= t_bl,
        "MemDag ranking should not be cheaper than bottom levels: {t_mm:?} vs {t_bl:?}"
    );
}
