//! End-to-end integration over the PJRT bridge: load both AOT artifacts,
//! execute them, and check numerics against the native implementations.
//! These tests require `make artifacts` (they are skipped otherwise so
//! `cargo test` works on a fresh checkout).

use memsched::runtime::{artifact_path, predictor::Predictor, scorer};
use memsched::scheduler::engine::{EftScorer, ParentInfo};
use memsched::scheduler::{Algorithm, Engine, EvictionPolicy, ScoreBuffers};
use memsched::testing::{check, random_cluster, random_dag};

fn artifacts_built() -> bool {
    artifact_path("eft_score.hlo.txt").exists() && artifact_path("predictor.hlo.txt").exists()
}

#[test]
fn xla_scorer_matches_native_on_random_queries() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let xla = scorer::XlaScorer::load_default().unwrap();
    check(25, 0x77AA, |rng| {
        let k = rng.range_inclusive(1, 72);
        let p = rng.range_inclusive(0, 16);
        let parents: Vec<ParentInfo> = (0..p)
            .map(|_| ParentInfo {
                finish: rng.uniform(0.0, 500.0),
                data: rng.uniform(0.0, 1e9),
                proc: rng.range_inclusive(0, k - 1),
            })
            .collect();
        let bufs = ScoreBuffers {
            proc_ready: (0..k).map(|_| rng.uniform(0.0, 500.0)).collect(),
            speeds: (0..k).map(|_| rng.uniform(1.0, 32.0)).collect(),
            avail_mem: (0..k).map(|_| rng.uniform(0.0, 64e9)).collect(),
            // Row-major parents × procs.
            comm: (0..p * k).map(|_| rng.uniform(0.0, 500.0)).collect(),
            parents,
            work: rng.uniform(0.1, 500.0),
            memory: rng.uniform(0.0, 8e9),
            out_total: rng.uniform(0.0, 4e9),
            bandwidth: 1e9,
            ..Default::default()
        };
        let (mut nft, mut nres) = (vec![0.0; k], vec![0.0; k]);
        scorer::NativeScorer.score(&bufs.query(), &mut nft, &mut nres);
        let (mut xft, mut xres) = (vec![0.0; k], vec![0.0; k]);
        xla.score(&bufs.query(), &mut xft, &mut xres);
        for j in 0..k {
            // f32 artifact vs f64 native: tolerances scaled to magnitude.
            let tol_ft = 1e-4 * nft[j].abs().max(1.0);
            if (nft[j] - xft[j]).abs() > tol_ft {
                return Err(format!("ft[{j}]: native {} vs xla {}", nft[j], xft[j]));
            }
            let tol_res = 1e-4 * nres[j].abs().max(1e4);
            if (nres[j] - xres[j]).abs() > tol_res {
                return Err(format!("res[{j}]: native {} vs xla {}", nres[j], xres[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn engine_with_xla_scorer_produces_equivalent_schedules() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let xla = scorer::XlaScorer::load_default().unwrap();
    check(8, 0x88BB, |rng| {
        let wf = random_dag(rng, 40);
        let cluster = random_cluster(rng);
        let order = Algorithm::HeftmBl.rank_order(&wf, &cluster);
        let native = Engine::new(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst)
            .run(&order);
        let accel = Engine::new(&wf, &cluster, Algorithm::HeftmBl, EvictionPolicy::LargestFirst)
            .with_scorer(&xla)
            .run(&order);
        if native.valid != accel.valid {
            return Err(format!("validity diverged: {} vs {}", native.valid, accel.valid));
        }
        let rel = (native.makespan - accel.makespan).abs() / native.makespan.max(1e-9);
        if rel > 0.01 {
            return Err(format!(
                "makespan diverged beyond tie-breaking: {} vs {}",
                native.makespan, accel.makespan
            ));
        }
        Ok(())
    });
}

#[test]
fn predictor_shrinks_toward_observation() {
    if !artifacts_built() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let p = Predictor::load_default().unwrap();
    // Monotone in the observed ratio; near-identity at 1.0.
    let (w0, m0) = p.correct(1.0, 1.0, 100.0).unwrap();
    assert!((w0 - 1.0).abs() < 0.1, "w0 = {w0}");
    assert!((m0 - 1.0).abs() < 0.1, "m0 = {m0}");
    let mut prev = 0.0;
    for obs in [0.7, 0.9, 1.1, 1.3] {
        let (w, _) = p.correct(obs, 1.0, 100.0).unwrap();
        assert!(w > prev, "not monotone at {obs}: {w} <= {prev}");
        prev = w;
    }
}
